"""splint — repo-specific static analysis for the SpliDT reproduction.

Enforces the parity, dispatch, and dtype contracts (docs/ANALYSIS.md)
at lint time::

    python -m tools.splint src tests benchmarks           # text report
    python -m tools.splint src --format=json              # CI artifact
    python -m tools.splint src --fix                      # R003/R005

Importing :mod:`tools.splint.rules` populates the registry as a side
effect, so ``from tools.splint import lint_source`` is ready to use.
"""
from tools.splint.core import (            # noqa: F401  (public surface)
    Diagnostic,
    Fix,
    LintContext,
    RULES,
    Rule,
    lint_source,
    render_json,
    render_text,
)
from tools.splint import rules as _rules   # noqa: F401  (registers rules)
from tools.splint.autofix import fix_file, fix_source  # noqa: F401

__version__ = "0.1.0"

"""splint core: diagnostics, suppression pragmas, the rule registry.

The analyzer is deliberately boring machinery: a rule is a function
``(LintContext) -> Iterable[Diagnostic]`` registered with
:func:`rule`; :func:`lint_source` parses one file, runs every rule
whose ``applies`` predicate matches the repo-relative path, then folds
in the suppression pragmas.  All repo knowledge lives in
``tools.splint.rules``; everything here is reusable plumbing.

Suppression syntax (see ``docs/ANALYSIS.md``)::

    x = jnp.cumsum(counts)  # splint: allow[R001]: int32 offsets, exact

A pragma suppresses the listed codes on its own line; a pragma on a
line by itself covers the *next* source line (for statements too long
to share a line with a justification).  The reason text after the
trailing ``:`` is mandatory — a reasonless or unused pragma is itself
reported as **R000**, so the suppression inventory can never rot.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from typing import Callable, Iterable, Iterator

__all__ = [
    "Diagnostic", "Fix", "LintContext", "Rule", "RULES", "rule",
    "lint_source", "render_text", "render_json",
]

#: Trees outside the SpliDT reproduction proper (the LM-serving
#: prototype kept for the roofline/bench harness).  None of the parity
#: or dispatch contracts apply there, so every rule skips them; the
#: rationale lives in README.md ("what splint covers").
EXCLUDED_TREES = (
    "src/repro/models/",
    "src/repro/configs/",
    "src/repro/train/",
)


@dataclasses.dataclass(frozen=True)
class Fix:
    """One mechanical text edit: replace the span from ``(line,
    col_start)`` to ``(end_line, col_end)`` (1-based lines, 0-based
    cols) with ``replacement``."""
    line: int
    col_start: int
    end_line: int
    col_end: int
    replacement: str


@dataclasses.dataclass
class Diagnostic:
    path: str           # repo-relative path as given to lint_source
    line: int           # 1-based
    col: int            # 0-based
    code: str           # "R001" ... "R008" ("R000" = pragma misuse)
    message: str
    fix: Fix | None = None

    def as_dict(self) -> dict:
        return {"path": self.path, "line": self.line, "col": self.col,
                "code": self.code, "message": self.message,
                "fixable": self.fix is not None}

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"


class LintContext:
    """Parsed view of one file handed to every rule."""

    def __init__(self, path: str, source: str):
        self.path = path.replace("\\", "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)

    # -- path classification helpers -----------------------------------
    def in_tree(self, *prefixes: str) -> bool:
        return any(self.path.startswith(p) for p in prefixes)

    @property
    def excluded(self) -> bool:
        return self.in_tree(*EXCLUDED_TREES)

    def segment(self, node: ast.AST) -> str:
        return ast.get_source_segment(self.source, node) or ""


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    name: str
    doc: str
    applies: Callable[[LintContext], bool]
    check: Callable[[LintContext], Iterable[Diagnostic]]


RULES: dict[str, Rule] = {}


def rule(code: str, name: str, doc: str,
         applies: Callable[[LintContext], bool]):
    """Register ``check(ctx)`` under ``code``; used as a decorator."""
    def register(check):
        RULES[code] = Rule(code, name, doc, applies, check)
        return check
    return register


# ---------------------------------------------------------------------------
# suppression pragmas
# ---------------------------------------------------------------------------

_PRAGMA = re.compile(
    r"#\s*splint:\s*allow\[(?P<codes>[A-Z0-9,\s]+)\]"
    r"(?::\s*(?P<reason>.*\S))?\s*$")


@dataclasses.dataclass
class _Pragma:
    line: int            # line the pragma text sits on
    target: int          # line it suppresses
    codes: tuple[str, ...]
    reason: str | None
    used: bool = False


def _collect_pragmas(ctx: LintContext) -> list[_Pragma]:
    out = []
    for ln, text in enumerate(ctx.lines, 1):
        m = _PRAGMA.search(text)
        if not m:
            continue
        codes = tuple(c.strip() for c in m.group("codes").split(",") if c.strip())
        own_line = text[:m.start()].strip() == ""
        target = ln
        if own_line:
            # an own-line pragma covers the next statement line; skip
            # over continuation comment lines (multi-line reasons)
            target = ln + 1
            while target <= len(ctx.lines) and \
                    ctx.lines[target - 1].lstrip().startswith("#"):
                target += 1
        out.append(_Pragma(line=ln, target=target,
                           codes=codes, reason=m.group("reason")))
    return out


def lint_source(source: str, path: str,
                select: Iterable[str] | None = None) -> list[Diagnostic]:
    """Lint one file's source. ``path`` must be repo-relative (it drives
    each rule's ``applies`` scoping).  Returns unsuppressed diagnostics
    plus any R000 pragma-hygiene findings, sorted by position.

    >>> lint_source("import jax.numpy as jnp\\nx = jnp.arange(8)\\n",
    ...             "src/repro/kernels/demo.py")[0].code
    'R003'
    """
    ctx = LintContext(path, source)
    diags: list[Diagnostic] = []
    for r in RULES.values():
        if select is not None and r.code not in select:
            continue
        if ctx.excluded or not r.applies(ctx):
            continue
        diags.extend(r.check(ctx))

    pragmas = _collect_pragmas(ctx)
    by_target: dict[int, list[_Pragma]] = {}
    for p in pragmas:
        by_target.setdefault(p.target, []).append(p)

    kept: list[Diagnostic] = []
    for d in diags:
        suppressed = False
        for p in by_target.get(d.line, ()):
            if d.code in p.codes:
                p.used = True
                suppressed = True
        if not suppressed:
            kept.append(d)

    for p in pragmas:
        unknown = [c for c in p.codes if c not in RULES and c != "R000"]
        if unknown:
            kept.append(Diagnostic(
                ctx.path, p.line, 0, "R000",
                f"suppression names unknown rule code(s) {', '.join(unknown)}"))
        if not p.reason:
            kept.append(Diagnostic(
                ctx.path, p.line, 0, "R000",
                "suppression without a reason — write "
                "`# splint: allow[%s]: <why this is safe>`"
                % ",".join(p.codes)))
        if p.used is False and not unknown and (
                select is None or any(c in select for c in p.codes)):
            kept.append(Diagnostic(
                ctx.path, p.line, 0, "R000",
                f"unused suppression for {', '.join(p.codes)} "
                "— nothing fires here; delete the pragma"))

    kept.sort(key=lambda d: (d.path, d.line, d.col, d.code))
    return kept


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def render_text(diags: list[Diagnostic]) -> str:
    lines = [d.render() for d in diags]
    lines.append(f"splint: {len(diags)} diagnostic(s)")
    return "\n".join(lines)


def render_json(diags: list[Diagnostic]) -> str:
    return json.dumps({"diagnostics": [d.as_dict() for d in diags],
                       "count": len(diags)}, indent=2)


def iter_py_files(paths: list[str]) -> Iterator[str]:
    import os
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)

"""CLI: ``python -m tools.splint [paths...] [options]``.

Exit status 0 when the tree is clean (every diagnostic suppressed with
a reasoned pragma), 1 when any diagnostic remains, 2 on usage errors.
"""
from __future__ import annotations

import argparse
import os
import sys

from tools.splint import (
    RULES, Diagnostic, fix_file, lint_source, render_json, render_text)
from tools.splint.core import iter_py_files

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

DEFAULT_PATHS = ["src", "tests", "benchmarks"]


def _rel(path: str) -> str:
    """Repo-relative path with forward slashes (drives rule scoping)."""
    ap = os.path.abspath(path)
    try:
        rel = os.path.relpath(ap, REPO)
    except ValueError:          # different drive (windows)
        rel = path
    return rel.replace(os.sep, "/")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.splint",
        description="repo-specific static analysis: parity, dispatch "
                    "and dtype contracts (docs/ANALYSIS.md)")
    ap.add_argument("paths", nargs="*", default=DEFAULT_PATHS,
                    help="files or directories (default: %(default)s)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", metavar="CODES",
                    help="comma-separated rule codes to run (default all)")
    ap.add_argument("--fix", action="store_true",
                    help="apply autofixes for the mechanical rules "
                         "(R003 dtype insertion, R005 options= rewrite)")
    ap.add_argument("--output", metavar="FILE",
                    help="write the report here as well as stdout")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for code, r in sorted(RULES.items()):
            print(f"{code}  {r.name}\n      {r.doc}")
        return 0

    select = None
    if args.select:
        select = {c.strip() for c in args.select.split(",") if c.strip()}
        unknown = select - set(RULES) - {"R000"}
        if unknown:
            print(f"unknown rule code(s): {', '.join(sorted(unknown))}",
                  file=sys.stderr)
            return 2

    files = list(iter_py_files(args.paths))
    if not files:
        print("no python files found", file=sys.stderr)
        return 2

    if args.fix:
        n_fixed = 0
        for f in files:
            n_fixed += fix_file(f, _rel(f))
        print(f"splint --fix: {n_fixed} fix(es) applied "
              f"across {len(files)} file(s)")
        # fall through: report whatever is left after fixing

    diags: list[Diagnostic] = []
    for f in files:
        with open(f, encoding="utf-8") as fh:
            source = fh.read()
        try:
            diags.extend(lint_source(source, _rel(f), select=select))
        except SyntaxError as e:
            diags.append(Diagnostic(_rel(f), e.lineno or 0, 0, "R000",
                                    f"syntax error: {e.msg}"))

    report = (render_json(diags) if args.format == "json"
              else render_text(diags))
    print(report)
    if args.output:
        with open(args.output, "w", encoding="utf-8") as fh:
            fh.write(report + "\n")
    return 1 if diags else 0


if __name__ == "__main__":
    sys.exit(main())

"""The ~8 splint rules: this repo's contracts, as AST checks.

Each rule encodes one invariant the runtime test suite can only probe
pointwise (docs/ANALYSIS.md has the full rationale table):

  R001  ordered reductions only        docs/PARITY.md §1
  R002  no host sync under jit         docs/ARCHITECTURE.md (dispatch)
  R003  explicit dtypes                docs/PARITY.md §1 (f32 contract)
  R004  seeded RNG streams only        flows/synthetic.py convention
  R005  no legacy engine kwargs        EngineOptions (PR 6 deprecation)
  R006  no python branching on tracers ConcretizationError hazard
  R007  no donated-buffer reuse        donate_argnums semantics
  R008  -1 sentinel discipline         docs/PARITY.md §2
  R009  no host timing under jit       docs/OBSERVABILITY.md (R009)

Scoping: every rule skips the LM prototype tree
(``core.EXCLUDED_TREES``); R001 additionally restricts itself to the
parity-critical ``kernels/`` + ``fit/`` modules, and R005 skips the two
files that *implement* the deprecation shim.
"""
from __future__ import annotations

import ast
from typing import Iterator

from tools.splint import callgraph
from tools.splint.core import Diagnostic, Fix, LintContext, rule

# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------


def _attr_chain(node: ast.AST) -> str:
    """Dotted name of an attribute chain ('jnp.sum'), '' if not one."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _walk_own(fn: ast.FunctionDef) -> Iterator[ast.AST]:
    """Walk a function body excluding nested function bodies (nested
    defs are visited on their own when reachable)."""
    stack: list[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            stack.extend(ast.iter_child_nodes(node))


def _diag(ctx: LintContext, node: ast.AST, code: str, msg: str,
          fix: Fix | None = None) -> Diagnostic:
    return Diagnostic(ctx.path, node.lineno, node.col_offset, code, msg,
                      fix=fix)


# ---------------------------------------------------------------------------
# R001 — ordered reductions only in parity-critical modules
# ---------------------------------------------------------------------------

_R001_BANNED = {"jnp.sum", "jnp.dot", "jnp.cumsum", "jnp.matmul"}


@rule("R001", "ordered-reduction",
      "XLA-order reductions are banned in kernels/ and fit/: route f32 "
      "sums through kernels.ref.ordered_wsum / core.tree.class_sq_chain "
      "(docs/PARITY.md §1). Integer (exact) reductions may carry an "
      "allow pragma stating so.",
      applies=lambda ctx: ctx.in_tree("src/repro/kernels/",
                                      "src/repro/fit/"))
def check_r001(ctx: LintContext):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            name = _attr_chain(node.func)
            if name in _R001_BANNED:
                yield _diag(
                    ctx, node, "R001",
                    f"`{name}` lets XLA pick the summation tree; use "
                    "kernels.ref.ordered_wsum / core.tree.class_sq_chain "
                    "for f32 reductions (PARITY.md §1), or suppress with "
                    "a reason if the reduction is integer-exact")


# ---------------------------------------------------------------------------
# R002 — no host sync inside jit-reachable code
# ---------------------------------------------------------------------------

_SHAPE_ATTRS = {"shape", "ndim", "size", "dtype"}
_STATIC_CALLS = {"len", "prod", "round", "min", "max", "range", "int",
                 "float", "bool", "abs", "sum"}


def _static_expr(node: ast.AST, static_names: set) -> bool:
    """Conservatively true when an expression is trace-time static
    (python scalars, shapes, static_argnames)."""
    if isinstance(node, ast.Constant):
        return True
    if isinstance(node, ast.Name):
        return node.id in static_names or node.id.isupper()
    if isinstance(node, ast.Attribute):
        if node.attr in _SHAPE_ATTRS:
            return True
        return _static_expr(node.value, static_names)
    if isinstance(node, ast.Subscript):
        return _static_expr(node.value, static_names)
    if isinstance(node, ast.BinOp):
        return (_static_expr(node.left, static_names)
                and _static_expr(node.right, static_names))
    if isinstance(node, ast.UnaryOp):
        return _static_expr(node.operand, static_names)
    if isinstance(node, ast.Call):
        # only *builtins* and np/math shape helpers are static; a method
        # call (x.sum()) on a traced array never is
        if isinstance(node.func, ast.Name):
            ok = node.func.id in _STATIC_CALLS
        else:
            ok = _attr_chain(node.func) in (
                "np.prod", "math.prod", "math.ceil", "math.floor",
                "np.ceil", "np.floor")
        return ok and all(_static_expr(a, static_names) for a in node.args)
    if isinstance(node, (ast.Tuple, ast.List)):
        return all(_static_expr(e, static_names) for e in node.elts)
    return False


_HOST_SYNC_CALLS = {"np.asarray", "np.array", "numpy.asarray",
                    "numpy.array", "onp.asarray", "jax.device_get"}


@rule("R002", "host-sync-under-jit",
      "Host synchronisation (.item()/.tolist(), float()/int()/bool() on "
      "traced values, np.asarray, jax.device_get) inside a @jax.jit "
      "function or a helper reachable from one forces a device round "
      "trip per call — the O(1)-dispatch bound (kernels/tick_step.py) "
      "dies silently.",
      applies=lambda ctx: True)
def check_r002(ctx: LintContext):
    graph = callgraph.build(ctx.tree)
    static_all = set().union(*graph.static_args.values()) \
        if graph.static_args else set()
    for name in sorted(graph.reachable):
        fn = graph.functions.get(name)
        if fn is None:
            continue
        statics = static_all | graph.static_args.get(name, set())
        for node in _walk_own(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr in ("item", "tolist")
                    and not node.args):
                yield _diag(
                    ctx, node, "R002",
                    f"`.{node.func.attr}()` inside jit-reachable "
                    f"`{name}` blocks on the device; return the array "
                    "and sync once at the caller")
            elif chain in _HOST_SYNC_CALLS and node.args and \
                    not _static_expr(node.args[0], statics):
                yield _diag(
                    ctx, node, "R002",
                    f"`{chain}` on a traced value inside jit-reachable "
                    f"`{name}` is a host transfer; keep the hot path "
                    "device-resident (use jnp ops)")
            elif isinstance(node.func, ast.Name) and \
                    node.func.id in ("float", "int", "bool") and \
                    len(node.args) == 1 and \
                    not _static_expr(node.args[0], statics):
                yield _diag(
                    ctx, node, "R002",
                    f"`{node.func.id}(...)` on a possibly-traced value "
                    f"inside jit-reachable `{name}` concretises (host "
                    "sync or ConcretizationTypeError); use jnp casts, "
                    "or suppress with a reason if the argument is "
                    "static")


# ---------------------------------------------------------------------------
# R003 — explicit dtypes on jnp array constructors
# ---------------------------------------------------------------------------

#: constructor -> index of the positional dtype slot
_R003_CTORS = {"zeros": 1, "ones": 1, "full": 2, "arange": 3}


def _r003_fix(ctx: LintContext, node: ast.Call, ctor: str) -> Fix | None:
    """Mechanical fix: append the dtype jax would infer anyway, so the
    edit is semantics-preserving (x64 disabled, the repo default)."""
    if ctor in ("zeros", "ones"):
        dtype = "jnp.float32"
    elif ctor == "full":
        fill = node.args[1] if len(node.args) > 1 else None
        if isinstance(fill, ast.UnaryOp) and \
                isinstance(fill.op, (ast.USub, ast.UAdd)):
            fill = fill.operand          # -1 parses as USub(Constant(1))
        if not isinstance(fill, ast.Constant):
            return None
        v = fill.value
        dtype = ("jnp.bool_" if isinstance(v, bool) else
                 "jnp.int32" if isinstance(v, int) else
                 "jnp.float32" if isinstance(v, float) else None)
        if dtype is None:
            return None
    else:  # arange
        if not all(isinstance(a, ast.Constant) for a in node.args):
            return None
        dtype = ("jnp.float32" if any(
            isinstance(a.value, float) for a in node.args) else "jnp.int32")
    end_col = node.end_col_offset - 1      # just before the ')'
    return Fix(node.end_lineno, end_col, node.end_lineno, end_col,
               f", dtype={dtype}")


@rule("R003", "explicit-dtype",
      "jnp.zeros/ones/full/arange without a dtype inherit jax's "
      "platform/x64-flag defaults; a silent f32/f64 or i32/i64 drift "
      "breaks the bit-exactness contract (docs/PARITY.md §1). "
      "Autofixable: --fix inserts the dtype jax would infer today.",
      applies=lambda ctx: ctx.in_tree("src/repro/"))
def check_r003(ctx: LintContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain.startswith("jnp."):
            continue
        ctor = chain[4:]
        slot = _R003_CTORS.get(ctor)
        if slot is None:
            continue
        if len(node.args) > slot:
            continue                       # dtype passed positionally
        if any(kw.arg == "dtype" for kw in node.keywords):
            continue
        yield _diag(
            ctx, node, "R003",
            f"`jnp.{ctor}(...)` without an explicit dtype — pin it "
            "(PARITY.md §1: no silent f32/f64 drift)",
            fix=_r003_fix(ctx, node, ctor))


# ---------------------------------------------------------------------------
# R004 — seeded SeedSequence streams only
# ---------------------------------------------------------------------------

_R004_ALLOWED = {"default_rng", "SeedSequence", "Generator", "BitGenerator",
                 "PCG64", "Philox", "SFC64"}


@rule("R004", "seeded-rng-only",
      "Legacy np.random global-state calls make runs irreproducible; "
      "src/repro derives every stream from a seeded "
      "np.random.default_rng(SeedSequence(...)) (flows/synthetic.py is "
      "the convention).",
      applies=lambda ctx: ctx.in_tree("src/repro/"))
def check_r004(ctx: LintContext):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and \
                _attr_chain(node.value) in ("np.random", "numpy.random"):
            if node.attr not in _R004_ALLOWED:
                yield _diag(
                    ctx, node, "R004",
                    f"`np.random.{node.attr}` uses the global RNG state; "
                    "derive a seeded stream via np.random.default_rng("
                    "SeedSequence(...)) as in flows/synthetic.py")
        if isinstance(node, ast.Call) and \
                _attr_chain(node.func).endswith("random.default_rng") and \
                not node.args and not node.keywords:
            yield _diag(
                ctx, node, "R004",
                "`default_rng()` with no seed is OS-entropy seeded "
                "(irreproducible); pass a seed or SeedSequence")


# ---------------------------------------------------------------------------
# R005 — no legacy engine kwargs outside the shim
# ---------------------------------------------------------------------------

_SHIM_FILES = ("src/repro/core/inference.py", "src/repro/serve/streaming.py")
_LEGACY_KWARGS = {"impl", "compact", "micro_batch", "inflight", "donate",
                  "mesh"}
_ENGINE_ENTRY_POINTS = {"run", "run_streaming", "run_looped",
                        "stream_batches"}


def _r005_fix(ctx: LintContext, node: ast.Call,
              legacy: list[ast.keyword]) -> Fix | None:
    if any(kw.arg in (None, "options") for kw in node.keywords):
        # options= already present (the shim raises on mixing) or a
        # **kwargs splat that may itself carry legacy keys: hand-fix
        return None
    func = ctx.segment(node.func)
    if not func:
        return None
    parts = [ctx.segment(a) for a in node.args]
    for kw in node.keywords:
        if kw in legacy:
            continue
        parts.append(f"**{ctx.segment(kw.value)}" if kw.arg is None
                     else f"{kw.arg}={ctx.segment(kw.value)}")
    opts = ", ".join(f"{kw.arg}={ctx.segment(kw.value)}" for kw in legacy)
    parts.append(f"options=EngineOptions({opts})")
    return Fix(node.lineno, node.col_offset, node.end_lineno,
               node.end_col_offset, f"{func}({', '.join(parts)})")


@rule("R005", "no-legacy-engine-kwargs",
      "Engine.run/run_streaming/run_looped/stream_batches legacy "
      "keywords (impl=/compact=/micro_batch=/inflight=/donate=/mesh=) "
      "are a deprecation shim; new call sites pass "
      "options=EngineOptions(...). Autofixable with --fix.",
      applies=lambda ctx: ctx.path not in _SHIM_FILES)
def check_r005(ctx: LintContext):
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        callee = f.attr if isinstance(f, ast.Attribute) else (
            f.id if isinstance(f, ast.Name) else "")
        if callee not in _ENGINE_ENTRY_POINTS:
            continue
        legacy = [kw for kw in node.keywords if kw.arg in _LEGACY_KWARGS]
        if not legacy:
            continue
        names = ", ".join(sorted(kw.arg for kw in legacy))
        yield _diag(
            ctx, node, "R005",
            f"legacy engine kwarg(s) {names} on `.{callee}(...)` — pass "
            "options=EngineOptions(...) (the kwargs warn "
            "DeprecationWarning and will be removed)",
            fix=_r005_fix(ctx, node, legacy))


# ---------------------------------------------------------------------------
# R006 — no python branching on tracer values
# ---------------------------------------------------------------------------

def _contains_jnp_call(node: ast.AST) -> str | None:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            chain = _attr_chain(sub.func)
            if chain.startswith(("jnp.", "jax.")):
                return chain
    return None


@rule("R006", "no-tracer-branch",
      "`if`/`while` on a jnp expression inside jit-reachable code either "
      "raises ConcretizationTypeError or (via static fallback) "
      "recompiles per distinct value; use lax.cond/lax.select/jnp.where "
      "(docs/ARCHITECTURE.md backend contract).",
      applies=lambda ctx: True)
def check_r006(ctx: LintContext):
    graph = callgraph.build(ctx.tree)
    for name in sorted(graph.reachable):
        fn = graph.functions.get(name)
        if fn is None:
            continue
        for node in _walk_own(fn):
            if isinstance(node, (ast.If, ast.While)):
                chain = _contains_jnp_call(node.test)
                if chain:
                    kind = "if" if isinstance(node, ast.If) else "while"
                    yield _diag(
                        ctx, node, "R006",
                        f"python `{kind}` on `{chain}(...)` inside "
                        f"jit-reachable `{name}` branches on a tracer; "
                        "use jax.lax.cond / jnp.where (or "
                        "lax.while_loop for loops)")


# ---------------------------------------------------------------------------
# R007 — donated buffers must not be reused after the donating call
# ---------------------------------------------------------------------------

def _stored_names(node: ast.AST) -> set[str]:
    return {n.id for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)}


def _loaded_names(node: ast.AST) -> list[ast.Name]:
    return [n for n in ast.walk(node)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)]


@rule("R007", "donated-buffer-reuse",
      "An argument at a donate_argnums position is deleted by the "
      "donating call; reading the same name afterwards returns a "
      "deleted-buffer error (or stale data under some backends). "
      "Rebind the result instead.",
      applies=lambda ctx: True)
def check_r007(ctx: LintContext):
    graph = callgraph.build(ctx.tree)
    if not graph.donated:
        return
    bodies: list[list[ast.stmt]] = [ctx.tree.body]
    for node in ast.walk(ctx.tree):
        for field in ("body", "orelse", "finalbody"):
            sub = getattr(node, field, None)
            if isinstance(sub, list) and sub and \
                    isinstance(sub[0], ast.stmt) and sub is not ctx.tree.body:
                bodies.append(sub)
    for body in bodies:
        for i, stmt in enumerate(body):
            donated_here: dict[str, str] = {}      # var -> jitted fn name
            for call in ast.walk(stmt):
                if not isinstance(call, ast.Call) or \
                        not isinstance(call.func, ast.Name):
                    continue
                idxs = graph.donated.get(call.func.id)
                if not idxs:
                    continue
                for idx in idxs:
                    if idx < len(call.args) and \
                            isinstance(call.args[idx], ast.Name):
                        donated_here[call.args[idx].id] = call.func.id
            for var in _stored_names(stmt):
                donated_here.pop(var, None)        # x = f(x): rebound
            if not donated_here:
                continue
            for later in body[i + 1:]:
                if not donated_here:
                    break
                for load in _loaded_names(later):
                    fn_name = donated_here.get(load.id)
                    if fn_name:
                        yield Diagnostic(
                            ctx.path, load.lineno, load.col_offset, "R007",
                            f"`{load.id}` was donated to `{fn_name}` "
                            "(donate_argnums) and its buffer is gone; "
                            "use the call's result, or drop the "
                            "donation")
                for var in _stored_names(later):
                    donated_here.pop(var, None)


# ---------------------------------------------------------------------------
# R008 — -1 sentinel discipline for verdict-bearing arrays
# ---------------------------------------------------------------------------

_SENTINEL_NAMES = ("label", "verdict", "exit_part")


def _sentinel_name(name: str) -> bool:
    low = name.lower()
    return any(s in low for s in _SENTINEL_NAMES)


def _is_zero_fill(value: ast.AST) -> str | None:
    """'' for zeros(), 'full'/'where' when the fill/else value is 0."""
    if not isinstance(value, ast.Call):
        return None
    chain = _attr_chain(value.func)
    base = chain.rsplit(".", 1)[-1]
    if base == "zeros" and chain.split(".")[0] in ("jnp", "np", "numpy"):
        return "zeros"
    if base == "full" and len(value.args) > 1 and \
            isinstance(value.args[1], ast.Constant) and value.args[1].value == 0:
        return "full"
    if base == "where" and len(value.args) == 3 and \
            isinstance(value.args[2], ast.Constant) and value.args[2].value == 0:
        return "where"
    return None


@rule("R008", "sentinel-discipline",
      "Arrays carrying flow verdicts (labels / exit_partition) must "
      "initialise and fall back to the -1 sentinel, never 0 — a 0 "
      "fallback silently claims class 0 at partition 0 "
      "(docs/PARITY.md §2).",
      applies=lambda ctx: ctx.in_tree("src/repro/"))
def check_r008(ctx: LintContext):
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign):
            targets = [t.id for t in node.targets
                       if isinstance(t, ast.Name) and _sentinel_name(t.id)]
            kind = _is_zero_fill(node.value)
            if targets and kind:
                yield _diag(
                    ctx, node.value, "R008",
                    f"`{targets[0]}` initialised by `{kind}` to 0 — "
                    "verdict arrays start at the -1 sentinel "
                    "(PARITY.md §2); a 0 default silently claims "
                    "class 0")
        elif isinstance(node, ast.keyword) and node.arg and \
                _sentinel_name(node.arg) and \
                isinstance(node.value, ast.Constant) and node.value.value == 0:
            yield _diag(
                ctx, node.value, "R008",
                f"`{node.arg}=0` — verdict fields use the -1 sentinel "
                "for 'no verdict' (PARITY.md §2)")


# ---------------------------------------------------------------------------
# R009 — no host timers / obs spans inside jit-reachable code
# ---------------------------------------------------------------------------

_R009_TIMERS = {"time.time", "time.perf_counter", "time.perf_counter_ns",
                "time.monotonic", "time.monotonic_ns", "time.process_time",
                "perf_counter", "monotonic"}
_R009_SPANS = {"span", "obs.span", "trace.span", "obs.trace.span",
               "repro.obs.span"}


@rule("R009", "no-host-timing-under-jit",
      "time.time/time.perf_counter and repro.obs span() entries inside "
      "a @jax.jit function (or a helper reachable from one) run ONCE at "
      "trace time, not per call — the 'timing' silently measures "
      "tracing, and the span brackets nothing. Time and annotate at the "
      "dispatch site on the host (docs/OBSERVABILITY.md).",
      applies=lambda ctx: True)
def check_r009(ctx: LintContext):
    graph = callgraph.build(ctx.tree)
    for name in sorted(graph.reachable):
        fn = graph.functions.get(name)
        if fn is None:
            continue
        for node in _walk_own(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain in _R009_TIMERS:
                yield _diag(
                    ctx, node, "R009",
                    f"`{chain}()` inside jit-reachable `{name}` reads "
                    "the host clock at TRACE time — it times tracing "
                    "once, not execution; hoist the timing to the "
                    "dispatch call site")
            elif chain in _R009_SPANS:
                yield _diag(
                    ctx, node, "R009",
                    f"obs span `{chain}(...)` inside jit-reachable "
                    f"`{name}` brackets trace time, not device "
                    "execution; open the span around the jitted CALL "
                    "instead (jax.named_scope is the in-trace marker)")

"""Lightweight per-module jit call-graph for the R002/R006/R007 rules.

"Lightweight" is deliberate: resolution is by bare function name within
one module (including nested and method defs), which is exactly how the
repro codebase is written — jit roots and their helpers live together
(``kernels/tick_step.py``, ``serve/flowtable.py``, ...).  Cross-module
helpers are out of scope; the contract rules catch the overwhelmingly
common failure (a host sync added to a helper three calls below a
``@jax.jit``) without a whole-program analysis.
"""
from __future__ import annotations

import ast
import dataclasses

__all__ = ["JitGraph", "build"]

_JIT_NAMES = {"jit"}          # bare `@jit` (from jax import jit)


def _is_jax_jit(node: ast.AST) -> bool:
    """`jax.jit` / `jit` / `jax.pjit` as an expression."""
    if isinstance(node, ast.Attribute):
        return node.attr in ("jit", "pjit") and isinstance(
            node.value, ast.Name) and node.value.id == "jax"
    return isinstance(node, ast.Name) and node.id in _JIT_NAMES


def _is_partial(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute):
        return node.attr == "partial"
    return isinstance(node, ast.Name) and node.id == "partial"


def _jit_call_info(call: ast.Call) -> dict | None:
    """If ``call`` is ``jax.jit(...)`` or ``partial(jax.jit, ...)``,
    return its keyword map (static_argnames/static_argnums/
    donate_argnums as literal values where possible), else None."""
    if _is_jax_jit(call.func):
        args = call.args
    elif _is_partial(call.func) and call.args and _is_jax_jit(call.args[0]):
        args = call.args[1:]
    else:
        return None
    info: dict = {"wrapped": None, "static": set(), "donate": ()}
    if args and isinstance(args[0], ast.Name):
        info["wrapped"] = args[0].id
    for kw in call.keywords:
        try:
            val = ast.literal_eval(kw.value)
        except (ValueError, SyntaxError):
            continue
        if kw.arg == "static_argnames":
            val = (val,) if isinstance(val, str) else val
            info["static"] |= set(val)
        elif kw.arg == "static_argnums":
            info["static_nums"] = tuple(val) if isinstance(
                val, (tuple, list)) else (val,)
        elif kw.arg == "donate_argnums":
            info["donate"] = tuple(val) if isinstance(
                val, (tuple, list)) else (val,)
    return info


@dataclasses.dataclass
class JitGraph:
    #: every def in the module by bare name (nested + methods included)
    functions: dict[str, ast.FunctionDef]
    #: names of defs that are jit entry points
    roots: set[str]
    #: per-root statically-known argument names (static_argnames)
    static_args: dict[str, set[str]]
    #: names bound to `jax.jit(fn, donate_argnums=(...))` -> donated idx
    donated: dict[str, tuple[int, ...]]
    #: roots ∪ every def reachable from a root by bare-name calls
    reachable: set[str]

    def is_traced_scope(self, fn: ast.FunctionDef) -> bool:
        return fn.name in self.reachable


def _called_names(fn: ast.FunctionDef) -> set[str]:
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name):
                out.add(f.id)
            elif isinstance(f, ast.Attribute):
                out.add(f.attr)      # self.helper(...) / mod.helper(...)
    return out


def build(tree: ast.Module) -> JitGraph:
    functions: dict[str, ast.FunctionDef] = {}
    roots: set[str] = set()
    static_args: dict[str, set[str]] = {}
    donated: dict[str, tuple[int, ...]] = {}

    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            functions.setdefault(node.name, node)
            for dec in node.decorator_list:
                if _is_jax_jit(dec):
                    roots.add(node.name)
                elif isinstance(dec, ast.Call):
                    info = _jit_call_info(dec)
                    if info is not None:
                        roots.add(node.name)
                        static_args[node.name] = info["static"]

    # `x = jax.jit(fn, ...)` / bare `jax.jit(fn)` expressions: `fn`
    # becomes a root; donate_argnums recorded under the bound name `x`.
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        info = _jit_call_info(node)
        if info is None or _is_partial(node.func):
            continue
        if info["wrapped"]:
            roots.add(info["wrapped"])
            static_args[info["wrapped"]] = info["static"]
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            info = _jit_call_info(node.value)
            if info and info["donate"]:
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        donated[tgt.id] = info["donate"]

    reachable = set(roots)
    frontier = list(roots)
    while frontier:
        name = frontier.pop()
        fn = functions.get(name)
        if fn is None:
            continue
        for callee in _called_names(fn):
            if callee in functions and callee not in reachable:
                reachable.add(callee)
                frontier.append(callee)
    return JitGraph(functions, roots, static_args, donated, reachable)

"""Autofixes for the mechanical rules (``python -m tools.splint --fix``).

Only diagnostics that carry a :class:`tools.splint.core.Fix` are
rewritten — today that is R003 (insert the dtype jax would infer, so
the edit is semantics-preserving) and R005 (fold legacy engine kwargs
into ``options=EngineOptions(...)``).  Fixes are applied bottom-up by
absolute offset so earlier edits never shift later spans, overlapping
fixes are skipped, and the whole pass is idempotent: a fixed file
re-lints clean for the fixable rules, so ``fix(fix(src)) == fix(src)``
(pinned by ``tests/test_splint.py``).
"""
from __future__ import annotations

import ast

from tools.splint.core import lint_source

__all__ = ["fix_source", "fix_file"]

_EO_IMPORT = "from repro.core.inference import EngineOptions\n"


def _line_offsets(source: str) -> list[int]:
    offsets = [0]
    for line in source.splitlines(keepends=True):
        offsets.append(offsets[-1] + len(line))
    return offsets


def _binds_engine_options(source: str) -> bool:
    try:
        tree = ast.parse(source)
    except SyntaxError:
        return True          # don't touch imports we can't parse
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            if any(a.name == "EngineOptions" or a.asname == "EngineOptions"
                   for a in node.names):
                return True
        elif isinstance(node, (ast.ClassDef, ast.Assign)):
            names = [node.name] if isinstance(node, ast.ClassDef) else [
                t.id for t in node.targets if isinstance(t, ast.Name)]
            if "EngineOptions" in names:
                return True
    return False


def _add_engine_options_import(source: str) -> str:
    """Insert the EngineOptions import after the last top-level import."""
    tree = ast.parse(source)
    last_import_line = 0
    for node in tree.body:
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            last_import_line = max(last_import_line,
                                   node.end_lineno or node.lineno)
    lines = source.splitlines(keepends=True)
    return "".join(lines[:last_import_line] + [_EO_IMPORT]
                   + lines[last_import_line:])


def fix_source(source: str, path: str) -> tuple[str, int]:
    """Apply every available fix once; returns (new_source, n_applied)."""
    diags = lint_source(source, path)
    fixes = [d for d in diags if d.fix is not None]
    if not fixes:
        return source, 0
    offs = _line_offsets(source)
    spans = []
    for d in fixes:
        f = d.fix
        spans.append((offs[f.line - 1] + f.col_start,
                      offs[f.end_line - 1] + f.col_end, f.replacement, d))
    spans.sort(key=lambda s: (s[0], s[1]))
    # drop overlaps (keep the earlier span)
    kept, last_end = [], -1
    for start, end, rep, d in spans:
        if start >= last_end:
            kept.append((start, end, rep, d))
            last_end = end
    out = source
    for start, end, rep, _d in reversed(kept):
        out = out[:start] + rep + out[end:]
    if any(d.code == "R005" for *_x, d in kept) and \
            not _binds_engine_options(source):
        out = _add_engine_options_import(out)
    return out, len(kept)


def fix_file(path: str, rel_path: str) -> int:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    fixed, n = fix_source(source, rel_path)
    if n and fixed != source:
        with open(path, "w", encoding="utf-8") as f:
            f.write(fixed)
    return n

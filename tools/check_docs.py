#!/usr/bin/env python
"""Keep the prose honest: check docs links and repo paths.

    python tools/check_docs.py [files...]

Two checks over the repo's markdown (default: README.md, ROADMAP.md,
docs/*.md):

  1. every relative markdown link ``[text](target)`` resolves to a file
     or directory in the repo (http(s) links and #anchors are skipped);
  2. every backticked repo path (``src/...``, ``tests/...``,
     ``docs/...``, ``benchmarks/...``, ``examples/...``, ``tools/...``)
     exists — so renaming a module without updating the docs fails CI.

Doctests embedded in the docs are NOT run here — CI runs them
separately via ``python -m doctest docs/*.md`` (doctest.testfile treats
the markdown as text and picks up the ``>>>`` examples).

Exit status: 0 clean, 1 with a report of every broken reference.
"""
from __future__ import annotations

import glob
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DEFAULT_FILES = ["README.md", "ROADMAP.md"] + sorted(
    glob.glob(os.path.join(REPO, "docs", "*.md")))

MD_LINK = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
# backticked repo-relative paths: `src/repro/core/inference.py`,
# `tests/`, `benchmarks/bench_engine.py`, `docs/PARITY.md`, ...
TICK_PATH = re.compile(
    r"`((?:src|tests|docs|benchmarks|examples|tools)/[\w./\-]*)`")


def check_file(path: str) -> list[str]:
    errors: list[str] = []
    base = os.path.dirname(path)
    with open(path, encoding="utf-8") as f:
        lines = f.read().splitlines()
    for ln, line in enumerate(lines, 1):
        for target in MD_LINK.findall(line):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = os.path.normpath(os.path.join(base, rel))
            if not os.path.exists(resolved):
                errors.append(f"{os.path.relpath(path, REPO)}:{ln}: "
                              f"broken link -> {target}")
        for p in TICK_PATH.findall(line):
            resolved = os.path.join(REPO, p.rstrip("/"))
            if not os.path.exists(resolved):
                errors.append(f"{os.path.relpath(path, REPO)}:{ln}: "
                              f"missing repo path -> {p}")
    return errors


def main(argv: list[str]) -> int:
    files = [os.path.join(REPO, f) if not os.path.isabs(f) else f
             for f in (argv or DEFAULT_FILES)]
    errors: list[str] = []
    for f in files:
        if not os.path.exists(f):
            errors.append(f"no such file: {f}")
            continue
        errors.extend(check_file(f))
    if errors:
        print("\n".join(errors))
        print(f"check_docs: {len(errors)} broken reference(s)")
        return 1
    print(f"check_docs: {len(files)} file(s) clean")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

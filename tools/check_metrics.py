#!/usr/bin/env python
"""Schema-check the observability artifacts the benches emit.

    python tools/check_metrics.py METRICS_serve.json [--kind serve]
    python tools/check_metrics.py METRICS_engine.json --kind engine

``benchmarks/bench_serve.py`` writes ``METRICS_serve.json`` (one
``MetricRegistry.snapshot()`` per timed grid cell) and
``benchmarks/bench_engine.py`` writes ``METRICS_engine.json`` (one
registry for the whole run).  CI uploads both; this check fails the
bench-smoke job when a required metric goes missing — i.e. when
someone unhooks the instrumentation the paper's evaluation numbers
(TTD, recirc overhead, dispatch counts) are derived from.  The metric
catalogue lives in ``docs/OBSERVABILITY.md``.

Required per serve cell:
  * histogram ``serve_ttd_seconds`` with a non-zero sample total,
  * gauge ``serve_recirc_overhead``,
  * counters ``serve_dispatches_total`` and ``serve_packets_total``,
    both non-zero.

Required for the engine registry: at least one
``engine_dispatches_total{backend=...}`` counter with a non-zero
value, and at least one ``engine_hop_survivors_total{hop=...}``.

Exit status: 0 clean, 1 with a report of every violation.
"""
from __future__ import annotations

import argparse
import json
import sys

SERVE_COUNTERS = ("serve_dispatches_total", "serve_packets_total")


def _names(section: dict) -> set[str]:
    """Metric names with any label suffix stripped."""
    return {k.split("{", 1)[0] for k in section}


def check_serve_cell(name: str, snap: dict) -> list[str]:
    errors = []
    hists = snap.get("histograms", {})
    ttd = hists.get("serve_ttd_seconds")
    if ttd is None:
        errors.append(f"{name}: missing histogram serve_ttd_seconds")
    elif ttd.get("total", 0) <= 0:
        errors.append(f"{name}: serve_ttd_seconds recorded no samples")
    if "serve_recirc_overhead" not in _names(snap.get("gauges", {})):
        errors.append(f"{name}: missing gauge serve_recirc_overhead")
    counters = snap.get("counters", {})
    for c in SERVE_COUNTERS:
        if c not in counters:
            errors.append(f"{name}: missing counter {c}")
        elif counters[c].get("value", 0) <= 0:
            errors.append(f"{name}: counter {c} is zero")
    return errors


def check_serve(payload: dict) -> list[str]:
    cells = payload.get("cells", {})
    if not cells:
        return ["no cells in serve metrics payload"]
    errors = []
    for name, snap in sorted(cells.items()):
        errors.extend(check_serve_cell(name, snap))
    return errors


def check_engine(payload: dict) -> list[str]:
    reg = payload.get("registry", {})
    counters = reg.get("counters", {})
    errors = []
    disp = {k: v for k, v in counters.items()
            if k.startswith("engine_dispatches_total")}
    if not disp:
        errors.append("no engine_dispatches_total counters")
    elif not any(v.get("value", 0) > 0 for v in disp.values()):
        errors.append("every engine_dispatches_total counter is zero")
    if not any(k.startswith("engine_hop_survivors_total")
               for k in counters):
        errors.append("no engine_hop_survivors_total counters")
    return errors


def main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="metrics JSON artifact to check")
    ap.add_argument("--kind", choices=("serve", "engine"), default=None,
                    help="artifact flavour (default: the payload's "
                         "'bench' field)")
    args = ap.parse_args(argv)
    try:
        with open(args.path, encoding="utf-8") as f:
            payload = json.load(f)
    except (OSError, ValueError) as e:
        print(f"check_metrics: cannot read {args.path}: {e}")
        return 1
    kind = args.kind or payload.get("bench")
    if kind == "serve":
        errors = check_serve(payload)
    elif kind == "engine":
        errors = check_engine(payload)
    else:
        errors = [f"unknown artifact kind {kind!r} (pass --kind)"]
    if errors:
        print("\n".join(errors))
        print(f"check_metrics: {args.path}: {len(errors)} violation(s)")
        return 1
    n = len(payload.get("cells", {})) or 1
    print(f"check_metrics: {args.path}: {kind} artifact clean ({n} cell(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

"""Watch the serving stack run: metrics scrape + span tree + audit.

    PYTHONPATH=src python examples/observe_serving.py [--smoke]

Trains a small SpliDT model, serves a synthetic packet stream through
:class:`repro.serve.FlowTableServer`, and then shows every face of the
observability stack (``docs/OBSERVABILITY.md``):

1. a **Prometheus scrape** — the reporter exposes the server's
   ``MetricRegistry`` over ``http.server`` and we curl ourselves;
2. the **span tree** — where the wall-clock went inside each ingest
   tick (admit / pack / dispatch / fetch / spill);
3. the **audit**: the live ``serve_recirc_overhead`` gauge is
   recomputed offline from the raw :class:`StreamVerdicts` the server
   returned — the two must agree exactly, which is what makes the
   paper's <0.05% recirculation-overhead claim checkable from a
   running server rather than a post-hoc script.

``--smoke`` shrinks everything for CI.
"""
import argparse
import urllib.request

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes (CI)")
    ap.add_argument("--flows", type=int, default=2000)
    ap.add_argument("--ticks", type=int, default=257)
    args = ap.parse_args()
    if args.smoke:
        args.flows, args.ticks = 300, 61

    from repro import obs
    from repro.core.inference import Engine
    from repro.core.partition import train_partitioned_dt
    from repro.flows.synthetic import make_dataset, make_packet_stream
    from repro.flows.windows import window_features
    from repro.serve import FlowTableServer, StreamVerdicts

    print("=== SpliDT serving observability ===")
    obs.set_enabled(True)
    obs.reset_spans()

    ds = make_dataset("d2", n_flows=args.flows)
    tr, _ = ds.split()
    Xw = window_features(tr, 3)
    pdt = train_partitioned_dt(Xw, tr.labels, partition_sizes=[2, 3, 2], k=4)
    eng = Engine.from_model(pdt)

    srv = FlowTableServer(eng, n_buckets=32, bucket_size=8)
    stream = make_packet_stream(tr, seed=11, profile="steady")
    parts = [srv.ingest(b) for b in stream.ticks(args.ticks)]
    parts.append(srv.flush())
    verdicts = StreamVerdicts.concat(parts)
    print(f"served {srv.stats.packets} packets -> "
          f"{verdicts.n_flows} verdicts in {srv.stats.ticks} ticks "
          f"({srv.stats.dispatches} device dispatches)")

    # -- 1. Prometheus scrape over HTTP ---------------------------------
    rep = obs.MetricsReporter(None, registry=srv.registry, http_port=0)
    try:
        url = f"http://127.0.0.1:{rep.http_port}/metrics"
        body = urllib.request.urlopen(url, timeout=10).read().decode()
    finally:
        rep.close()
    print(f"\n--- scrape of {url} (serve_* lines) ---")
    for line in body.splitlines():
        if line.startswith(("serve_", "# TYPE serve_")) \
                and "_bucket" not in line:
            print(" ", line)

    # -- 2. where the time went: the span tree --------------------------
    print("\n--- span tree (host wall-clock per ingest stage) ---")
    print(obs.span_tree())

    # -- 3. audit: live gauge == offline recompute from raw verdicts ----
    recircs = int(np.asarray(verdicts.recircs, np.int64).sum())
    offline = recircs / srv.stats.packets
    live = srv.registry.gauge("serve_recirc_overhead").value
    print("\n--- recirc-overhead audit ---")
    print(f"  offline: {recircs} recircs / {srv.stats.packets} packets "
          f"= {offline:.6f}")
    print(f"  live gauge serve_recirc_overhead = {live:.6f}")
    if live != offline:
        print("MISMATCH: live metrics drifted from the raw verdicts")
        return 1
    ttd = srv.registry.histogram(
        "serve_ttd_seconds", edges=obs.exp_edges(1e-3, 1e4, 15))
    print(f"  TTD: p50 <= {ttd.quantile(0.5):.4g}s, "
          f"p99 <= {ttd.quantile(0.99):.4g}s over {ttd.total} verdicts")
    print("\nlive metrics match the offline recompute — audit clean")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

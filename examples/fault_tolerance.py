"""Fault-tolerance demo: training with simulated hard failures, async
checkpointing, exactly-once recovery, and straggler detection.

    PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data.tokens import TokenPipeline
from repro.distributed import pspec
from repro.models import model_zoo
from repro.train.elastic import StepWatchdog, run_with_recovery
from repro.train.optimizer import AdamW
from repro.train.train_step import make_train_step


def main():
    cfg = get_arch("granite-3-2b").reduced()
    zoo = model_zoo.get_model(cfg)
    params = pspec.init_params(zoo.param_defs(cfg), jax.random.key(0))
    opt = AdamW(lr=3e-3)
    state = opt.init(params)
    raw = make_train_step(cfg, opt)
    jit_step = jax.jit(lambda s, b: raw(s, b, None)[:2])

    pipe = TokenPipeline(cfg.vocab, batch=4, seq=32)
    batches = [
        {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        for i in range(24)
    ]
    root = tempfile.mkdtemp(prefix="ft_demo_")
    wd = StepWatchdog(on_straggler=lambda s, dt, ema: print(
        f"  [watchdog] straggler at step {s}: {dt:.2f}s vs ema {ema:.2f}s"))
    print("training 24 steps with failures injected after steps 9 and 17…")
    state, rep = run_with_recovery(
        jit_step, state, batches, ckpt_root=root, ckpt_every=4,
        fail_at={9, 17}, watchdog=wd)
    print(f"failures={rep.failures} restores={rep.restores} "
          f"steps_run={rep.steps_run} (includes replay) "
          f"final_step={rep.final_step}")
    assert rep.final_step == 24 and rep.restores == 2
    print("ACCEPTANCE: recovered to exactly step 24 through 2 failures OK")
    shutil.rmtree(root, ignore_errors=True)


if __name__ == "__main__":
    main()

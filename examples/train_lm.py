"""End-to-end training driver: a ~100M-parameter tinyllama-family model
trained for a few hundred steps on the synthetic Markov stream, with
async checkpointing and the step watchdog.

    PYTHONPATH=src python examples/train_lm.py           # ~100M, 200 steps
    PYTHONPATH=src python examples/train_lm.py --quick   # tiny, 40 steps

Acceptance: final loss well below the uniform floor log(vocab), i.e. the
model learned the Markov structure end-to-end through the full stack
(data pipeline -> sharded step -> AdamW -> checkpointing).
"""
import argparse
import sys

import numpy as np

from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.quick:
        argv = ["--arch", "tinyllama-1.1b", "--reduced",
                "--steps", str(args.steps or 40), "--batch", "8",
                "--seq", "64", "--lr", "1e-2", "--ckpt-dir", "/tmp/lm_ckpt"]
    else:
        # ~100M params: d_model 640, 12 layers, vocab 32000
        argv = ["--arch", "tinyllama-1.1b", "--d-model", "640",
                "--layers", "12", "--steps", str(args.steps or 200),
                "--batch", "4", "--seq", "256", "--lr", "3e-3",
                "--ckpt-dir", "/tmp/lm_ckpt", "--microbatches", "2"]
    losses = train_launch.main(argv)
    floor = np.log(256 if args.quick else 32000)
    final = float(np.mean(losses[-10:]))
    print(f"ACCEPTANCE: final loss {final:.3f} vs uniform floor "
          f"{floor:.3f}: {'OK' if final < floor else 'needs more steps'}")


if __name__ == "__main__":
    main()

"""Quickstart: the complete SpliDT pipeline in one script.

    PYTHONPATH=src python examples/quickstart.py

Synthetic flows -> windowed features -> Algorithm-1 partitioned training
-> range-marking rules -> data-plane engine inference (Pallas kernels in
interpret mode) -> resource + recirculation reports.
"""
import numpy as np

from repro.core.inference import Engine
from repro.core.partition import train_partitioned_dt
from repro.core.recirc import HADOOP, WEBSERVER, recirc_bandwidth
from repro.core.resources import estimate
from repro.core.tree import macro_f1
from repro.flows.synthetic import make_dataset
from repro.flows.windows import window_features, window_packets


def main():
    print("=== SpliDT quickstart ===")
    ds = make_dataset("d2", n_flows=3000)
    train, test = ds.split()
    P, K = 3, 4
    print(f"dataset: {ds.name}, {ds.n_flows} flows, {ds.n_classes} classes; "
          f"partitions={P}, k={K} feature registers/flow")

    Xw = window_features(train, P)
    pdt = train_partitioned_dt(Xw, train.labels,
                               partition_sizes=[3, 3, 3], k=K)
    per_part, per_sub = pdt.feature_density()
    print(f"trained {len(pdt.subtrees)} subtrees, total depth "
          f"{pdt.total_depth}; unique features "
          f"{len(pdt.unique_features())} (vs k={K} registers); "
          f"density/subtree {per_sub:.1f}%")

    # data-plane engine (feature_window + dt_traverse kernels)
    wp = window_packets(test, P)
    res = Engine.from_model(pdt, impl="ref").run(wp)
    f1 = macro_f1(test.labels, res.labels, ds.n_classes)
    print(f"engine F1 = {f1:.3f}; mean recirculations/flow = "
          f"{res.recircs.mean():.2f}")

    rep = estimate(pdt, flows=500_000)
    print(f"resources: {rep.tcam_entries} TCAM entries "
          f"({rep.tcam_bits / 1e6:.2f} Mb), "
          f"{rep.register_bits_per_flow} register bits/flow, "
          f"capacity {rep.flow_capacity:,} flows, "
          f"feasible@500K={rep.feasible}")
    for env in (WEBSERVER, HADOOP):
        bw = recirc_bandwidth(res.recircs, 1_000_000, env)
        print(f"recirculation @1M flows [{env.name}]: "
              f"{bw.mean_mbps:.1f} Mbps "
              f"({bw.fraction_of_budget * 100:.4f}% of the 100G path)")


if __name__ == "__main__":
    main()

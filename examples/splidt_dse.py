"""Design-space exploration example (paper §3.2.1, Fig. 5-7):
Bayesian-optimisation search over (k, partition sizes) producing the
F1-vs-flows Pareto frontier for a flow target.

    PYTHONPATH=src python examples/splidt_dse.py [--iterations 10]
"""
import argparse

from repro.core.dse import SearchSpace, bayes_search, make_splidt_evaluator
from repro.flows.synthetic import make_dataset
from repro.flows.windows import window_features


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="d1")
    ap.add_argument("--flows", type=int, default=500_000)
    ap.add_argument("--iterations", type=int, default=8)
    args = ap.parse_args()

    ds = make_dataset(args.dataset, n_flows=3000)
    tr, te = ds.split()
    P = 5
    Xw_tr, Xw_te = window_features(tr, P), window_features(te, P)
    ev = make_splidt_evaluator(Xw_tr, tr.labels, Xw_te, te.labels,
                               n_classes=ds.n_classes, flows=args.flows)
    res = bayes_search(
        ev, SearchSpace(max_partitions=P, k_max=6, depth_max=8),
        n_iterations=args.iterations, batch=4, n_init=8, seed=0)

    print(f"\n=== BO search on {args.dataset} @ {args.flows:,} flows "
          f"({len(res.history)} evaluations) ===")
    print(f"best feasible: F1={res.best.f1:.3f} cfg={res.best.config} "
          f"(found at evaluation {res.iterations_to_best})")
    print("\nPareto frontier (F1 vs flow capacity):")
    for e in res.pareto():
        print(f"  F1={e.f1:.3f} capacity={e.flow_capacity:>9,} "
              f"k={e.config.k} partitions={e.config.partition_sizes} "
              f"feats={e.unique_features} tcam={e.tcam_entries} "
              f"recirc={e.recirc_mbps:.1f}Mbps")


if __name__ == "__main__":
    main()

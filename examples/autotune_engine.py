"""Autotune the engine for your model + batch shape, then verify parity.

    PYTHONPATH=src python examples/autotune_engine.py [--smoke]

Builds a synthetic SpliDT model, asks the router for its analytical
pick (``impl="auto"``, cost model — no timing), then runs the real
tuner (``EngineOptions(impl="tuned")``): candidate plans are
shortlisted by the cost
model, timed on the actual windows, and the winner is cached per
(shape, device fingerprint), so re-running this script resolves the
plan with a dict lookup.  Finally the tuned route is cross-checked
bit-for-bit against ``impl="fused"`` — routing may change speed, never
verdicts (docs/PARITY.md).

``--smoke`` shrinks everything for CI (and points the cache at a temp
file so CI runs do not touch ``~/.cache``).
"""
import argparse
import os
import sys
import tempfile
import time

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes + temp cache (CI)")
    ap.add_argument("--flows", type=int, default=2000)
    ap.add_argument("--batch", type=int, default=4096)
    args = ap.parse_args()
    if args.smoke:
        args.flows, args.batch = 400, 256
        os.environ["SPLIDT_AUTOTUNE_CACHE"] = os.path.join(
            tempfile.mkdtemp(prefix="splidt-tune-"), "autotune.json")

    from repro.core.inference import Engine, EngineOptions
    from repro.core.partition import train_partitioned_dt
    from repro.flows.synthetic import make_dataset
    from repro.flows.windows import window_features, window_packets
    from repro.tuning import ShapeInfo, choose_plan, estimate_us, Plan
    from repro.tuning.autotune import cache_path

    print("=== SpliDT engine autotuning ===")
    ds = make_dataset("d2", n_flows=args.flows)
    tr, te = ds.split()
    P, K = 3, 4
    Xw = window_features(tr, P)
    pdt = train_partitioned_dt(Xw, tr.labels, partition_sizes=[3, 3, 3], k=K)
    wp = window_packets(te, P)
    reps = -(-args.batch // wp.shape[0])
    wp = np.tile(wp, (reps, 1, 1, 1))[:args.batch]
    eng = Engine.from_model(pdt)

    shape = ShapeInfo.from_engine(eng, wp)
    print(f"model: S={shape.S} subtrees over P={shape.P} partitions, "
          f"k={shape.k} registers; batch B={shape.B}, W={shape.W}")

    # 1. the analytical router (what EngineOptions(impl="auto") does
    # on every call)
    print("\ncost-model estimates (us/batch):")
    for b in ("looped", "fused", "pallas"):
        print(f"  {b:>7}: {estimate_us(shape, Plan(backend=b)):>12.0f}")
    print(f"impl='auto' would pick: {choose_plan(shape).describe()}")

    # 2. the empirical tuner (impl="tuned"): cold call probes + caches
    tuned = EngineOptions(impl="tuned")
    t0 = time.perf_counter()
    res = eng.run(wp, with_trace=False, options=tuned)
    cold_s = time.perf_counter() - t0
    print(f"\nimpl='tuned' cold call: {cold_s:.2f}s "
          f"-> plan: {res.plan.describe()}")
    t0 = time.perf_counter()
    res2 = eng.run(wp, with_trace=False, options=tuned)
    print(f"impl='tuned' warm call: {time.perf_counter() - t0:.3f}s "
          f"(plan source: {res2.plan.source})")
    print(f"cache: {cache_path()}")

    # 3. parity: the tuned route must be bit-identical to the reference
    ref = eng.run(wp, with_trace=False,
                  options=EngineOptions(impl="fused"))
    for field in ("labels", "recircs", "exit_partition"):
        np.testing.assert_array_equal(getattr(res2, field),
                                      getattr(ref, field))
    print("parity vs impl='fused': bit-identical "
          f"({res2.labels.size} verdicts)")
    return 0


if __name__ == "__main__":
    sys.exit(main())

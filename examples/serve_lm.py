"""Serving example: continuous batching over a fixed cache-slot pool —
the LM-side incarnation of SpliDT's register reuse (DESIGN.md §4).

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch import serve as serve_launch


def main():
    stats = serve_launch.main([
        "--arch", "granite-3-2b", "--slots", "3", "--requests", "9",
        "--max-new", "12",
    ])
    assert stats.completed == 9
    print("ACCEPTANCE: all requests served through the fixed slot pool OK")


if __name__ == "__main__":
    main()

"""Whole-program sharding resolution: params, optimizer state, batches,
and KV/state caches onto a mesh (DP/TP/FSDP/EP/SP).

Parameter/optimizer shardings come from the ParamDef logical axes
(``pspec.resolve_specs``).  Activations/batches/caches are resolved here
by dimension-role heuristics that encode the design in DESIGN.md §5:

  * batch dims ride ("pod", "data") when divisible;
  * head dims ride "model";
  * long sequence/cache dims ride "model" for decode (flash-decode
    style KV split) and "data" when the batch axis is unusable
    (long_500k batch=1 -> sequence parallelism).
"""
from __future__ import annotations


import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed import pspec
from repro.launch.mesh import mesh_shape_dict
from repro.train.optimizer import TrainState


def _div(n: int, size: int) -> bool:
    return size > 0 and n % size == 0


def batch_axes(mesh) -> tuple[str, ...]:
    from repro.models import layers as L
    names = mesh.axis_names
    return tuple(a for a in L.BATCH_AXES if a in names)


def flow_batch_spec(mesh) -> P:
    """PartitionSpec for a flow-batch tensor (leading dim = flows).

    The streaming scheduler fans micro-batches out across the mesh's
    data-parallel axes; every other dim (partition, window, packet
    fields) stays replicated-local.  Used as the ``shard_map`` in/out
    spec for the partition walk."""
    axes = batch_axes(mesh)
    if not axes:
        raise ValueError(
            f"mesh axes {mesh.axis_names} have no data-parallel axis "
            f"(need one of {('pod', 'data')})")
    return P(axes)


def flow_batch_devices(mesh) -> int:
    """How many ways :func:`flow_batch_spec` splits the flow axis."""
    sizes = mesh_shape_dict(mesh)
    return int(np.prod([sizes[a] for a in batch_axes(mesh)]))


def batch_spec(mesh, shape: tuple[int, ...]) -> P:
    """Shard the leading (global-batch) dim over ("pod","data")."""
    sizes = mesh_shape_dict(mesh)
    axes = batch_axes(mesh)
    total = int(np.prod([sizes[a] for a in axes]))
    if shape and _div(shape[0], total):
        return P(axes, *([None] * (len(shape) - 1)))
    # batch=1 (long-context): shard the largest long axis over "data"
    spec: list = [None] * len(shape)
    for i, d in sorted(enumerate(shape), key=lambda t: -t[1]):
        if i == 0:
            continue
        if _div(d, sizes.get("data", 1)) and d >= sizes.get("data", 1) * 8:
            spec[i] = "data"
            break
    return P(*spec)


def cache_spec(mesh, shape: tuple[int, ...], cfg: ArchConfig,
               opt: bool = True) -> P:
    """KV/state cache sharding.

    Heuristic roles by dim size: batch (== global_batch) -> dp axes;
    a dim equal to n_kv/n_heads (or B*H products) -> "model"; the long
    seq dim -> "model" if batch sharded else "data" (SP).
    """
    sizes = mesh_shape_dict(mesh)
    dp = batch_axes(mesh)
    dp_total = int(np.prod([sizes[a] for a in dp])) if dp else 1
    model = sizes.get("model", 1)
    spec: list = [None] * len(shape)
    if not shape:
        return P()
    # caches arrive with the stacked-layer dim in front; recognise it by
    # value (known per-arch layer counts) and never shard it
    lead = cfg.moe.first_dense_layers if cfg.moe else 0
    layer_counts = {cfg.n_layers, cfg.n_layers - lead, cfg.enc_layers}
    if cfg.shared_attn_every:
        layer_counts.add(cfg.n_layers // cfg.shared_attn_every)
    layer_counts.discard(0)
    used_model = used_seq = used_batch = False
    # pass 1: batch + head dims.  Head dims take the "model" axis with
    # priority over long sequence dims when the arch's head count
    # divides it: a window-sliced (sliding-window decode) or ring cache
    # then stays shard-local, where a seq-sharded cache would force a
    # gather for any dynamic slice (§Perf, zamba2 long_500k).
    head_sizes = {cfg.n_heads, cfg.n_kv_heads}
    for i, d in enumerate(shape):
        if i == 0 and len(shape) >= 3 and d in layer_counts:
            continue   # stacked layer dim
        if not used_batch and _div(d, dp_total) and d >= dp_total and i <= 1:
            spec[i] = dp
            used_batch = True
            continue
        if (opt and not used_model and i >= 2 and d in head_sizes
                and cfg.sliding_window and _div(d, model)):
            spec[i] = "model"
            used_model = True
            used_seq = True   # window slice must stay shard-local
    # pass 2: remaining model-axis candidates (latent dims, long seq)
    for i, d in enumerate(shape):
        if spec[i] is not None or (i == 0 and len(shape) >= 3
                                   and d in layer_counts):
            continue
        if (not used_model and d >= model and _div(d, model)
                and d <= max(cfg.n_heads, cfg.d_model) and i >= 2):
            spec[i] = "model"
            used_model = True
            continue
        if not used_seq and d >= 4096 and i >= 1:
            ax = "model" if not used_model and _div(d, model) else (
                "data" if not used_batch and _div(d, sizes.get("data", 1))
                else None)
            if ax:
                spec[i] = ax
                used_seq = used_model = True
            continue
    return P(*spec)


def train_state_shardings(cfg: ArchConfig, mesh, defs=None):
    """NamedSharding tree for a TrainState (params + mu/nu mirrored)."""
    from repro.models import model_zoo
    defs = defs or model_zoo.get_model(cfg).param_defs(cfg)
    specs = pspec.resolve_specs(defs, mesh_shape_dict(mesh))
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), specs)
    scalar = NamedSharding(mesh, P())
    return TrainState(step=scalar, params=named, mu=named, nu=named)


def tree_shardings(mesh, tree, spec_fn):
    """Map ShapeDtypeStruct tree -> NamedSharding tree via spec_fn(shape)."""
    return jax.tree.map(
        lambda x: NamedSharding(mesh, spec_fn(tuple(x.shape))), tree)


def batch_shardings(cfg: ArchConfig, mesh, batch_sds):
    return tree_shardings(mesh, batch_sds, lambda s: batch_spec(mesh, s))


def cache_shardings(cfg: ArchConfig, mesh, cache_sds):
    return tree_shardings(mesh, cache_sds,
                          lambda s: cache_spec(mesh, s, cfg))

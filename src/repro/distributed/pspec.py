"""Parameter definitions with logical sharding axes.

Every model declares its parameters once as a tree of :class:`ParamDef`
(shape + per-dim *logical* axis names + init).  From that single source
of truth we derive:
  * materialised parameters (``init_params``),
  * ``jax.sharding.PartitionSpec`` trees (``resolve_specs``) under a
    rule set mapping logical axes -> mesh axes, with automatic
    divisibility fallback (a dim that doesn't divide its mesh axis is
    replicated -- e.g. 4 KV heads on a 16-way model axis),
  * ``ShapeDtypeStruct`` trees for AOT lowering (``abstract_params``).

Logical axes used across the zoo:
  vocab, embed, mlp, heads, kv, head_dim, expert, expert_mlp, lora,
  state, conv, frames
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

Logical = tuple[str | None, ...]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    logical: Logical
    init: str = "normal"        # normal | zeros | ones | embed
    scale: float | None = None  # stddev override (default fan-in)
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


# rules: logical axis -> mesh axis (or tuple of mesh axes)
# The production mesh is ("data", "model"); "pod" stays pure-DP so params
# are replicated across pods.  "embed" riding the data axis is the FSDP
# (ZeRO-3) dimension: weights are all-gathered per-layer on use.
DEFAULT_RULES: dict[str, Any] = {
    "vocab": "model",
    "embed": "data",
    "mlp": "model",
    "heads": "model",
    "kv": "model",
    "expert": "model",
    "expert_mlp": None,
    "head_dim": None,
    "lora": None,
    "state": None,
    "conv": None,
    "frames": None,
    "layers": None,
}

# §Perf train layout for DENSE archs (EXPERIMENTS.md): fully-sharded
# (ZeRO-3 over both mesh axes), no tensor parallelism.  At train_4k's
# 1M-token global batch the per-layer activation all-reduces of TP cost
# ~4x more wire than per-layer weight all-gathers, so FSDP-2D wins.
FSDP2D_RULES: dict[str, Any] = dict(
    DEFAULT_RULES,
    embed=("data", "model"), vocab=None, mlp=None, heads=None, kv=None,
)

# §Perf serve layout: weights fully resident (NO per-token FSDP
# gathers) — TP over "model", replicated over "data"; MoE experts live
# whole on their EP shard with d_ff sharded over "data" so DeepSeek's
# 222B of expert weights fit (bf16).
SERVE_RULES: dict[str, Any] = dict(
    DEFAULT_RULES,
    embed=None, expert="model", expert_mlp="data",
)


def _axis_size(mesh_shape: dict[str, int], axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh_shape.get(a, 1) for a in axis]))
    return mesh_shape.get(axis, 1)


def resolve_spec(d: ParamDef, mesh_shape: dict[str, int],
                 rules: dict[str, Any] | None = None) -> P:
    rules = rules or DEFAULT_RULES
    out = []
    for dim, name in zip(d.shape, d.logical):
        axis = rules.get(name) if name else None
        if axis is not None and dim % _axis_size(mesh_shape, axis) == 0:
            out.append(axis)
        else:
            out.append(None)
    return P(*out)


def resolve_specs(defs, mesh_shape: dict[str, int],
                  rules: dict[str, Any] | None = None):
    return jax.tree.map(
        lambda d: resolve_spec(d, mesh_shape, rules), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_params(defs, dtype=None):
    """ShapeDtypeStruct tree; ``dtype`` overrides float leaves (bf16
    weights for serving)."""
    def mk(d: ParamDef):
        dt = d.dtype
        if dtype is not None and jnp.issubdtype(dt, jnp.floating):
            dt = dtype
        return jax.ShapeDtypeStruct(d.shape, dt)

    return jax.tree.map(mk, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def init_params(defs, key: jax.Array):
    """Materialise parameters (smoke tests / real training)."""
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))

    def mk(d: ParamDef, k):
        if d.init == "zeros":
            return jnp.zeros(d.shape, d.dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, d.dtype)
        if d.init == "embed":
            return (jax.random.normal(k, d.shape, d.dtype)
                    * (d.scale if d.scale is not None else 0.02))
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        scale = d.scale if d.scale is not None else fan_in ** -0.5
        return jax.random.normal(k, d.shape, d.dtype) * scale

    return jax.tree.unflatten(treedef, [mk(d, k) for d, k in zip(leaves, keys)])


def param_bytes(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(int(np.prod(d.shape)) * np.dtype(d.dtype).itemsize
               for d in leaves)


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return sum(int(np.prod(d.shape)) for d in leaves)


def stack_defs(d: ParamDef, n: int) -> ParamDef:
    """Stack a per-layer def across ``n`` scanned layers."""
    return dataclasses.replace(
        d, shape=(n,) + d.shape, logical=("layers",) + d.logical)


def stack_tree(defs, n: int):
    return jax.tree.map(lambda d: stack_defs(d, n), defs,
                        is_leaf=lambda x: isinstance(x, ParamDef))

"""GPipe-style pipeline parallelism via shard_map + collective_permute.

For meshes beyond (pod, data, model) — the 1000+-node regime where a
third intra-pod axis pays off — layers are divided into S stages along a
"stage" mesh axis and microbatches stream through with the standard
GPipe schedule: S + M - 1 ticks, activations handed to the next stage by
``jax.lax.ppermute`` each tick.

This module is self-contained (used by its own tests and the scaling
example, not by the assigned dry-run mesh, which is 2-axis by spec).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable,     # (stage_params, x) -> x
    mesh: Mesh,
    stage_axis: str = "stage",
):
    """Returns fn(stacked_stage_params, microbatches) -> outputs.

    stacked_stage_params: leaves with leading dim = n_stages, sharded
    one-stage-per-device along ``stage_axis``.
    microbatches: (M, mb, ...) — all microbatches enter at stage 0.
    """
    n_stages = mesh.shape[stage_axis]

    def per_device(params, mbs):
        # params: this stage's params (leading stage dim of size 1)
        params = jax.tree.map(lambda a: a[0], params)
        stage = jax.lax.axis_index(stage_axis)
        M = mbs.shape[0]
        ticks = n_stages + M - 1
        buf = jnp.zeros_like(mbs[0])                     # current activation
        outs = jnp.zeros_like(mbs)                       # stage S-1 results

        def tick(t, carry):
            buf, outs = carry
            mb_idx = t - stage
            # stage 0 ingests a fresh microbatch on ticks [0, M)
            fresh = jnp.take(mbs, jnp.clip(mb_idx, 0, M - 1), axis=0)
            x = jnp.where(stage == 0, fresh, buf)
            active = (mb_idx >= 0) & (mb_idx < M)
            y = stage_fn(params, x)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            outs = jnp.where(
                (stage == n_stages - 1) & active,
                outs.at[jnp.clip(mb_idx, 0, M - 1)].set(y), outs)
            # hand activations downstream (ring permute; wraparound value
            # at stage 0 is ignored -- it reads from mbs)
            nxt = jax.lax.ppermute(
                y, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return nxt, outs

        buf, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; sum-broadcast them so
        # the replicated out_spec is truthful on every device
        return jax.lax.psum(outs, stage_axis)

    return shard_map(
        per_device, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(),
        check_rep=False)


def make_stage_mesh(n_stages: int, data: int = 1):
    import jax as _jax
    from jax.sharding import AxisType
    return _jax.make_mesh((n_stages, data), ("stage", "data"),
                          axis_types=(AxisType.Auto,) * 2)

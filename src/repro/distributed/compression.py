"""Gradient compression for cross-pod synchronisation.

At 2+ pods the gradient all-reduce crosses the DCN/optical boundary
("pod" axis), which is an order of magnitude slower than ICI.  We
compress that hop: int8 quantise per-tensor (symmetric, max-abs scale),
all-reduce the quantised values, dequantise, and carry the quantisation
residual into the next step (error feedback, arXiv:1901.09847) so the
compression is unbiased over time.

``compressed_psum`` is the wire-level primitive (use under shard_map);
``compress_grads`` is the jit-level transform used by the train step —
numerically identical to quantise -> psum -> dequantise when the mean
over the pod axis is taken AFTER dequantisation on each member (our
psum/num_pods ordering), and exercised against the shard_map version in
tests.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

BITS = 8
_LEVELS = 2 ** (BITS - 1) - 1   # 127


def _quantize(g: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(g)) / _LEVELS
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g / scale), -_LEVELS, _LEVELS).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jnp.ndarray, axis_name: str,
                    err: jnp.ndarray | None = None):
    """int8-compressed mean over ``axis_name`` with error feedback.

    Call inside shard_map.  Returns (mean_grad_f32, new_err).
    """
    gf = g.astype(jnp.float32)
    if err is not None:
        gf = gf + err
    # agree on a SHARED scale first (one scalar pmax -- negligible bytes)
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    scale = jnp.maximum(amax / _LEVELS, 1e-30)
    q = jnp.clip(jnp.round(gf / scale), -_LEVELS, _LEVELS)
    new_err = gf - q * scale
    # sum int8 payloads in int32 (wire format: 1 byte/elem + 1 scalar)
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return total.astype(jnp.float32) * scale / n, new_err


def compress_grads(grads: Any, err: Any | None = None):
    """Jit-level quantise/dequantise with error feedback (per tensor).

    Models the numerics of the compressed cross-pod exchange; XLA keeps
    ownership of the actual collective.  Returns (grads', new_err).
    """
    flat, tdef = jax.tree.flatten(grads)
    if err is None:
        flat_err = [jnp.zeros_like(g, jnp.float32) for g in flat]
    else:
        flat_err = jax.tree.leaves(err)
    out_g, out_e = [], []
    for g, e in zip(flat, flat_err):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        deq = _dequantize(q, scale)
        out_g.append(deq.astype(g.dtype))
        out_e.append(gf - deq)
    return jax.tree.unflatten(tdef, out_g), jax.tree.unflatten(tdef, out_e)


def compression_ratio(grads: Any) -> float:
    """Wire bytes int8 / bf16 baseline (~0.5) -- reported in benchmarks."""
    total = sum(g.size for g in jax.tree.leaves(grads))
    return (total * 1 + 4) / (total * 2)

"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: config -> model -> data pipeline (prefetching Markov
stream) -> AdamW train step (donated buffers) -> async checkpointing ->
step watchdog (straggler flags) -> recovery on restart (resumes from the
last committed checkpoint and the matching stream position).

On a real pod the same script runs under the production mesh; on CPU use
``--reduced`` (tiny same-family config) — the end-to-end example trains
a ~100M model a few hundred steps this way.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_arch
from repro.data.tokens import TokenPipeline
from repro.distributed import pspec as pspec_lib
from repro.models import model_zoo
from repro.train import checkpoint as ckpt_lib
from repro.train.elastic import StepWatchdog
from repro.train.optimizer import AdamW, warmup_cosine
from repro.train.train_step import TrainLoopCfg, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (e.g. ~100M model on CPU)")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.d_model:
        cfg = dataclasses.replace(
            cfg, d_model=args.d_model, n_heads=max(args.d_model // 64, 1),
            n_kv_heads=max(min(cfg.n_kv_heads, args.d_model // 64), 1),
            d_ff=args.d_model * 3, d_head=64)
    if args.layers:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)

    zoo = model_zoo.get_model(cfg)
    defs = zoo.param_defs(cfg)
    opt = AdamW(lr=warmup_cosine(args.lr, 20, args.steps))
    loop = TrainLoopCfg(microbatches=args.microbatches,
                        compress_grads=args.compress_grads)
    raw_step = make_train_step(cfg, opt, loop)
    step_fn = jax.jit(raw_step, donate_argnums=(0,))

    pipe = TokenPipeline(cfg.vocab, args.batch, args.seq, seed=args.seed)
    start_step = 0
    state = None
    if args.ckpt_dir:
        last = ckpt_lib.latest_committed(args.ckpt_dir)
        if last:
            state, _ = ckpt_lib.restore(last)
            state = jax.tree.map(jnp.asarray, state)
            start_step = int(jax.device_get(state.step))
            print(f"resumed from {last} at step {start_step}")
    if state is None:
        params = pspec_lib.init_params(defs, jax.random.key(args.seed))
        state = opt.init(params)

    n_params = pspec_lib.param_count(defs)
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    writer = ckpt_lib.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None
    wd = StepWatchdog(on_straggler=lambda s, dt, ema: print(
        f"  [watchdog] step {s} took {dt:.2f}s (ema {ema:.2f}s)"))
    comp_err = None
    losses = []
    it = pipe.iterate(start_step)
    for i in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        t0 = time.perf_counter()
        state, metrics, comp_err = step_fn(state, batch, comp_err)
        loss = float(jax.device_get(metrics["loss"]))
        losses.append(loss)
        wd.observe(i + 1, time.perf_counter() - t0)
        if (i + 1) % args.log_every == 0 or i == start_step:
            print(f"step {i+1:5d} loss {loss:.4f} "
                  f"gnorm {float(jax.device_get(metrics['grad_norm'])):.3f}")
        if writer and (i + 1) % args.ckpt_every == 0:
            writer.save(state)
    if writer:
        writer.save(state)
        writer.wait()
    print(f"done. first-10 mean loss {np.mean(losses[:10]):.4f} -> "
          f"last-10 mean loss {np.mean(losses[-10:]):.4f}; "
          f"uniform floor {np.log(cfg.vocab):.3f}")
    return losses


if __name__ == "__main__":
    main()

"""Serving launcher: continuous batching over a fixed slot pool.

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --slots 4 --requests 12 --max-new 16

Demonstrates the register-pool reuse pattern (DESIGN.md §4): an open
request stream served with a FIXED pool of cache slots; admission into
freed slots every engine tick.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_arch
from repro.distributed import pspec as pspec_lib
from repro.models import model_zoo
from repro.serve.batching import ContinuousBatcher, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.is_encoder_decoder:
        raise SystemExit("enc-dec serving requires audio frames; "
                         "use the decoder-only archs for this demo")
    zoo = model_zoo.get_model(cfg)
    params = pspec_lib.init_params(zoo.param_defs(cfg), jax.random.key(0))

    eng = ContinuousBatcher(cfg, params, slots=args.slots,
                            max_len=args.max_len, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, rng.integers(4, 12)).tolist()
        eng.submit(Request(rid=rid, prompt=prompt, max_new=args.max_new))

    t0 = time.perf_counter()
    stats = eng.run_until_drained()
    dt = time.perf_counter() - t0
    print(f"completed {stats.completed}/{args.requests} requests in "
          f"{stats.ticks} ticks ({dt:.1f}s); decode tokens "
          f"{stats.decode_tokens}; mean slot occupancy "
          f"{np.mean(stats.slot_occupancy):.2f}/{args.slots}")
    return stats


if __name__ == "__main__":
    main()

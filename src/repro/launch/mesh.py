"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — smoke tests keep their single
CPU device; only the dry-run sets ``xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_flow_mesh(n_data: int | None = None):
    """1-D ("data",) mesh for flow-batch sharding (streaming engine).

    ``n_data`` defaults to every visible device — the serving topology
    where one host fans flow micro-batches out across its accelerators.
    """
    n = len(jax.devices()) if n_data is None else n_data
    return jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def mesh_shape_dict(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))

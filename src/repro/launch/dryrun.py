import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^^ MUST precede any jax-importing import: jax locks the device count on
# first init.  Smoke tests / benches never import this module.
"""Multi-pod dry-run driver.

For every (architecture x input shape) cell, on the single-pod 16x16
mesh AND the 2x16x16 multi-pod mesh:

    jit(step).lower(**abstract inputs).compile()

recording memory_analysis(), cost_analysis(), the collective schedule
parsed from the optimised HLO, and (single-pod) the three-term roofline
via exact affine depth extrapolation (see analysis/roofline.py).

Usage:
    python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
    python -m repro.launch.dryrun --all          # every cell, subprocesses
    python -m repro.launch.dryrun --all --jobs 4
Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis import memory as memory_lib
from repro.analysis import roofline as roof
from repro.configs import ARCHS, get_arch
from repro.configs.base import ArchConfig, SHAPES, ShapeCfg, shape_supported
from repro.distributed import pspec as pspec_lib
from repro.distributed import sharding
from repro.launch.mesh import make_production_mesh, mesh_shape_dict
from repro.models import layers as L
from repro.models import model_zoo
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.optimizer import AdamW, TrainState
from repro.train.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# step builders: (fn, arg_sds tuple, in_shardings tuple)
# ---------------------------------------------------------------------------
def build_cell(cfg: ArchConfig, shape: ShapeCfg, mesh, layout: str = "base"):
    """layout:
      base — paper-faithful port: TP+FSDP sharding, naive attention,
             scatter MoE dispatch, full-cache window masking
      opt  — §Perf: FSDP-2D train layout (dense archs, no remat),
             resident bf16 weights + EP-2D experts for serving,
             blockwise attention, einsum MoE decode dispatch,
             window-local cache slicing/sharding
    """
    zoo = model_zoo.get_model(cfg)
    defs = zoo.param_defs(cfg)
    msizes = mesh_shape_dict(mesh)
    rules = None
    param_dtype = None
    if layout == "base":
        # paper-faithful baseline: naive (probs-materialising) attention,
        # scatter MoE dispatch, full-cache window masking
        L.set_blockwise_min(1 << 30)
        L.set_window_slice(False)
        from repro.models import moe as _moe
        _moe.set_einsum_decode(False)
    if layout == "opt":
        L.set_blockwise_min(2048)
        if shape.kind == "train" and cfg.moe is None:
            rules = pspec_lib.FSDP2D_RULES
            L.set_layout("fsdp2d")
            from repro.models import transformer as _tf
            _tf.set_remat(False)     # ample per-chip activation headroom
        elif shape.kind in ("prefill", "decode"):
            rules = pspec_lib.SERVE_RULES
            param_dtype = jnp.bfloat16
    pspecs = pspec_lib.resolve_specs(defs, msizes, rules)
    from jax.sharding import NamedSharding, PartitionSpec as P
    named = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    params_sds = pspec_lib.abstract_params(defs, dtype=param_dtype)
    batch_sds = model_zoo.input_specs(cfg, shape)
    batch_sh = sharding.batch_shardings(cfg, mesh, batch_sds)
    scalar = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = AdamW(lr=1e-3)
        step = make_train_step(cfg, opt)
        state_sds = jax.eval_shape(opt.init, params_sds)
        state_sh = TrainState(step=scalar, params=named, mu=named, nu=named)

        def fn(state, batch):
            new_state, metrics, _ = step(state, batch, None)
            return new_state, metrics["loss"]

        return fn, (state_sds, batch_sds), (state_sh, batch_sh), defs, None, None

    cache_sds = model_zoo.abstract_cache(cfg, shape)
    cache_specs = jax.tree.map(
        lambda x: sharding.cache_spec(mesh, tuple(x.shape), cfg,
                                      opt=layout == "opt"), cache_sds)
    cache_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), cache_specs)

    if shape.kind == "prefill":
        prefill = make_prefill_step(cfg)
        return (prefill, (params_sds, batch_sds, cache_sds),
                (named, batch_sh, cache_sh), defs, cache_sds, cache_specs)

    decode = make_decode_step(cfg)

    def fn(params, tokens, cache):
        return decode(params, tokens, cache, None)

    tok_sds = batch_sds["tokens"]
    tok_sh = batch_sh["tokens"]
    return (fn, (params_sds, tok_sds, cache_sds),
            (named, tok_sh, cache_sh), defs, cache_sds, cache_specs)


def lower_compile(cfg, shape, mesh, unroll: bool, layout: str = "base"):
    try:
        fn, sds, shardings_, defs, cache_sds, cache_specs = build_cell(
            cfg, shape, mesh, layout)
        L.set_unroll(unroll)
        t0 = time.time()
        with jax.set_mesh(mesh):
            lowered = jax.jit(fn, in_shardings=shardings_).lower(*sds)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
        t_compile = time.time() - t0
    finally:
        L.set_unroll(False)
        L.set_layout("tp")
        L.set_blockwise_min(2048)
        L.set_window_slice(True)
        from repro.models import moe as _moe
        _moe.set_einsum_decode(True)
        from repro.models import transformer as _tf
        _tf.set_remat(True)
    return compiled, t_lower, t_compile, defs, cache_sds, cache_specs


# ---------------------------------------------------------------------------
# depth variants for exact affine cost extrapolation
# ---------------------------------------------------------------------------
def depth_variants(cfg: ArchConfig):
    """[(cfg_small, n_small), ...], n_full — n counts the repeating unit."""
    if cfg.shared_attn_every:          # zamba: unit = group of ssm layers
        e = cfg.shared_attn_every
        mk = lambda g: dataclasses.replace(cfg, n_layers=e * g)
        return [(mk(1), 1), (mk(2), 2)], cfg.n_layers // e
    if cfg.is_encoder_decoder:         # whisper: enc+dec vary together
        mk = lambda n: dataclasses.replace(cfg, n_layers=n, enc_layers=n)
        return [(mk(2), 2), (mk(4), 4)], cfg.n_layers
    lead = cfg.moe.first_dense_layers if cfg.moe else 0
    mk = lambda n: dataclasses.replace(cfg, n_layers=n + lead)
    return [(mk(2), 2), (mk(4), 4)], cfg.n_layers - lead


def roofline_cell(cfg: ArchConfig, shape: ShapeCfg, mesh,
                  layout: str = "base") -> dict:
    """Three-term roofline via two unrolled small-depth compiles."""
    variants, n_full = depth_variants(cfg)
    samples = []
    for vcfg, n in variants:
        compiled, tl, tc, *_ = lower_compile(vcfg, shape, mesh, unroll=True,
                                             layout=layout)
        ca = compiled.cost_analysis()
        coll = roof.parse_collectives(compiled.as_text())
        samples.append({
            "n": n,
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "collective_bytes": float(coll.total_bytes),
            "collective_counts": coll.counts,
            "t_lower_s": tl, "t_compile_s": tc,
        })
    (s1, s2) = samples
    ex = lambda k: roof.affine_extrapolate(s1[k], s2[k], s1["n"], s2["n"],
                                           n_full)
    chips = mesh.devices.size
    msizes = mesh_shape_dict(mesh)
    cache_bytes = resident = 0
    if shape.kind == "decode":
        cache_sds = model_zoo.abstract_cache(cfg, shape)
        cache_specs = jax.tree.map(
            lambda x: sharding.cache_spec(mesh, tuple(x.shape), cfg,
                                          opt=layout == "opt"),
            cache_sds)
        cache_bytes = memory_lib._sharded_bytes(cache_sds, cache_specs,
                                                msizes)
        # exact per-layout resident weight bytes (serve: bf16, EP-2D)
        zoo = model_zoo.get_model(cfg)
        defs = zoo.param_defs(cfg)
        rules = pspec_lib.SERVE_RULES if layout == "opt" else None
        dt = jnp.bfloat16 if layout == "opt" else None
        resident = memory_lib._sharded_bytes(
            pspec_lib.abstract_params(defs, dtype=dt),
            pspec_lib.resolve_specs(defs, msizes, rules), msizes)
    terms = roof.RooflineTerms(
        flops_per_chip=ex("flops"),
        hbm_bytes_per_chip=ex("bytes"),
        collective_bytes_per_chip=ex("collective_bytes"),
        chips=chips,
        model_flops=roof.model_flops_for(cfg, shape),
        hbm_bytes_model=roof.analytic_hbm_bytes(
            cfg, shape, msizes, cache_bytes_per_chip=cache_bytes,
            resident_param_bytes=resident),
    )
    return {"samples": samples, "n_full": n_full, **terms.as_dict()}


# ---------------------------------------------------------------------------
# per-cell driver
# ---------------------------------------------------------------------------
def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             with_roofline: bool = True, layout: str = "base") -> dict:
    cfg = get_arch(arch_id)
    shape = SHAPES[shape_name]
    mesh_name = ("2x16x16" if multi_pod else "16x16") + (
        "" if layout == "base" else f"_{layout}")
    record: dict = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
                    "layout": layout}
    ok, reason = shape_supported(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=reason)
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    compiled, t_lower, t_compile, defs, cache_sds, cache_specs = \
        lower_compile(cfg, shape, mesh, unroll=False, layout=layout)
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    coll = roof.parse_collectives(compiled.as_text())
    opt_rules = None
    opt_dtype = None
    if layout == "opt":
        if shape.kind == "train" and cfg.moe is None:
            opt_rules = pspec_lib.FSDP2D_RULES
        elif shape.kind in ("prefill", "decode"):
            opt_rules = pspec_lib.SERVE_RULES
            opt_dtype = jnp.bfloat16
    mem = memory_lib.budget(
        cfg, shape, mesh_shape_dict(mesh), defs,
        cache_sds=cache_sds, cache_specs=cache_specs,
        train=shape.kind == "train", rules=opt_rules, param_dtype=opt_dtype)
    record.update(
        status="ok",
        t_lower_s=round(t_lower, 2),
        t_compile_s=round(t_compile, 2),
        memory_analysis={
            "temp_bytes": int(ma.temp_size_in_bytes),
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "generated_code_bytes": int(ma.generated_code_size_in_bytes),
        },
        analytic_memory=mem.as_dict(),
        cost_analysis={"flops": float(ca.get("flops", 0.0)),
                       "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
                       "note": "while-loop bodies counted once; see roofline"},
        collectives={"counts": coll.counts,
                     "bytes_by_kind": coll.bytes_by_kind},
    )
    print(f"[{arch_id} x {shape_name} x {mesh_name}] compile ok in "
          f"{t_compile:.1f}s; analytic mem {mem.total_bytes / 1e9:.2f} GB/chip "
          f"(fits={mem.fits}); collectives {coll.counts}")
    print("memory_analysis:", record["memory_analysis"])
    print("cost_analysis:", record["cost_analysis"])

    if with_roofline and not multi_pod:
        record["roofline"] = roofline_cell(cfg, shape, mesh, layout=layout)
        r = record["roofline"]
        print(f"  roofline: compute {r['t_compute_s']:.4f}s "
              f"memory {r['t_memory_s']:.4f}s (hlo-bound "
              f"{r['t_memory_hlo_s']:.4f}s) collective "
              f"{r['t_collective_s']:.4f}s -> {r['bottleneck']}-bound; "
              f"useful-FLOP frac {r['useful_flops_fraction']:.3f}; "
              f"roofline frac {r['roofline_fraction']:.4f}")
    record["t_total_s"] = round(time.time() - t0, 1)
    return record


def cell_path(arch_id, shape_name, mesh_name) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(
        OUT_DIR, f"{arch_id}__{shape_name}__{mesh_name}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--layout", choices=("base", "opt"), default="base")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        cells = [(a, s, mp) for a in ARCHS for s in SHAPES
                 for mp in (False, True)]
        procs: list[tuple[subprocess.Popen, str]] = []
        failed = []
        for a, s, mp in cells:
            path = cell_path(a, s, "2x16x16" if mp else "16x16")
            if os.path.exists(path) and not args.force:
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s]
            if mp:
                cmd.append("--multi-pod")
            if args.no_roofline:
                cmd.append("--no-roofline")
            while len(procs) >= args.jobs:
                procs, failed = _reap(procs, failed)
                time.sleep(1)
            print(">>", " ".join(cmd), flush=True)
            procs.append((subprocess.Popen(cmd), f"{a}/{s}/{mp}"))
        while procs:
            procs, failed = _reap(procs, failed)
            time.sleep(1)
        print("FAILED CELLS:", failed if failed else "none")
        return

    rec = {}
    try:
        rec = run_cell(args.arch, args.shape, args.multi_pod,
                       with_roofline=not args.no_roofline,
                       layout=args.layout)
    except Exception as e:
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()}
        print(rec["traceback"], file=sys.stderr)
    mesh_name = ("2x16x16" if args.multi_pod else "16x16") + (
        "" if args.layout == "base" else f"_{args.layout}")
    path = cell_path(args.arch, args.shape, mesh_name)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print("wrote", path)
    sys.exit(0 if rec.get("status") in ("ok", "skipped") else 1)


def _reap(procs, failed):
    alive = []
    for p, name in procs:
        if p.poll() is None:
            alive.append((p, name))
        elif p.returncode != 0:
            failed.append(name)
    return alive, failed


if __name__ == "__main__":
    main()

"""SpliDT reproduction: partitioned decision trees, TPU-native.

Importing ``repro`` installs small forward-compat aliases for newer
JAX APIs (see :mod:`repro._jax_compat`) so the same source runs on the
pinned 0.4.x wheels and on current jax.
"""
from repro import _jax_compat as _jax_compat  # noqa: F401  (side effect)

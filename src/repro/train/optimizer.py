"""AdamW + schedules, pure JAX (no optax offline).

Optimizer state mirrors the parameter tree (same shapes), so the FSDP
parameter shardings apply verbatim to ``mu``/``nu`` -- ZeRO-style
sharded optimizer state falls out of the sharding rules for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TrainState:
    step: jnp.ndarray          # () int32
    params: Any
    mu: Any
    nu: Any

    def tree_flatten(self):
        return (self.step, self.params, self.mu, self.nu), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState, TrainState.tree_flatten, TrainState.tree_unflatten)


def warmup_cosine(peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> TrainState:
        zeros = lambda: jax.tree.map(jnp.zeros_like, params)
        return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                          mu=zeros(), nu=zeros())

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, state: TrainState, grads) -> tuple[TrainState, dict]:
        # global-norm clip (f32)
        gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                  for g in jax.tree.leaves(grads))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self._lr(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay and p.ndim >= 2:   # decay matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p - lr * delta.astype(p.dtype)).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(state.params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
        new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
        new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return TrainState(step=step, params=new_p, mu=new_m, nu=new_v), metrics

"""Sharded checkpointing with elastic restore + async writer.

Format: one ``.npz`` per array group + a JSON manifest (step, tree
structure, shapes, dtypes).  Restore places arrays onto ANY mesh via
``jax.device_put`` with that mesh's resolved shardings — a checkpoint
written on 8 devices restores onto 4 or 2 (elastic scale-down) or 512
(scale-up) unchanged; the resharding test exercises this.

On a real multi-host pod each host would write its addressable shards
(process-local npz + shard manifest); the single-controller CPU harness
gathers full arrays, which is faithful for correctness testing.

``AsyncCheckpointer`` double-buffers: device_get on the main thread
(cheap, donating nothing), file I/O on a background thread so the train
loop never blocks on disk — checkpoint/compute overlap.
"""
from __future__ import annotations

import json
import os
import threading
import time

import jax
import numpy as np

from repro.train.optimizer import TrainState

_SEP = "."


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}

    def rec(prefix, node):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(f"{prefix}{_SEP}{k}" if prefix else str(k), node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                rec(f"{prefix}{_SEP}{i}", v)
        elif node is None:
            flat[prefix + f"{_SEP}__none__"] = np.zeros(0)
        else:
            flat[prefix] = np.asarray(node)

    rec("", tree)
    return flat


def _tree_template(tree):
    """JSON-serialisable structure descriptor."""
    if isinstance(tree, dict):
        return {"__kind__": "dict",
                "items": {k: _tree_template(v) for k, v in tree.items()}}
    if isinstance(tree, (list, tuple)):
        return {"__kind__": "list" if isinstance(tree, list) else "tuple",
                "items": [_tree_template(v) for v in tree]}
    if tree is None:
        return {"__kind__": "none"}
    return {"__kind__": "leaf"}


def _rebuild(template, flat, prefix=""):
    kind = template["__kind__"]
    if kind == "dict":
        return {k: _rebuild(v, flat, f"{prefix}{_SEP}{k}" if prefix else str(k))
                for k, v in template["items"].items()}
    if kind in ("list", "tuple"):
        seq = [_rebuild(v, flat, f"{prefix}{_SEP}{i}")
               for i, v in enumerate(template["items"])]
        return seq if kind == "list" else tuple(seq)
    if kind == "none":
        return None
    return flat[prefix]


def save(path: str, state: TrainState, extra: dict | None = None) -> None:
    os.makedirs(path, exist_ok=True)
    tree = {"step": state.step, "params": state.params,
            "mu": state.mu, "nu": state.nu}
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    flat = _flatten(host)
    np.savez(os.path.join(path, "arrays.npz"), **flat)
    manifest = {
        "template": _tree_template(host),
        "step": int(host["step"]),
        "extra": extra or {},
        "time": time.time(),
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # atomic-ish completion marker (crash-consistent restore)
    with open(os.path.join(path, "COMMITTED"), "w") as f:
        f.write("ok")


def latest_committed(root: str) -> str | None:
    """Most recent committed checkpoint dir under ``root`` (step_N dirs)."""
    if not os.path.isdir(root):
        return None
    cands = []
    for d in os.listdir(root):
        full = os.path.join(root, d)
        if os.path.exists(os.path.join(full, "COMMITTED")):
            try:
                cands.append((int(d.split("_")[-1]), full))
            except ValueError:
                continue
    return max(cands)[1] if cands else None


def restore(path: str, shardings=None) -> tuple[TrainState, dict]:
    """Restore; ``shardings``: TrainState-shaped tree of NamedShardings
    for the TARGET mesh (elastic restore), or None for host arrays."""
    if not os.path.exists(os.path.join(path, "COMMITTED")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    tree = _rebuild(manifest["template"], flat)
    state = TrainState(step=tree["step"], params=tree["params"],
                       mu=tree["mu"], nu=tree["nu"])
    if shardings is not None:
        state = jax.tree.map(jax.device_put, state, shardings)
    return state, manifest["extra"]


class AsyncCheckpointer:
    """Background-thread writer: snapshot on caller thread, I/O async."""

    def __init__(self, root: str, keep: int = 3):
        self.root = root
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.last_error: Exception | None = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error

    def save(self, state: TrainState, extra: dict | None = None):
        self.wait()   # one in flight at a time (double buffer)
        step = int(jax.device_get(state.step))
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        path = os.path.join(self.root, f"step_{step}")

        def work():
            try:
                save(path, host, extra)
                self._gc()
            except Exception as e:   # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        return path

    def _gc(self):
        dirs = []
        for d in os.listdir(self.root):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.root, d, "COMMITTED")):
                dirs.append((int(d.split("_")[1]), d))
        for _, d in sorted(dirs)[:-self.keep]:
            full = os.path.join(self.root, d)
            for f in os.listdir(full):
                os.remove(os.path.join(full, f))
            os.rmdir(full)

"""Fault tolerance & elasticity: step watchdog (straggler mitigation),
elastic remesh, and checkpoint-based recovery.

At 1000+ nodes the failure model is: (a) slow steps from stragglers
(bad host, thermal throttling, network incast), (b) hard node loss.
The framework's answer:

  * ``StepWatchdog`` — EMA of step wall-time; a step exceeding
    ``threshold x EMA`` fires the mitigation callback (in deployment:
    evict the slow host / re-dispatch the shard; here: counted + tested).
  * ``remesh`` — device_put a TrainState onto a different mesh (scale
    up/down without retraining); combined with ``checkpoint.restore``
    this is the elastic-recovery path (N hosts -> N-k hosts and back).
  * ``run_with_recovery`` — the driver loop: train, checkpoint every k
    steps (async), on simulated/real failure restore the last committed
    step and continue — exactly-once step semantics come from the step
    counter in the checkpoint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import TrainState


@dataclasses.dataclass
class StepWatchdog:
    threshold: float = 3.0      # x EMA -> straggler
    ema_decay: float = 0.9
    warmup_steps: int = 2       # ignore compile steps
    ema: float = 0.0
    seen: int = 0
    stragglers: int = 0
    on_straggler: Callable[[int, float, float], None] | None = None

    def observe(self, step: int, dt: float) -> bool:
        """Returns True if this step was flagged as a straggler."""
        self.seen += 1
        if self.seen <= self.warmup_steps:
            return False
        if self.ema == 0.0:
            self.ema = dt
            return False
        flagged = dt > self.threshold * self.ema
        if flagged:
            self.stragglers += 1
            if self.on_straggler:
                self.on_straggler(step, dt, self.ema)
        else:
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * dt
        return flagged


def remesh(state: TrainState, shardings) -> TrainState:
    """Move a TrainState onto a new mesh's shardings (elastic rescale)."""
    return jax.tree.map(jax.device_put, state, shardings)


@dataclasses.dataclass
class RecoveryReport:
    steps_run: int
    failures: int
    restores: int
    final_step: int
    straggler_flags: int


def run_with_recovery(
    step_fn: Callable[[TrainState, Any], tuple[TrainState, dict]],
    state: TrainState,
    batches,                       # iterable of batches
    *,
    ckpt_root: str,
    ckpt_every: int = 10,
    fail_at: set[int] | None = None,   # simulated failures (step numbers)
    shardings=None,
    watchdog: StepWatchdog | None = None,
) -> tuple[TrainState, RecoveryReport]:
    """Training driver with checkpoint/restart semantics.

    ``fail_at`` simulates hard failures AFTER the given step numbers:
    the in-memory state is discarded and the last committed checkpoint
    is restored (possibly replaying steps — the exactly-once guarantee
    is on the checkpoint step counter, matching real preemption).
    """
    writer = ckpt_lib.AsyncCheckpointer(ckpt_root)
    fail_at = set(fail_at or ())
    failures = restores = steps = 0
    wd = watchdog or StepWatchdog()
    batches = list(batches)
    i = 0
    while i < len(batches):
        t0 = time.perf_counter()
        state, _ = step_fn(state, batches[i])
        step = int(jax.device_get(state.step))
        wd.observe(step, time.perf_counter() - t0)
        steps += 1
        if step % ckpt_every == 0:
            writer.save(state)
        if step in fail_at:
            fail_at.discard(step)
            failures += 1
            writer.wait()
            last = ckpt_lib.latest_committed(ckpt_root)
            if last is not None:
                state, _ = ckpt_lib.restore(last, shardings)
                restores += 1
                i = int(jax.device_get(state.step))   # replay from ckpt
                continue
        i += 1
    writer.wait()
    return state, RecoveryReport(
        steps_run=steps, failures=failures, restores=restores,
        final_step=int(jax.device_get(state.step)),
        straggler_flags=wd.stragglers)

"""Training step factory: loss -> grads -> (optional microbatch
accumulation, optional int8 gradient compression with error feedback)
-> AdamW update.  All buffers donated.

Batch sharding: leading (global-batch) axis over ("pod", "data") — the
gradient all-reduce over "pod" is the only cross-pod traffic per step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed import compression
from repro.models import model_zoo
from repro.train.optimizer import AdamW, TrainState


@dataclasses.dataclass(frozen=True)
class TrainLoopCfg:
    microbatches: int = 1
    compress_grads: bool = False


def make_train_step(cfg: ArchConfig, opt: AdamW,
                    loop: TrainLoopCfg = TrainLoopCfg()) -> Callable:
    """Returns step(state, batch, comp_err) -> (state, metrics, comp_err)."""
    zoo = model_zoo.get_model(cfg)

    def loss_fn(params, batch):
        return zoo.loss_fn(cfg, params, batch)

    def grads_of(params, batch):
        if loop.microbatches <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        m = loop.microbatches
        mb = jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)

        def acc(carry, mb_i):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb_i)
            return (loss_acc + loss / m,
                    jax.tree.map(lambda a, b: a + b / m, g_acc, g)), None

        zero = (jnp.float32(0.0),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss, grads), _ = jax.lax.scan(acc, zero, mb)
        return loss, grads

    def step(state: TrainState, batch: dict, comp_err: Any | None = None):
        loss, grads = grads_of(state.params, batch)
        if loop.compress_grads:
            grads, comp_err = compression.compress_grads(grads, comp_err)
        new_state, metrics = opt.update(state, grads)
        metrics["loss"] = loss
        return new_state, metrics, comp_err

    return step


def init_comp_err(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

"""granite-3-2b — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    arch_id="granite-3-2b",
    family=Family.DENSE,
    n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8,
    d_ff=8192, vocab=49155, rope_theta=10000.0, act="silu",
    tie_embeddings=True,
    supports_long=False,
    source="hf:ibm-granite/granite-3.0-2b-base",
)

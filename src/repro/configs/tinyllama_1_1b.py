"""tinyllama-1.1b — llama2-arch small [arXiv:2401.02385; hf]."""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    arch_id="tinyllama-1.1b",
    family=Family.DENSE,
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab=32000, rope_theta=10000.0, act="silu",
    supports_long=False,
    source="arXiv:2401.02385; hf:TinyLlama/TinyLlama-1.1B",
)

"""qwen2-moe-a2.7b — 4 shared + 60 routed top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

60 routed experts (padded to 64 on 16-way expert-parallel meshes; pad
experts are masked out of routing), top-4, d_ff_expert=1408; the 4
shared experts are fused into one always-on MLP of width 4*1408=5632.
"""
from repro.configs.base import ArchConfig, Family, MoECfg

CONFIG = ArchConfig(
    arch_id="qwen2-moe-a2.7b",
    family=Family.MOE,
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, act="silu",
    moe=MoECfg(n_experts=60, top_k=4, d_ff_expert=1408,
               n_shared=4, d_ff_shared=5632),
    supports_long=False,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

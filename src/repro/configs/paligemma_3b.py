"""paligemma-3b — SigLIP + gemma [arXiv:2407.07726; hf].

The SigLIP vision tower is a STUB per the assignment: ``input_specs``
provides 256 precomputed patch embeddings which form a bidirectional
prefix (prefix-LM mask) ahead of the text tokens.  Backbone is the
gemma-2b decoder: 18L, d_model 2048, 8 heads / 1 KV head (MQA),
d_ff 16384, gelu, vocab 257216.
"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    arch_id="paligemma-3b",
    family=Family.VLM,
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1,
    d_ff=16384, vocab=257216, act="gelu", d_head=256,
    n_image_tokens=256, tie_embeddings=True,
    supports_long=False,
    source="arXiv:2407.07726; hf:google/paligemma-3b",
)

"""whisper-medium — enc-dec, conv frontend (stub) [arXiv:2212.04356].

Shape interpretation (DESIGN.md §4): ``seq_len`` is the audio-frame count
into the encoder; the conv frontend is a STUB (``input_specs`` provides
precomputed frame embeddings).  Decoder text length = seq_len // 8.
Decode shapes cache both self- and cross-attention.
"""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    arch_id="whisper-medium",
    family=Family.AUDIO,
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=4096, vocab=51865, act="gelu",
    is_encoder_decoder=True, enc_layers=24, dec_ratio=8,
    supports_long=False,
    source="arXiv:2212.04356 (unverified)",
)

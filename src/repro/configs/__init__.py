"""Config registry: ``--arch <id>`` -> ArchConfig."""
from __future__ import annotations

from repro.configs import (
    deepseek_v2_236b, granite_3_2b, minitron_8b, paligemma_3b,
    qwen2_moe_a2_7b, rwkv6_1_6b, stablelm_3b, tinyllama_1_1b,
    whisper_medium, zamba2_2_7b,
)
from repro.configs.base import ArchConfig, SHAPES, ShapeCfg, shape_supported

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.arch_id: m.CONFIG
    for m in (
        tinyllama_1_1b, minitron_8b, granite_3_2b, stablelm_3b,
        rwkv6_1_6b, whisper_medium, qwen2_moe_a2_7b, deepseek_v2_236b,
        paligemma_3b, zamba2_2_7b,
    )
}


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; options: {sorted(ARCHS)}")
    return ARCHS[arch_id]

"""rwkv6-1.6b — Finch, data-dependent decay [arXiv:2404.05892; unverified].

Attention-free: time-mix blocks run the chunked gated linear recurrence
(``kernels/chunk_scan``) with per-channel data-dependent decay and the
RWKV bonus term.  Sub-quadratic -> runs the ``long_500k`` cell.
"""
from repro.configs.base import ArchConfig, Family, SSMCfg

CONFIG = ArchConfig(
    arch_id="rwkv6-1.6b",
    family=Family.SSM,
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=7168, vocab=65536, act="relu_sq",
    ssm=SSMCfg(state_dim=64, head_dim=64, chunk=128),
    supports_long=True,
    source="arXiv:2404.05892 (Finch; unverified)",
)

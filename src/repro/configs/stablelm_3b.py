"""stablelm-3b — [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    arch_id="stablelm-3b",
    family=Family.DENSE,
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304, rope_theta=10000.0, act="silu",
    supports_long=False,
    source="hf:stabilityai/stablelm-2-1_6b (unverified)",
)

"""zamba2-2.7b — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B].

54 Mamba2 layers (ssm_state=64) with ONE shared transformer block whose
weights are re-invoked every 6 layers (Zamba2's parameter-sharing
scheme; per-invocation LoRA adapters omitted -- noted in DESIGN.md).
Sub-quadratic: runs ``long_500k`` with the shared attention block in
sliding-window mode (window 4096) at 500k context.
"""
from repro.configs.base import ArchConfig, Family, SSMCfg

CONFIG = ArchConfig(
    arch_id="zamba2-2.7b",
    family=Family.HYBRID,
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, act="gelu",
    ssm=SSMCfg(state_dim=64, head_dim=64, expand=2, conv_dim=4, chunk=128),
    shared_attn_every=6, sliding_window=4096,
    supports_long=True,
    source="arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B",
)

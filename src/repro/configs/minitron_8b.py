"""minitron-8b — pruned nemotron [arXiv:2407.14679; hf]."""
from repro.configs.base import ArchConfig, Family

CONFIG = ArchConfig(
    arch_id="minitron-8b",
    family=Family.DENSE,
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=16384, vocab=256000, rope_theta=10000.0, act="silu",
    supports_long=False,
    source="arXiv:2407.14679; hf:nvidia/Minitron-8B-Base",
)

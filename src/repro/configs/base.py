"""Architecture config schema + input-shape registry.

One ``ArchConfig`` per assigned architecture lives in
``configs/<arch_id>.py``; each exposes ``CONFIG`` (the exact published
numbers) and every config supports ``.reduced()`` -- a tiny same-family
variant for CPU smoke tests.  The four assigned input shapes are global
(``SHAPES``); per-arch applicability (decode/long-context skips) is
declared via ``ArchConfig.supports``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Family(str, enum.Enum):
    DENSE = "dense"
    SSM = "ssm"
    AUDIO = "audio"
    MOE = "moe"
    VLM = "vlm"
    HYBRID = "hybrid"


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int              # routed experts
    top_k: int
    d_ff_expert: int
    n_shared: int = 0           # shared ("always-on") experts
    d_ff_shared: int = 0        # total shared width (n_shared * d_ff_expert)
    first_dense_layers: int = 0 # leading layers with dense FFN (DeepSeek)
    d_ff_dense: int = 0         # width of those dense layers
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MLACfg:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    state_dim: int = 64         # N (per-head state) for Mamba2; dk for RWKV6
    head_dim: int = 64
    expand: int = 2             # d_inner = expand * d_model (Mamba2)
    conv_dim: int = 4           # depthwise causal conv width (Mamba2)
    chunk: int = 128            # chunked-scan window (the SpliDT "window")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                     # 0 -> d_model // n_heads
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    act: str = "silu"                   # mlp activation: silu|gelu
    tie_embeddings: bool = False
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # enc-dec (whisper): encoder shares d_model/heads; frontend is a stub
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    dec_ratio: int = 8                  # decoder len = seq_len // dec_ratio
    # vlm: image-prefix length fed as precomputed patch embeddings (stub)
    n_image_tokens: int = 0
    # hybrid (zamba2): shared attention block applied every N ssm layers
    shared_attn_every: int = 0
    # attention window for long-context serving (0 = full causal)
    sliding_window: int = 0
    # which assigned shapes this arch runs (DESIGN.md §Arch-applicability)
    supports_decode: bool = True
    supports_long: bool = False
    source: str = ""

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        return self.family in (Family.SSM, Family.HYBRID)

    def param_count(self) -> int:
        """Approximate total parameters (for MODEL_FLOPS = 6*N*D)."""
        from repro.models import model_zoo
        return model_zoo.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import model_zoo
        return model_zoo.param_count(self, active_only=True)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        def shrink_moe(m: Optional[MoECfg]) -> Optional[MoECfg]:
            if m is None:
                return None
            return dataclasses.replace(
                m, n_experts=8, top_k=min(m.top_k, 2), d_ff_expert=64,
                n_shared=min(m.n_shared, 1), d_ff_shared=64 if m.n_shared else 0,
                first_dense_layers=min(m.first_dense_layers, 1),
                d_ff_dense=128 if m.first_dense_layers else 0)

        def shrink_mla(m: Optional[MLACfg]) -> Optional[MLACfg]:
            if m is None:
                return None
            return MLACfg(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                          qk_rope_dim=8, v_head_dim=16)

        def shrink_ssm(m: Optional[SSMCfg]) -> Optional[SSMCfg]:
            if m is None:
                return None
            return dataclasses.replace(m, state_dim=16, head_dim=16, chunk=16)

        return dataclasses.replace(
            self,
            n_layers=2 if not self.shared_attn_every else 4,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_ff=128,
            vocab=256,
            d_head=16,
            moe=shrink_moe(self.moe),
            mla=shrink_mla(self.mla),
            ssm=shrink_ssm(self.ssm),
            enc_layers=min(self.enc_layers, 2),
            n_image_tokens=min(self.n_image_tokens, 8),
            shared_attn_every=2 if self.shared_attn_every else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str           # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def shape_supported(cfg: ArchConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable dry-run cell; else the reason."""
    if shape.name == "long_500k" and not cfg.supports_long:
        return False, ("pure full-attention architecture: 500k-token decode "
                       "requires sub-quadratic state (skip noted in DESIGN.md)")
    if shape.kind == "decode" and not cfg.supports_decode:
        return False, "encoder-only architecture has no decode step"
    return True, ""

"""deepseek-v2-236b — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2].

MLA: q_lora 1536, kv_lora 512, qk_nope 128, qk_rope 64, v_head 128,
128 heads.  MoE: 160 routed top-6 (d_ff 1536) + 2 shared, first layer
dense (d_ff 12288).
"""
from repro.configs.base import ArchConfig, Family, MLACfg, MoECfg

CONFIG = ArchConfig(
    arch_id="deepseek-v2-236b",
    family=Family.MOE,
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=1536, vocab=102400, act="silu",
    mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512,
               qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoECfg(n_experts=160, top_k=6, d_ff_expert=1536,
               n_shared=2, d_ff_shared=3072,
               first_dense_layers=1, d_ff_dense=12288),
    supports_long=False,
    source="arXiv:2405.04434; hf:deepseek-ai/DeepSeek-V2",
)

"""Partitioned decision trees + SpliDT's custom training (Algorithm 1).

A :class:`PartitionedDT` is a collection of subtrees grouped into
partitions.  Subtree 0 (SID 0) lives in partition 0 and sees window 0's
features; each of its leaves either *exits* with a class label or routes
to a subtree in the next partition, which sees window 1's features, and
so on.  Every subtree uses at most ``k`` distinct features -- the
register budget that the data plane time-shares across partitions via
recirculation.

Training follows the paper's Algorithm 1: per-leaf training on exactly
the samples that reach the leaf, using the *next* window's features --
so subtrees specialise to the traffic distribution they will actually
observe at inference time.  Growth is partition-major (level order):
all of partition p's subtrees train before partition p+1's, which is
what lets ``trainer="jax"`` train each partition's whole subtree fleet
as one vmapped dispatch (``repro.fit``).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.core import tree as tree_lib
from repro.core.features import max_dep_depth
from repro.core.tree import Tree, train_tree

EXIT = -1  # leaf routing value: emit class label


@dataclasses.dataclass
class SubTree:
    sid: int
    partition: int                  # which partition (== window index)
    tree: Tree
    # per-leaf routing: maps leaf node id -> next SID, or EXIT
    leaf_next_sid: dict[int, int]
    # per-leaf class label (used when routing == EXIT)
    leaf_label: dict[int, int]

    @property
    def used_features(self) -> np.ndarray:
        return self.tree.used_features()

    @property
    def depth(self) -> int:
        return self.tree.max_depth


@dataclasses.dataclass
class PartitionedDT:
    subtrees: list[SubTree]
    partition_sizes: list[int]      # [i_1 .. i_p]; sum == total depth D
    k: int                          # feature slots per subtree
    n_classes: int
    n_features: int

    # ---- structure queries (drive the resource model) ----------------
    @property
    def n_partitions(self) -> int:
        return len(self.partition_sizes)

    @property
    def total_depth(self) -> int:
        return int(sum(self.partition_sizes))

    def sids_in_partition(self, p: int) -> list[int]:
        return [s.sid for s in self.subtrees if s.partition == p]

    def unique_features(self) -> np.ndarray:
        if not self.subtrees:
            return np.zeros(0, dtype=np.int64)
        return np.unique(np.concatenate([s.used_features for s in self.subtrees]))

    def max_features_per_subtree(self) -> int:
        return max((len(s.used_features) for s in self.subtrees), default=0)

    def dep_depth(self) -> int:
        return max((max_dep_depth(s.used_features) for s in self.subtrees),
                   default=0)

    def feature_density(self) -> tuple[float, float]:
        """(%features used per partition, %features per subtree) -- Table 1."""
        per_sub = [100.0 * len(s.used_features) / self.n_features
                   for s in self.subtrees]
        per_part = []
        for p in range(self.n_partitions):
            feats = [s.used_features for s in self.subtrees if s.partition == p]
            if feats:
                per_part.append(
                    100.0 * len(np.unique(np.concatenate(feats))) / self.n_features)
        return (float(np.mean(per_part)) if per_part else 0.0,
                float(np.mean(per_sub)) if per_sub else 0.0)

    # ---- reference inference (numpy oracle) ---------------------------
    def predict(self, X_windows: np.ndarray,
                return_trace: bool = False):
        """Windowed partitioned inference.

        ``X_windows``: (n, p, N) per-window features.  Returns predicted
        labels (n,); with ``return_trace`` also returns the number of
        partition transitions ("recirculations") per flow and the
        partition index at which each flow exited.
        """
        n = X_windows.shape[0]
        sid = np.zeros(n, dtype=np.int64)            # all flows start at root
        done = np.zeros(n, dtype=bool)
        # verdict arrays start at the -1 sentinel (docs/PARITY.md §2): a
        # flow that never takes an exit action keeps it, so a corrupt/
        # truncated model can't silently claim class 0 at partition 0
        label = np.full(n, -1, dtype=np.int64)
        recircs = np.zeros(n, dtype=np.int64)
        exit_partition = np.full(n, -1, dtype=np.int64)
        for p in range(self.n_partitions):
            active_sids = self.sids_in_partition(p)
            for s_id in active_sids:
                st = self.subtrees[s_id]
                rows = np.nonzero((~done) & (sid == s_id))[0]
                if rows.size == 0:
                    continue
                leaves = st.tree.apply(X_windows[rows, p, :])
                nxt = np.asarray([st.leaf_next_sid.get(int(l), EXIT) for l in leaves])
                lab = np.asarray([st.leaf_label[int(l)] for l in leaves])
                exiting = nxt == EXIT
                done[rows[exiting]] = True
                label[rows[exiting]] = lab[exiting]
                exit_partition[rows[exiting]] = p
                cont = rows[~exiting]
                sid[cont] = nxt[~exiting]
                recircs[cont] += 1                    # one control packet
        # a flow still active after the last partition never took an exit
        # action (possible only for corrupt/truncated models — training
        # exits every leaf of the final partition) and keeps the -1
        # sentinels it was initialised with, matching the engine backends
        if return_trace:
            return label, recircs, exit_partition
        return label


def train_partitioned_dt(
    X_windows: np.ndarray,
    y: np.ndarray,
    *,
    partition_sizes: list[int],
    k: int,
    n_classes: int | None = None,
    min_samples_subtree: int = 16,
    min_samples_leaf: int = 2,
    max_bins: int = tree_lib.MAX_BINS,
    max_dep_depth: int | None = None,
    trainer: str = "numpy",
) -> PartitionedDT:
    """Paper Algorithm 1: per-leaf subtree training, one partition level
    at a time.

    ``X_windows``: (n, p, N) features per window; ``partition_sizes``:
    depth of each partition's subtrees; ``k``: distinct-feature budget
    per subtree.  ``max_dep_depth`` restricts candidate features to
    those whose dependency chain fits the register budget (the DSE sets
    this at high flow targets, where dependency registers are the
    binding constraint).

    ``trainer`` selects the subtree grower:

    * ``"numpy"`` -- the host CART oracle (:func:`repro.core.tree.train_tree`),
      one subtree at a time;
    * ``"jax"``   -- the jitted level-synchronous histogram grower
      (``repro.fit``): each partition's subtree fleet trains as ONE
      vmapped dispatch, structurally identical to the numpy trees
      node-for-node (the contract in ``repro.core.tree``).

    SIDs are assigned in partition-major level order (partition 0's
    subtree, then partition 1's subtrees in the order their parent
    leaves appear, ...) so both trainers number subtrees identically.
    """
    n, p_avail, N = X_windows.shape
    p = len(partition_sizes)
    if p > p_avail:
        raise ValueError(f"need {p} windows, dataset has {p_avail}")
    if trainer not in ("numpy", "jax"):
        raise ValueError(f"unknown trainer {trainer!r}; options: numpy, jax")
    y = np.asarray(y, dtype=np.int64)
    C = int(n_classes if n_classes is not None else y.max() + 1)
    allowed = None
    if max_dep_depth is not None:
        from repro.core.features import REGISTRY
        allowed = np.asarray([s.fid for s in REGISTRY
                              if s.dep_depth <= max_dep_depth])

    subtrees: list[SubTree] = []

    # frontier entry: (rows, parent_sid, parent_leaf); partition 0 has a
    # single root subtree with no parent
    frontier: list[tuple[np.ndarray, int, int]] = [(np.arange(n), -1, -1)]
    for partition in range(p):
        if not frontier:
            break
        depth = int(partition_sizes[partition])
        fleet_X = [X_windows[rows, partition, :] for rows, _, _ in frontier]
        fleet_y = [y[rows] for rows, _, _ in frontier]
        grow_t0 = time.perf_counter() if obs.enabled() else 0.0
        with obs.span("fit/level"):
            if trainer == "jax":
                from repro.fit import train_forest
                trees = train_forest(
                    fleet_X, fleet_y, max_depth=depth, k_features=k,
                    n_classes=C, min_samples_leaf=min_samples_leaf,
                    max_bins=max_bins, allowed_features=allowed)
            else:
                trees = [train_tree(Xs, ys, max_depth=depth, k_features=k,
                                    n_classes=C,
                                    min_samples_leaf=min_samples_leaf,
                                    max_bins=max_bins,
                                    allowed_features=allowed)
                         for Xs, ys in zip(fleet_X, fleet_y)]
        reg_obs = obs.get_registry()
        reg_obs.counter("fit_trees_total", "subtrees grown",
                        labels={"trainer": trainer}).inc(len(trees))
        if obs.enabled():
            reg_obs.histogram(
                "fit_level_seconds",
                "wall-clock per-partition subtree-fleet grow time",
                edges=obs.exp_edges(1e-4, 100.0, 13),
                labels={"trainer": trainer},
            ).record(time.perf_counter() - grow_t0)

        next_frontier: list[tuple[np.ndarray, int, int]] = []
        last = partition + 1 >= p
        for (rows, parent_sid, parent_leaf), Xs, t in zip(
                frontier, fleet_X, trees):
            sid = len(subtrees)
            st = SubTree(sid=sid, partition=partition, tree=t,
                         leaf_next_sid={}, leaf_label={})
            subtrees.append(st)
            if parent_sid >= 0:
                subtrees[parent_sid].leaf_next_sid[parent_leaf] = sid

            leaves = t.apply(Xs)
            leaf_ids = np.nonzero(t.feature < 0)[0]
            for leaf in leaf_ids:
                leaf = int(leaf)
                st.leaf_label[leaf] = int(t.value[leaf].argmax())
                subset = rows[leaves == leaf]
                counts = t.value[leaf]
                pure = (counts > 0).sum() <= 1
                # early exit: last partition, pure leaf, or too few samples
                if last or pure or subset.shape[0] < min_samples_subtree:
                    st.leaf_next_sid[leaf] = EXIT
                else:
                    # SID filled in when the child trains next level
                    next_frontier.append((subset, sid, leaf))
        frontier = next_frontier

    return PartitionedDT(
        subtrees=subtrees, partition_sizes=list(partition_sizes), k=k,
        n_classes=C, n_features=N,
    )

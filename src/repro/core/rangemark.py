"""Range-Marking rule generation (NetBeacon's algorithm, paper §3.2.1).

Maps a (sub)tree's feature thresholds to per-feature *range marks* and
the tree's leaves to model-table entries:

  * Feature tables: for each feature used by the subtree, its sorted
    thresholds t_1 < ... < t_r segment the domain into r+1 ranges; each
    range gets a mark (its ordinal index).  In TCAM, a range over a
    W-bit field is matched with its minimal prefix cover; we count exact
    prefix-cover entries (classic <= 2W-2 bound per range).
  * Model table: each leaf constrains every feature to a *contiguous*
    interval of marks, so one leaf = one entry (paper: "one TCAM rule
    per leaf"), matched together with an exact SID key.

Both executable rule tables and TCAM entry/bit counts are produced; a
property test asserts rule-table semantics == direct tree traversal.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.tree import Tree


def prefix_cover_count(lo: int, hi: int, width: int) -> int:
    """Number of ternary prefixes needed to match the integer range
    [lo, hi] within a ``width``-bit field (minimal prefix cover)."""
    if hi < lo:
        return 0
    lo = max(int(lo), 0)
    hi = min(int(hi), (1 << width) - 1)
    count = 0
    while lo <= hi:
        # largest aligned power-of-two block starting at lo that fits
        b = lo & -lo if lo > 0 else 1 << width
        while lo + b - 1 > hi:
            b >>= 1
        count += 1
        lo += b
    return count


def quantize_thresholds(thresholds: np.ndarray, lo: float, hi: float,
                        bits: int) -> np.ndarray:
    """Map float thresholds into the ``bits``-wide register domain."""
    span = max(hi - lo, 1e-9)
    levels = (1 << bits) - 1
    q = np.floor((np.asarray(thresholds, dtype=np.float64) - lo) / span * levels)
    return np.clip(q, 0, levels).astype(np.int64)


@dataclasses.dataclass
class FeatureRangeTable:
    """Executable range->mark table for one feature of one subtree."""
    fid: int
    thresholds: np.ndarray          # sorted float thresholds (r,)
    mark_bits: int
    tcam_entries: int               # prefix-cover entry count
    # executable form: mark(value) = searchsorted(thresholds, value, 'left')
    #   value <= t_1 -> 0 ; t_1 < value <= t_2 -> 1 ; ... ; value > t_r -> r

    def marks(self, values: np.ndarray) -> np.ndarray:
        return np.searchsorted(self.thresholds, values, side="left").astype(np.int64)


@dataclasses.dataclass
class LeafRule:
    leaf: int
    # per-fid inclusive mark interval; features absent from the path are
    # wildcarded (don't-care) in TCAM
    mark_intervals: dict[int, tuple[int, int]]
    action: int                     # next SID or class (interpreted by caller)


@dataclasses.dataclass
class SubtreeRules:
    feature_tables: dict[int, FeatureRangeTable]
    leaf_rules: list[LeafRule]
    model_entries: int              # == len(leaf_rules) (one rule per leaf)
    feature_entries: int            # sum of prefix-cover counts
    key_bits: int                   # model-table match key width (sid+marks)

    @property
    def total_entries(self) -> int:
        return self.model_entries + self.feature_entries

    def tcam_bits(self, sid_bits: int = 8) -> int:
        feat_bits = 0
        for ft in self.feature_tables.values():
            # feature-table entry: value (register width proxy: use the
            # threshold quantisation width) -> handled by caller via
            # entry counts x field width; here count mark/key bits only.
            feat_bits += ft.tcam_entries * 32
        return feat_bits + self.model_entries * self.key_bits

    def apply(self, X: np.ndarray) -> np.ndarray:
        """Execute the rule tables on raw features (n, N) -> action (n,).

        First matching leaf rule wins (TCAM priority order).
        """
        n = X.shape[0]
        marks = {fid: ft.marks(X[:, fid]) for fid, ft in self.feature_tables.items()}
        out = np.full(n, -1, dtype=np.int64)
        unmatched = np.ones(n, dtype=bool)
        for rule in self.leaf_rules:
            hit = unmatched.copy()
            for fid, (lo, hi) in rule.mark_intervals.items():
                m = marks[fid]
                hit &= (m >= lo) & (m <= hi)
            out[hit] = rule.action
            unmatched &= ~hit
        return out


def build_subtree_rules(
    tree: Tree,
    leaf_action: dict[int, int],
    *,
    bits: int = 32,
    feature_ranges: dict[int, tuple[float, float]] | None = None,
    sid_bits: int = 8,
) -> SubtreeRules:
    """Generate range-marking rules for one subtree.

    ``leaf_action``: leaf node id -> action (next SID or class label,
    encoded by the caller).  ``feature_ranges``: observed (lo, hi) per
    feature for threshold quantisation when counting TCAM entries.
    """
    thr_per_f = tree.thresholds_per_feature()
    feature_tables: dict[int, FeatureRangeTable] = {}
    feature_entries = 0
    key_bits = sid_bits
    for fid, thr in sorted(thr_per_f.items()):
        r = len(thr)
        mark_bits = max(int(np.ceil(np.log2(r + 1))), 1)
        if feature_ranges and fid in feature_ranges:
            lo, hi = feature_ranges[fid]
        else:
            lo, hi = float(thr.min()), float(thr.max() + 1.0)
        qt = quantize_thresholds(thr, lo, hi, bits)
        # ranges in the integer domain: [0, q1], [q1+1, q2], ..., [qr+1, max]
        edges = np.concatenate([[-1], qt, [(1 << bits) - 1]])
        entries = 0
        for i in range(len(edges) - 1):
            entries += prefix_cover_count(int(edges[i]) + 1, int(edges[i + 1]), bits)
        ft = FeatureRangeTable(fid=fid, thresholds=thr.astype(np.float64),
                               mark_bits=mark_bits, tcam_entries=entries)
        feature_tables[fid] = ft
        feature_entries += entries
        key_bits += mark_bits

    # walk root->leaf paths accumulating per-feature mark intervals
    leaf_rules: list[LeafRule] = []

    def walk(node: int, intervals: dict[int, tuple[int, int]]):
        f = int(tree.feature[node])
        if f < 0:
            leaf_rules.append(LeafRule(
                leaf=node, mark_intervals=dict(intervals),
                action=int(leaf_action.get(node, -1))))
            return
        thr = float(tree.threshold[node])
        ft = feature_tables[f]
        # mark of the range containing values <= thr is searchsorted('left')
        split_mark = int(np.searchsorted(ft.thresholds, thr, side="left"))
        lo, hi = intervals.get(f, (0, len(ft.thresholds)))
        # left: value <= thr -> mark <= split_mark
        li = dict(intervals)
        li[f] = (lo, min(hi, split_mark))
        walk(int(tree.left[node]), li)
        # right: value > thr -> mark >= split_mark + 1
        ri = dict(intervals)
        ri[f] = (max(lo, split_mark + 1), hi)
        walk(int(tree.right[node]), ri)

    walk(0, {})
    return SubtreeRules(
        feature_tables=feature_tables,
        leaf_rules=leaf_rules,
        model_entries=len(leaf_rules),
        feature_entries=feature_entries,
        key_bits=key_bits,
    )

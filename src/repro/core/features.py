"""Stateful feature definitions for SpliDT.

A *feature* is a windowed statistic over a flow's packets (CICFlowMeter
style).  Each feature is described by an op-code triple

    (op, field, predicate)

so that the data plane can compute it with a per-SID operator-selection
table (paper Fig. 4): the MAT keyed on the subtree id (SID) selects which
op/field/predicate to apply to each of the k feature register slots.

Packet record layout (dense, one row per packet):

    col 0: timestamp   (float seconds; monotone within a flow)
    col 1: size        (bytes)
    col 2: direction   (0 = fwd, 1 = bwd)
    col 3: flags       (bitmask: SYN=1, ACK=2, FIN=4, RST=8, PSH=16, URG=32)
    col 4: iat         (inter-arrival time, derived via the dependency
                        chain -- requires the previous timestamp register)
    col 5: valid       (1 for a real packet, 0 for padding)

Ops are chosen to be implementable as single-stage register updates on a
Tofino-class pipeline (reads/writes one register, optional predicate from
the packet header).  Features whose inputs need intermediate values (IAT,
squared sums for variance) declare a dependency-chain depth, which the
resource model charges as extra register stages (paper §3.1.1).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

# --- packet field columns -------------------------------------------------
PKT_TS = 0
PKT_SIZE = 1
PKT_DIR = 2
PKT_FLAGS = 3
PKT_IAT = 4
PKT_VALID = 5
PKT_NFIELDS = 6

# --- flag bits --------------------------------------------------------------
FLAG_SYN = 1
FLAG_ACK = 2
FLAG_FIN = 4
FLAG_RST = 8
FLAG_PSH = 16
FLAG_URG = 32

# --- op codes (register update ops) -----------------------------------------
OP_NONE = 0     # slot unused by the active subtree
OP_COUNT = 1    # regs += pred
OP_SUM = 2      # regs += field * pred
OP_MAX = 3      # regs = max(regs, field) where pred
OP_MIN = 4      # regs = min(regs, field) where pred  (init +inf)
OP_LAST = 5     # regs = field where pred
OP_SUMSQ = 6    # regs += field^2 * pred       (dep depth 1: needs square)
OP_FIRST = 7    # regs = field on first matching packet

N_OPS = 8

# --- predicate codes --------------------------------------------------------
PRED_TRUE = 0
PRED_FWD = 1
PRED_BWD = 2
PRED_SYN = 3
PRED_ACK = 4
PRED_FIN = 5
PRED_RST = 6
PRED_PSH = 7
PRED_URG = 8

N_PREDS = 9

_PRED_FLAG = {
    PRED_SYN: FLAG_SYN,
    PRED_ACK: FLAG_ACK,
    PRED_FIN: FLAG_FIN,
    PRED_RST: FLAG_RST,
    PRED_PSH: FLAG_PSH,
    PRED_URG: FLAG_URG,
}


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    """One stateful feature = one register-update program."""

    fid: int
    name: str
    op: int
    field: int
    pred: int = PRED_TRUE
    dep_depth: int = 0     # extra dependency-chain stages (paper: <= 3)

    @property
    def init_value(self) -> float:
        if self.op == OP_MIN:
            return np.float32(np.finfo(np.float32).max)
        return np.float32(0.0)


def _mk_registry() -> list[FeatureSpec]:
    specs: list[FeatureSpec] = []

    def add(name, op, field, pred=PRED_TRUE, dep=0):
        specs.append(FeatureSpec(len(specs), name, op, field, pred, dep))

    # volume / count features
    add("pkt_count", OP_COUNT, PKT_SIZE)
    add("byte_sum", OP_SUM, PKT_SIZE)
    add("pkt_size_max", OP_MAX, PKT_SIZE)
    add("pkt_size_min", OP_MIN, PKT_SIZE)
    add("pkt_size_sumsq", OP_SUMSQ, PKT_SIZE, dep=1)
    add("pkt_size_first", OP_FIRST, PKT_SIZE)
    add("pkt_size_last", OP_LAST, PKT_SIZE)
    # direction-split volume
    add("fwd_pkt_count", OP_COUNT, PKT_SIZE, PRED_FWD)
    add("bwd_pkt_count", OP_COUNT, PKT_SIZE, PRED_BWD)
    add("fwd_byte_sum", OP_SUM, PKT_SIZE, PRED_FWD)
    add("bwd_byte_sum", OP_SUM, PKT_SIZE, PRED_BWD)
    add("fwd_size_max", OP_MAX, PKT_SIZE, PRED_FWD)
    add("bwd_size_max", OP_MAX, PKT_SIZE, PRED_BWD)
    add("fwd_size_min", OP_MIN, PKT_SIZE, PRED_FWD)
    add("bwd_size_min", OP_MIN, PKT_SIZE, PRED_BWD)
    # inter-arrival time (dependency chain: prev-timestamp register)
    add("iat_sum", OP_SUM, PKT_IAT, dep=1)
    add("iat_max", OP_MAX, PKT_IAT, dep=1)
    add("iat_min", OP_MIN, PKT_IAT, dep=1)
    add("iat_sumsq", OP_SUMSQ, PKT_IAT, dep=2)
    add("fwd_iat_sum", OP_SUM, PKT_IAT, PRED_FWD, dep=1)
    add("bwd_iat_sum", OP_SUM, PKT_IAT, PRED_BWD, dep=1)
    add("fwd_iat_max", OP_MAX, PKT_IAT, PRED_FWD, dep=1)
    add("bwd_iat_max", OP_MAX, PKT_IAT, PRED_BWD, dep=1)
    # flag counters
    add("syn_count", OP_COUNT, PKT_SIZE, PRED_SYN)
    add("ack_count", OP_COUNT, PKT_SIZE, PRED_ACK)
    add("fin_count", OP_COUNT, PKT_SIZE, PRED_FIN)
    add("rst_count", OP_COUNT, PKT_SIZE, PRED_RST)
    add("psh_count", OP_COUNT, PKT_SIZE, PRED_PSH)
    add("urg_count", OP_COUNT, PKT_SIZE, PRED_URG)
    # flag-gated sizes
    add("syn_size_sum", OP_SUM, PKT_SIZE, PRED_SYN)
    add("psh_size_sum", OP_SUM, PKT_SIZE, PRED_PSH)
    add("ack_size_max", OP_MAX, PKT_SIZE, PRED_ACK)
    # timing
    add("ts_first", OP_FIRST, PKT_TS, dep=1)
    add("ts_last", OP_LAST, PKT_TS, dep=1)
    add("syn_iat_sum", OP_SUM, PKT_IAT, PRED_SYN, dep=1)
    add("psh_iat_max", OP_MAX, PKT_IAT, PRED_PSH, dep=1)
    # direction-flag crosses
    add("fwd_psh_count", OP_COUNT, PKT_SIZE, PRED_PSH)
    add("bwd_ack_count", OP_COUNT, PKT_SIZE, PRED_ACK)
    add("fwd_size_sumsq", OP_SUMSQ, PKT_SIZE, PRED_FWD, dep=1)
    add("bwd_size_sumsq", OP_SUMSQ, PKT_SIZE, PRED_BWD, dep=1)
    add("bwd_size_last", OP_LAST, PKT_SIZE, PRED_BWD)
    return specs


REGISTRY: list[FeatureSpec] = _mk_registry()
N_FEATURES = len(REGISTRY)          # 41, matching D1's N in the paper
FEATURE_NAMES = [s.name for s in REGISTRY]
NAME_TO_FID = {s.name: s.fid for s in REGISTRY}

# packed (N_FEATURES, 4) table: op, field, pred, dep_depth
FEATURE_TABLE = np.asarray(
    [[s.op, s.field, s.pred, s.dep_depth] for s in REGISTRY], dtype=np.int32
)


def max_dep_depth(fids: Sequence[int]) -> int:
    """Dependency-chain depth needed by a feature subset (paper: <= 3)."""
    if len(fids) == 0:
        return 0
    return int(max(REGISTRY[f].dep_depth for f in fids))


def predicate_mask(pkts: np.ndarray, pred: int) -> np.ndarray:
    """Evaluate a predicate over packets ``(..., PKT_NFIELDS)`` -> bool."""
    valid = pkts[..., PKT_VALID] > 0
    if pred == PRED_TRUE:
        return valid
    if pred == PRED_FWD:
        return valid & (pkts[..., PKT_DIR] == 0)
    if pred == PRED_BWD:
        return valid & (pkts[..., PKT_DIR] == 1)
    flag = _PRED_FLAG[pred]
    return valid & ((pkts[..., PKT_FLAGS].astype(np.int64) & flag) > 0)


def compute_feature(pkts: np.ndarray, spec: FeatureSpec) -> np.ndarray:
    """Reference (offline) computation of one feature over a window.

    ``pkts``: (..., W, PKT_NFIELDS).  Returns (...,) float32.  This is the
    oracle the data-plane engine (and the Pallas kernel) must match.
    """
    mask = predicate_mask(pkts, spec.pred)
    field = pkts[..., spec.field].astype(np.float64)
    if spec.op == OP_COUNT:
        out = mask.sum(axis=-1)
    elif spec.op == OP_SUM:
        out = np.where(mask, field, 0.0).sum(axis=-1)
    elif spec.op == OP_MAX:
        out = np.where(mask, field, -np.inf).max(axis=-1, initial=-np.inf)
        out = np.where(np.isfinite(out), out, 0.0)
    elif spec.op == OP_MIN:
        out = np.where(mask, field, np.inf).min(axis=-1, initial=np.inf)
        out = np.where(np.isfinite(out), out, spec.init_value)
    elif spec.op == OP_LAST:
        idx = _last_true_index(mask)
        out = np.where(idx >= 0, np.take_along_axis(
            field, np.maximum(idx, 0)[..., None], axis=-1)[..., 0], 0.0)
    elif spec.op == OP_FIRST:
        idx = _first_true_index(mask)
        out = np.where(idx >= 0, np.take_along_axis(
            field, np.maximum(idx, 0)[..., None], axis=-1)[..., 0], 0.0)
    elif spec.op == OP_SUMSQ:
        out = np.where(mask, field * field, 0.0).sum(axis=-1)
    else:
        raise ValueError(f"unknown op {spec.op}")
    return out.astype(np.float32)


def _first_true_index(mask: np.ndarray) -> np.ndarray:
    any_ = mask.any(axis=-1)
    idx = mask.argmax(axis=-1)
    return np.where(any_, idx, -1)


def _last_true_index(mask: np.ndarray) -> np.ndarray:
    rev = mask[..., ::-1]
    any_ = mask.any(axis=-1)
    idx = mask.shape[-1] - 1 - rev.argmax(axis=-1)
    return np.where(any_, idx, -1)


def compute_all_features(pkts: np.ndarray) -> np.ndarray:
    """All N features over a window: (..., W, F) -> (..., N_FEATURES)."""
    cols = [compute_feature(pkts, s) for s in REGISTRY]
    return np.stack(cols, axis=-1)

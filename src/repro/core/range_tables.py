"""Range-execution tables: the TPU-native form of the MAT pipeline.

A Tofino MAT matches (SID, range marks) against TCAM rules.  The TPU
adaptation replaces pointer-chasing tree traversal with the *same*
range-marking semantics as dense compute (DESIGN.md §2):

  mark_j   = #{ t in thresholds[sid, j] : value_j > t }        (VPU compare+reduce)
  hit(l)   = AND_j  lo[sid, l, j] <= mark_j <= hi[sid, l, j]    (dense match)
  action   = first hit's action                                (priority encode)

Tables are padded to rectangular arrays so a Pallas kernel can stream
one subtree's block per grid step (grouped by SID, MoE-dispatch style).

Action encoding: ``action < n_subtrees`` -> transition to that SID;
``action >= n_subtrees`` -> exit with class ``action - n_subtrees``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.partition import EXIT, PartitionedDT

_PAD = 8  # pad threshold/leaf axes to multiples of this


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass
class RangeExecTables:
    """Dense per-SID matching tables.

    thresholds (S, k, T) f32, padded with +inf
    leaf_lo    (S, L, k) int32   inclusive mark interval per slot
    leaf_hi    (S, L, k) int32   (wildcard slots: [0, T])
    leaf_action(S, L)    int32   next-SID or n_subtrees+class; -1 padding
    leaf_valid (S, L)    bool
    """
    thresholds: np.ndarray
    leaf_lo: np.ndarray
    leaf_hi: np.ndarray
    leaf_action: np.ndarray
    leaf_valid: np.ndarray
    n_subtrees: int
    n_classes: int

    @property
    def k(self) -> int:
        return int(self.thresholds.shape[1])

    @property
    def max_thresholds(self) -> int:
        return int(self.thresholds.shape[2])

    @property
    def max_leaves(self) -> int:
        return int(self.leaf_lo.shape[1])

    def decode_action(self, action: np.ndarray):
        """-> (is_exit, next_sid, label)"""
        is_exit = action >= self.n_subtrees
        next_sid = np.where(is_exit, 0, action)
        # non-exit rows carry the -1 sentinel (docs/PARITY.md §2), never
        # a fake class 0
        label = np.where(is_exit, action - self.n_subtrees, -1)
        return is_exit, next_sid, label


def pack_range_exec(pdt: PartitionedDT) -> RangeExecTables:
    S, k = len(pdt.subtrees), pdt.k
    thr_lists: list[list[np.ndarray]] = []
    max_t = 1
    # per-subtree, per-slot sorted thresholds
    for st in pdt.subtrees:
        per_f = st.tree.thresholds_per_feature()
        used = list(map(int, st.used_features))
        slots = []
        for j in range(k):
            if j < len(used):
                t = per_f.get(used[j], np.zeros(0))
            else:
                t = np.zeros(0)
            slots.append(np.sort(np.asarray(t, dtype=np.float32)))
            max_t = max(max_t, len(slots[-1]))
        thr_lists.append(slots)
    T = _round_up(max_t, _PAD)

    max_l = max(max(st.tree.n_leaves for st in pdt.subtrees), 1)
    L = _round_up(max_l, _PAD)

    thresholds = np.full((S, k, T), np.inf, dtype=np.float32)
    leaf_lo = np.zeros((S, L, k), dtype=np.int32)
    leaf_hi = np.full((S, L, k), T, dtype=np.int32)
    leaf_action = np.full((S, L), -1, dtype=np.int32)
    leaf_valid = np.zeros((S, L), dtype=bool)

    for st in pdt.subtrees:
        s = st.sid
        used = list(map(int, st.used_features))
        fid_to_slot = {fid: j for j, fid in enumerate(used)}
        for j, tlist in enumerate(thr_lists[s]):
            thresholds[s, j, :len(tlist)] = tlist
        # walk root->leaf accumulating slot-local mark intervals
        t = st.tree
        li = 0

        def walk(node: int, lo: np.ndarray, hi: np.ndarray):
            nonlocal li
            f = int(t.feature[node])
            if f < 0:
                leaf_lo[s, li] = lo
                leaf_hi[s, li] = hi
                nxt = st.leaf_next_sid.get(node, EXIT)
                if nxt == EXIT:
                    leaf_action[s, li] = S + st.leaf_label[node]
                else:
                    leaf_action[s, li] = nxt
                leaf_valid[s, li] = True
                li += 1
                return
            j = fid_to_slot[f]
            thr = float(t.threshold[node])
            tl = thr_lists[s][j]
            split_mark = int(np.searchsorted(tl, thr, side="left"))
            llo, lhi = lo.copy(), hi.copy()
            lhi[j] = min(lhi[j], split_mark)
            walk(int(t.left[node]), llo, lhi)
            rlo, rhi = lo.copy(), hi.copy()
            rlo[j] = max(rlo[j], split_mark + 1)
            walk(int(t.right[node]), rlo, rhi)

        walk(0, np.zeros(k, dtype=np.int32), np.full(k, T, dtype=np.int32))

    return RangeExecTables(
        thresholds=thresholds, leaf_lo=leaf_lo, leaf_hi=leaf_hi,
        leaf_action=leaf_action, leaf_valid=leaf_valid,
        n_subtrees=S, n_classes=pdt.n_classes,
    )

"""Flow-state store: the switch's register-indexing layer (paper §3.1).

Models how a Tofino-class pipeline locates per-flow state: the packet's
5-tuple is CRC32-hashed into a fixed register array of M slots.  SpliDT
keeps exactly (SID + counter + dependency chain + k feature registers)
per slot, so M is the concurrent-flow capacity the resource model trades
against k and bits.

This layer provides the scaling evidence the paper claims ("millions of
flows"): slot collisions vs. load factor, eviction behaviour, and the
recirculation-event time series that prices the in-band control channel.
The dense engine (`core/inference.py`) consumes flow-major blocks that
this store admits/evicts -- out-of-order packet arrival is handled here,
keeping the TPU hot path gather-free (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import zlib

import numpy as np


def crc32_hash(five_tuples: np.ndarray) -> np.ndarray:
    """CRC32 over packed 5-tuples (n, 5) uint32 -> uint32 hash."""
    ft = np.ascontiguousarray(five_tuples.astype(np.uint32))
    out = np.empty(ft.shape[0], dtype=np.uint32)
    for i in range(ft.shape[0]):
        out[i] = zlib.crc32(ft[i].tobytes()) & 0xFFFFFFFF
    return out


def random_five_tuples(n: int, rng: np.random.Generator) -> np.ndarray:
    """Synthetic (src_ip, dst_ip, src_port, dst_port, proto) tuples."""
    return np.stack([
        rng.integers(0, 2 ** 32, n, dtype=np.uint32),
        rng.integers(0, 2 ** 32, n, dtype=np.uint32),
        rng.integers(1024, 65536, n).astype(np.uint32),
        rng.integers(1, 1024, n).astype(np.uint32),
        rng.choice(np.asarray([6, 17], dtype=np.uint32), n),
    ], axis=1)


@dataclasses.dataclass
class StoreStats:
    n_flows: int
    capacity: int
    load_factor: float
    collisions: int             # flows hashed onto an occupied live slot
    collision_rate: float
    evictions: int


class FlowStore:
    """Hash-indexed slot table with SpliDT's per-flow register layout."""

    def __init__(self, capacity: int, k: int, seed: int = 0):
        self.capacity = int(capacity)
        self.k = int(k)
        self.slot_owner = np.full(self.capacity, -1, dtype=np.int64)
        self.sid = np.zeros(self.capacity, dtype=np.int32)
        self.pkt_count = np.zeros(self.capacity, dtype=np.int32)
        self.regs = np.zeros((self.capacity, k), dtype=np.float32)
        self.collisions = 0
        self.evictions = 0
        self._rng = np.random.default_rng(seed)

    def admit(self, flow_ids: np.ndarray, hashes: np.ndarray) -> np.ndarray:
        """Admit flows; returns slot index per flow (-1 if collided).

        A live collision mirrors switch behaviour: the new flow shares
        (and corrupts) the victim's registers; we count it and refuse the
        slot so accuracy accounting stays honest.
        """
        slots = (hashes % np.uint32(self.capacity)).astype(np.int64)
        out = np.full(flow_ids.shape[0], -1, dtype=np.int64)
        for i, (fid, s) in enumerate(zip(flow_ids, slots)):
            if self.slot_owner[s] == -1:
                self.slot_owner[s] = fid
                self.sid[s] = 0
                self.pkt_count[s] = 0
                self.regs[s] = 0.0
                out[i] = s
            elif self.slot_owner[s] == fid:
                out[i] = s
            else:
                self.collisions += 1
        return out

    def evict(self, slots: np.ndarray):
        live = slots[slots >= 0]
        self.slot_owner[live] = -1
        self.evictions += int(live.size)

    def stats(self) -> StoreStats:
        live = int((self.slot_owner >= 0).sum())
        return StoreStats(
            n_flows=live, capacity=self.capacity,
            load_factor=live / self.capacity,
            collisions=self.collisions,
            collision_rate=self.collisions / max(self.collisions + live, 1),
            evictions=self.evictions,
        )


def collision_curve(capacity: int, loads: list[float], seed: int = 0
                    ) -> list[tuple[float, float]]:
    """Collision rate vs. load factor for CRC-indexed admission."""
    rng = np.random.default_rng(seed)
    out = []
    for lf in loads:
        n = int(capacity * lf)
        store = FlowStore(capacity, k=4, seed=seed)
        ft = random_five_tuples(n, rng)
        h = crc32_hash(ft)
        store.admit(np.arange(n), h)
        out.append((lf, store.stats().collision_rate))
    return out

"""Recirculation-bandwidth model (paper §3.2.1, Tables 1 & 5).

Each flow issues one 64-byte control packet per partition transition
(window boundary that does not exit).  Aggregate in-band control traffic
for F concurrent flows is

    bw = F * E[transitions per flow] * pkt_bits / E[flow duration]

under steady-state churn (a flow's transitions are spread over its
lifetime; concurrency F is sustained by arrivals).  Transition counts
come from the model's *measured* inference trace (early exits reduce
them; single-partition models recirculate nothing, reproducing the
0.0 +- 0.0 rows of Table 5).

Environments follow the paper's two datacenter workloads (Roy et al.):
  WS (webserver): long-lived flows -> longer mean duration
  HD (hadoop):    short bursty mice flows -> ~2x the control-packet rate
Durations are calibrated so worst-case bandwidth lands in the paper's
range (<= ~60 Mbps at 1M flows, << 100 Gbps budget).
"""
from __future__ import annotations

import dataclasses

import numpy as np

CONTROL_PKT_BYTES = 64


@dataclasses.dataclass(frozen=True)
class Environment:
    name: str
    mean_flow_duration_s: float


WEBSERVER = Environment("WS", 60.0)
HADOOP = Environment("HD", 30.0)
ENVIRONMENTS = {"WS": WEBSERVER, "HD": HADOOP}


@dataclasses.dataclass
class RecircStats:
    mean_mbps: float
    std_mbps: float
    pkts_per_sec: float
    fraction_of_budget: float   # vs 100 Gbps recirculation path


def recirc_bandwidth(
    transitions_per_flow: np.ndarray,
    flows: int,
    env: Environment,
    *,
    budget_gbps: float = 100.0,
) -> RecircStats:
    """Bandwidth of the in-band control channel.

    ``transitions_per_flow``: measured per-flow transition counts from an
    inference trace (sampled flows; scaled to ``flows`` concurrent).
    """
    t = np.asarray(transitions_per_flow, dtype=np.float64)
    pkt_bits = CONTROL_PKT_BYTES * 8
    rate = flows / env.mean_flow_duration_s          # flow completions/s
    mean_bps = rate * t.mean() * pkt_bits
    std_bps = rate * t.std() * pkt_bits
    return RecircStats(
        mean_mbps=mean_bps / 1e6,
        std_mbps=std_bps / 1e6,
        pkts_per_sec=rate * t.mean(),
        fraction_of_budget=mean_bps / (budget_gbps * 1e9),
    )


def time_to_detection(
    packets: np.ndarray,
    lengths: np.ndarray,
    exit_partition: np.ndarray,
    n_partitions: int,
) -> np.ndarray:
    """Per-flow TTD: time from flow start to the end of the exit window
    (paper Fig. 10).  One-shot baselines detect at flow completion, i.e.
    ``exit_partition == n_partitions - 1`` for every flow."""
    from repro.core.features import PKT_TS
    from repro.flows.windows import window_bounds

    n = lengths.shape[0]
    ttd = np.zeros(n, dtype=np.float64)
    for i in range(n):
        if exit_partition[i] < 0:
            # -1 sentinel: the flow never took an exit action, so it has
            # no detection time — NaN, not the last window's end (Python
            # negative indexing would silently report a plausible TTD)
            ttd[i] = np.nan
            continue
        L = int(lengths[i])
        bounds = window_bounds(L, n_partitions)
        _, hi = bounds[int(exit_partition[i])]
        t_end = packets[i, min(hi, L) - 1, PKT_TS]
        ttd[i] = float(t_end - packets[i, 0, PKT_TS])
    return ttd

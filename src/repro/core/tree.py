"""Histogram-based CART decision-tree trainer (pure numpy).

Scikit-learn is unavailable offline, so SpliDT's subtree learner is
implemented from scratch: quantile-binned features + per-node class
histograms, Gini-gain splits, and -- the SpliDT-specific part -- a hard
budget of at most ``k`` *distinct* features per tree (paper §2.2
"feature density": every subtree must fit in the k feature-register
slots).  Once the tree has consumed k distinct features, further splits
may only reuse those features.

The tree is stored as flat arrays so it can be packed for the JAX/Pallas
engine (``core/tables.py``).

Cross-trainer contract
----------------------
This module is the **oracle** for ``repro.fit`` (the jitted
level-synchronous grower).  Both trainers must produce *structurally
identical* trees -- same ``feature``/``threshold``/``left``/``right``/
``value`` arrays -- so DSE results are reproducible whichever trainer
ran them.  The contract, stated once here and mirrored exactly in
``repro.fit.hist``:

1. **Binning**: :func:`quantile_bins` + :func:`bin_data`.  Bin ``b``
   for feature ``j`` means ``edges[j][b-1] < x <= edges[j][b]``
   (``np.searchsorted(edges, x, side="left")``), so the split
   "bins [0..e] go left" is exactly ``x <= edges[j][e]`` on raw values.
2. **Scoring**: :func:`split_scores` / :func:`node_impurity` -- the
   weighted-Gini child impurity evaluated in **float32** with the
   class-axis reduction pinned to a left-to-right chain
   (:func:`class_sq_chain`).  Integer counts below 2**24 are exact in
   f32 and IEEE-754 +,-,*,/ round identically in numpy and XLA, so the
   two trainers compare *the same bits*.
3. **Tie-break**: within a feature, the lowest bin index among minimal
   child impurities (first ``argmin``); across features, the lowest
   feature index among maximal gains (first ``argmax``).  A split must
   *strictly* beat ``min_gain`` (compared in f32).
4. **Growth order**: level-synchronous (BFS).  Nodes are numbered in
   level order, left child before right; the greedy tree-wide
   ``k_features`` budget admits new features in that same order --
   the budget state a node sees is the state after every node above it
   and to its left has been decided.

``docs/PARITY.md`` states the contract for reviewers; the zero-tolerance
structural-parity property tests live in ``tests/test_fit.py``.
"""
from __future__ import annotations

import collections
import dataclasses

import numpy as np

MAX_BINS = 64  # quantile bins per feature


@dataclasses.dataclass
class Tree:
    """Flat-array binary decision tree.

    Node 0 is the root.  For internal nodes ``feature/threshold`` define
    ``x[feature] <= threshold -> left else right``.  Leaves have
    ``feature == -1`` and carry a class distribution.  Nodes are
    numbered in level (BFS) order, left before right, so parents always
    precede children.
    """

    feature: np.ndarray      # (n_nodes,) int32, -1 for leaf
    threshold: np.ndarray    # (n_nodes,) float32
    left: np.ndarray         # (n_nodes,) int32
    right: np.ndarray        # (n_nodes,) int32
    value: np.ndarray        # (n_nodes, n_classes) float32 class counts
    n_classes: int

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int((self.feature < 0).sum())

    @property
    def max_depth(self) -> int:
        depth = np.zeros(self.n_nodes, dtype=np.int32)
        for i in range(self.n_nodes):      # parents precede children
            if self.feature[i] >= 0:
                depth[self.left[i]] = depth[i] + 1
                depth[self.right[i]] = depth[i] + 1
        return int(depth.max(initial=0))

    def used_features(self) -> np.ndarray:
        f = self.feature[self.feature >= 0]
        return np.unique(f)

    def thresholds_per_feature(self) -> dict[int, np.ndarray]:
        out: dict[int, np.ndarray] = {}
        for fid in self.used_features():
            thr = self.threshold[self.feature == fid]
            out[int(fid)] = np.unique(thr.astype(np.float32))
        return out

    def apply(self, X: np.ndarray) -> np.ndarray:
        """Leaf index for each row of ``X`` (n, n_features)."""
        n = X.shape[0]
        node = np.zeros(n, dtype=np.int32)
        active = self.feature[node] >= 0
        while active.any():
            idx = np.nonzero(active)[0]
            nd = node[idx]
            f = self.feature[nd]
            thr = self.threshold[nd]
            go_left = X[idx, f] <= thr
            node[idx] = np.where(go_left, self.left[nd], self.right[nd])
            active = self.feature[node] >= 0
        return node

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        leaves = self.apply(X)
        v = self.value[leaves]
        s = v.sum(axis=1, keepdims=True)
        return v / np.maximum(s, 1e-9)

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.value[self.apply(X)].argmax(axis=1)


# ---------------------------------------------------------------------------
# binning (contract item 1)
# ---------------------------------------------------------------------------
def quantile_bins(X: np.ndarray, max_bins: int) -> list[np.ndarray]:
    """Per-feature ascending candidate thresholds (bin edges)."""
    edges = []
    qs = np.linspace(0, 1, max_bins + 1)[1:-1]
    for j in range(X.shape[1]):
        col = X[:, j]
        e = np.unique(np.quantile(col, qs, method="lower").astype(np.float32))
        edges.append(e)
    return edges


def bin_data(X: np.ndarray, edges: list[np.ndarray]) -> np.ndarray:
    """Map raw features to bin ids: ``np.searchsorted(edges, x, 'left')``,
    so ``bin(x) <= e  <=>  x <= edges[e]`` exactly."""
    n, m = X.shape
    B = np.empty((n, m), dtype=np.int16)
    for j in range(m):
        B[:, j] = np.searchsorted(edges[j], X[:, j], side="left")
    return B


# PR-4-era private names, kept for external callers
_quantile_bins = quantile_bins
_bin_data = bin_data


# ---------------------------------------------------------------------------
# split scoring (contract items 2-3) -- mirrored by repro.fit.hist
# ---------------------------------------------------------------------------
def class_sq_chain(counts: np.ndarray) -> np.ndarray:
    """Left-to-right f32 chain of squared class counts over the last axis.

    The ONLY reduction in the split score whose order matters: f32
    addition is not associative, so the chain is pinned (the trainer
    analogue of ``kernels.ref.ordered_wsum``).  ``counts`` is integer
    (exact in f32 below 2**24); the result is the ``sum_c counts[c]^2``
    term of the Gini impurity.
    """
    acc = np.zeros(counts.shape[:-1], dtype=np.float32)
    for c in range(counts.shape[-1]):
        x = counts[..., c].astype(np.float32)
        acc = acc + x * x
    return acc


def split_scores(hist: np.ndarray, total: np.ndarray) -> np.ndarray:
    """Weighted-Gini child impurity per split edge for one node×feature.

    ``hist``: (n_bins, n_classes) integer class counts per bin;
    ``total``: (n_classes,) node class counts.  Splitting at edge ``e``
    sends bins ``[0..e]`` left.  Returns (n_bins,) f32 child impurity,
    ``+inf`` where a side would be empty.  Lower is better; the parent
    impurity (:func:`node_impurity`) is a per-node constant, so
    ``gain = parent - child``.
    """
    cum = np.cumsum(hist.astype(np.int64), axis=0)      # (n_bins, C) left
    nl = cum.sum(axis=1)                                # (n_bins,)
    n = int(total.sum())
    nr = n - nl
    sl = class_sq_chain(cum)
    sr = class_sq_chain(total[None, :].astype(np.int64) - cum)
    nl_f = nl.astype(np.float32)
    nr_f = nr.astype(np.float32)
    one = np.float32(1.0)
    child = ((nl_f - sl / np.maximum(nl_f, one))
             + (nr_f - sr / np.maximum(nr_f, one)))
    return np.where((nl > 0) & (nr > 0), child,
                    np.float32(np.inf)).astype(np.float32)


def node_impurity(total: np.ndarray) -> np.float32:
    """f32 Gini "impurity mass" ``n - (sum_c total_c^2) / n`` of a node."""
    n_f = np.float32(int(total.sum()))
    st = class_sq_chain(np.asarray(total, dtype=np.int64))
    return np.float32(n_f - st / np.maximum(n_f, np.float32(1.0)))


def train_tree(
    X: np.ndarray,
    y: np.ndarray,
    *,
    max_depth: int,
    k_features: int | None = None,
    allowed_features: np.ndarray | None = None,
    n_classes: int | None = None,
    min_samples_leaf: int = 4,
    min_gain: float = 1e-7,
    max_bins: int = MAX_BINS,
) -> Tree:
    """Train a CART tree with an optional distinct-feature budget.

    ``k_features``: max distinct features in the whole tree (SpliDT
    subtree register budget), enforced greedily in level order: once k
    distinct features have been used anywhere in the tree, only those
    features remain candidates.  ``allowed_features`` restricts
    candidates up-front (used for the top-k baselines).

    Fully deterministic -- no RNG is consumed anywhere.  Tie-break (the
    cross-trainer contract with ``repro.fit``; see the module
    docstring): within a feature the lowest bin index wins, across
    features the lowest feature index wins, and both trainers evaluate
    the f32 :func:`split_scores` so the comparisons see identical bits.
    """
    X = np.asarray(X, dtype=np.float32)
    y = np.asarray(y, dtype=np.int64)
    n, m = X.shape
    C = int(n_classes if n_classes is not None else y.max() + 1)
    allowed_mask = np.zeros(m, dtype=bool)
    if allowed_features is None:
        allowed_mask[:] = True
    else:
        allowed_mask[np.asarray(allowed_features, dtype=np.int64)] = True

    edges = quantile_bins(X, max_bins)
    B = bin_data(X, edges)
    min_gain32 = np.float32(min_gain)

    feature: list[int] = []
    threshold: list[float] = []
    left: list[int] = []
    right: list[int] = []
    value: list[np.ndarray] = []

    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(-1)
        right.append(-1)
        value.append(np.zeros(C, dtype=np.float32))
        return len(feature) - 1

    # global distinct-feature budget, grown greedily in level order
    used_mask = np.zeros(m, dtype=bool)

    # BFS frontier: (rows, depth, parent, is_left).  FIFO order = level
    # order, left before right -- node ids and budget-acquisition order
    # both follow it (contract item 4).
    queue = collections.deque([(np.arange(n), 0, -1, False)])
    while queue:
        rows, depth, parent, is_left = queue.popleft()
        node_id = new_node()
        if parent >= 0:
            if is_left:
                left[parent] = node_id
            else:
                right[parent] = node_id
        yb = y[rows]
        total = np.bincount(yb, minlength=C).astype(np.int64)
        value[node_id] = total.astype(np.float32)
        n_node = rows.shape[0]
        pure = (total > 0).sum() <= 1
        if depth >= max_depth or pure or n_node < 2 * min_samples_leaf:
            continue

        # candidate features under the budget
        budget_open = (k_features is None
                       or int(used_mask.sum()) < k_features)
        cand_mask = allowed_mask if budget_open else (allowed_mask & used_mask)

        parent_imp = node_impurity(total)
        gains = np.full(m, -np.inf, dtype=np.float32)
        best_bin = np.zeros(m, dtype=np.int64)
        best_nl = np.zeros(m, dtype=np.int64)
        for j in np.nonzero(cand_mask)[0]:
            j = int(j)
            nb = len(edges[j]) + 1
            bj = B[rows, j].astype(np.int64)
            hist = np.zeros((nb, C), dtype=np.int64)
            np.add.at(hist, (bj, yb), 1)
            child = split_scores(hist, total)
            e = int(np.argmin(child))               # first min: lowest bin
            gains[j] = parent_imp - child[e]        # -inf when child is inf
            best_bin[j] = e
            best_nl[j] = hist[:e + 1].sum()
        j = int(np.argmax(gains))                   # first max: lowest feature
        gain = gains[j]
        if not (gain > min_gain32):
            continue
        e = int(best_bin[j])
        nl = int(best_nl[j])
        if nl < min_samples_leaf or n_node - nl < min_samples_leaf:
            continue
        thr = float(edges[j][e])
        go_left = X[rows, j] <= thr                 # == (bin <= e), exactly

        feature[node_id] = j
        threshold[node_id] = thr
        used_mask[j] = True
        queue.append((rows[go_left], depth + 1, node_id, True))
        queue.append((rows[~go_left], depth + 1, node_id, False))

    return Tree(
        feature=np.asarray(feature, dtype=np.int32),
        threshold=np.asarray(threshold, dtype=np.float32),
        left=np.asarray(left, dtype=np.int32),
        right=np.asarray(right, dtype=np.int32),
        value=np.stack(value).astype(np.float32),
        n_classes=C,
    )


def feature_importance(X: np.ndarray, y: np.ndarray, *, max_depth: int = 12,
                       n_classes: int | None = None) -> np.ndarray:
    """Impurity-based importances from one unconstrained tree (used by the
    top-k baselines to pick their global feature set)."""
    t = train_tree(X, y, max_depth=max_depth, n_classes=n_classes)
    imp = np.zeros(X.shape[1], dtype=np.float64)
    totals = t.value.sum(axis=1)

    def gini(v):
        s = v.sum()
        if s <= 0:
            return 0.0
        p = v / s
        return 1.0 - (p ** 2).sum()

    for i in range(t.n_nodes):
        f = t.feature[i]
        if f < 0:
            continue
        l, r = t.left[i], t.right[i]
        w, wl, wr = totals[i], totals[l], totals[r]
        imp[f] += w * gini(t.value[i]) - wl * gini(t.value[l]) - wr * gini(t.value[r])
    s = imp.sum()
    return imp / s if s > 0 else imp


def macro_f1(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int) -> float:
    """Macro-averaged F1 (paper's headline metric).

    Vectorised -- one ``np.bincount`` over the joint (true, pred) index
    builds the whole confusion matrix; it sits on the DSE hot path (one
    call per candidate evaluation).  Out-of-range predictions (e.g. the
    engine's ``-1`` non-termination sentinel) fall into an overflow bin:
    they are a false negative for their true class and a true positive
    for nothing, exactly as the per-class loop scored them.
    """
    yt = np.asarray(y_true, dtype=np.int64).ravel()
    yp = np.asarray(y_pred, dtype=np.int64).ravel()
    C = int(n_classes)
    t = np.where((yt >= 0) & (yt < C), yt, C)
    p = np.where((yp >= 0) & (yp < C), yp, C)
    cm = np.bincount(t * (C + 1) + p,
                     minlength=(C + 1) ** 2).reshape(C + 1, C + 1)
    tp = np.diag(cm)[:C].astype(np.float64)
    fp = cm[:, :C].sum(axis=0) - np.diag(cm)[:C]
    fn = cm[:C, :].sum(axis=1) - np.diag(cm)[:C]
    seen = (tp + fp + fn) > 0
    if not seen.any():
        return 0.0
    with np.errstate(divide="ignore", invalid="ignore"):
        prec = np.where(tp + fp > 0, tp / (tp + fp), 0.0)
        rec = np.where(tp + fn > 0, tp / (tp + fn), 0.0)
        f1 = np.where(prec + rec > 0, 2 * prec * rec / (prec + rec), 0.0)
    return float(np.mean(f1[seen]))

"""Histogram-based CART decision-tree trainer (pure numpy).

Scikit-learn is unavailable offline, so SpliDT's subtree learner is
implemented from scratch: quantile-binned features + per-node class
histograms, Gini-gain splits, and -- the SpliDT-specific part -- a hard
budget of at most ``k`` *distinct* features per tree (paper §2.2
"feature density": every subtree must fit in the k feature-register
slots).  Once a branch has consumed k distinct features, further splits
on that branch may only reuse those features.

The tree is stored as flat arrays so it can be packed for the JAX/Pallas
engine (``core/tables.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

MAX_BINS = 64  # quantile bins per feature


@dataclasses.dataclass
class Tree:
    """Flat-array binary decision tree.

    Node 0 is the root.  For internal nodes ``feature/threshold`` define
    ``x[feature] <= threshold -> left else right``.  Leaves have
    ``feature == -1`` and carry a class distribution.
    """

    feature: np.ndarray      # (n_nodes,) int32, -1 for leaf
    threshold: np.ndarray    # (n_nodes,) float32
    left: np.ndarray         # (n_nodes,) int32
    right: np.ndarray        # (n_nodes,) int32
    value: np.ndarray        # (n_nodes, n_classes) float32 class counts
    n_classes: int

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_leaves(self) -> int:
        return int((self.feature < 0).sum())

    @property
    def max_depth(self) -> int:
        depth = np.zeros(self.n_nodes, dtype=np.int32)
        for i in range(self.n_nodes):      # parents precede children
            if self.feature[i] >= 0:
                depth[self.left[i]] = depth[i] + 1
                depth[self.right[i]] = depth[i] + 1
        return int(depth.max(initial=0))

    def used_features(self) -> np.ndarray:
        f = self.feature[self.feature >= 0]
        return np.unique(f)

    def thresholds_per_feature(self) -> dict[int, np.ndarray]:
        out: dict[int, np.ndarray] = {}
        for fid in self.used_features():
            thr = self.threshold[self.feature == fid]
            out[int(fid)] = np.unique(thr.astype(np.float32))
        return out

    def apply(self, X: np.ndarray) -> np.ndarray:
        """Leaf index for each row of ``X`` (n, n_features)."""
        n = X.shape[0]
        node = np.zeros(n, dtype=np.int32)
        active = self.feature[node] >= 0
        while active.any():
            idx = np.nonzero(active)[0]
            nd = node[idx]
            f = self.feature[nd]
            thr = self.threshold[nd]
            go_left = X[idx, f] <= thr
            node[idx] = np.where(go_left, self.left[nd], self.right[nd])
            active = self.feature[node] >= 0
        return node

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        leaves = self.apply(X)
        v = self.value[leaves]
        s = v.sum(axis=1, keepdims=True)
        return v / np.maximum(s, 1e-9)

    def predict(self, X: np.ndarray) -> np.ndarray:
        return self.value[self.apply(X)].argmax(axis=1)


def _quantile_bins(X: np.ndarray, max_bins: int) -> list[np.ndarray]:
    """Per-feature ascending candidate thresholds (bin edges)."""
    edges = []
    qs = np.linspace(0, 1, max_bins + 1)[1:-1]
    for j in range(X.shape[1]):
        col = X[:, j]
        e = np.unique(np.quantile(col, qs, method="lower").astype(np.float32))
        edges.append(e)
    return edges


def _bin_data(X: np.ndarray, edges: list[np.ndarray]) -> np.ndarray:
    """Map raw features to bin ids: bin b means value <= edges[b] fails for
    all earlier edges; i.e. ``np.searchsorted(edges, x, 'left')``."""
    n, m = X.shape
    B = np.empty((n, m), dtype=np.int16)
    for j in range(m):
        B[:, j] = np.searchsorted(edges[j], X[:, j], side="left")
    return B


def _gini_gain_curves(hist: np.ndarray, total: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Best split position & impurity decrease for one feature.

    ``hist``: (n_bins, n_classes) class counts per bin; ``total``:
    (n_classes,).  Split at edge e sends bins [0..e] left.  Returns
    (best_edge_index, best_gain); gain is -inf if no valid split.
    """
    cum = np.cumsum(hist, axis=0)            # (n_bins, C) left counts
    nl = cum.sum(axis=1)                      # (n_bins,)
    n = total.sum()
    nr = n - nl
    valid = (nl > 0) & (nr > 0)
    # weighted Gini of children; parent impurity constant per node
    sl = (cum.astype(np.float64) ** 2).sum(axis=1)
    right = total[None, :] - cum
    sr = (right.astype(np.float64) ** 2).sum(axis=1)
    with np.errstate(divide="ignore", invalid="ignore"):
        child = (nl - sl / np.maximum(nl, 1)) + (nr - sr / np.maximum(nr, 1))
    child = np.where(valid, child, np.inf)
    e = int(np.argmin(child))
    if not valid[e]:
        return -1, -np.inf
    parent = n - (total.astype(np.float64) ** 2).sum() / max(n, 1)
    return e, float(parent - child[e])


@dataclasses.dataclass
class _BuildNode:
    rows: np.ndarray
    depth: int
    used: frozenset
    parent: int
    is_left: bool


def train_tree(
    X: np.ndarray,
    y: np.ndarray,
    *,
    max_depth: int,
    k_features: int | None = None,
    allowed_features: np.ndarray | None = None,
    n_classes: int | None = None,
    min_samples_leaf: int = 4,
    min_gain: float = 1e-7,
    max_bins: int = MAX_BINS,
    rng: np.random.Generator | None = None,
) -> Tree:
    """Train a CART tree with an optional distinct-feature budget.

    ``k_features``: max distinct features on any root-to-leaf path *and*
    in the whole tree (SpliDT subtree register budget).  Enforced
    greedily: after k distinct features have been used anywhere in the
    tree, only those features remain candidates.  ``allowed_features``
    restricts candidates up-front (used for the top-k baselines).
    """
    X = np.asarray(X, dtype=np.float32)
    y = np.asarray(y, dtype=np.int64)
    n, m = X.shape
    C = int(n_classes if n_classes is not None else y.max() + 1)
    if allowed_features is None:
        allowed = np.arange(m)
    else:
        allowed = np.asarray(allowed_features, dtype=np.int64)

    edges = _quantile_bins(X, max_bins)
    B = _bin_data(X, edges)

    feature: list[int] = []
    threshold: list[float] = []
    left: list[int] = []
    right: list[int] = []
    value: list[np.ndarray] = []

    def new_node() -> int:
        feature.append(-1)
        threshold.append(0.0)
        left.append(-1)
        right.append(-1)
        value.append(np.zeros(C, dtype=np.float32))
        return len(feature) - 1

    # global distinct-feature budget, grown greedily as the tree is built
    tree_used: set[int] = set()

    stack = [_BuildNode(np.arange(n), 0, frozenset(), -1, False)]
    root = None
    while stack:
        nd = stack.pop()
        node_id = new_node()
        if root is None:
            root = node_id
        if nd.parent >= 0:
            if nd.is_left:
                left[nd.parent] = node_id
            else:
                right[nd.parent] = node_id
        rows = nd.rows
        counts = np.bincount(y[rows], minlength=C).astype(np.float32)
        value[node_id] = counts
        pure = (counts > 0).sum() <= 1
        if nd.depth >= max_depth or pure or rows.shape[0] < 2 * min_samples_leaf:
            continue

        # candidate features under the budget
        if k_features is not None and len(tree_used) >= k_features:
            cand = np.asarray(sorted(tree_used), dtype=np.int64)
        else:
            cand = allowed
        cand = cand[[len(edges[int(j)]) > 0 for j in cand]]
        if cand.size == 0:
            continue

        yb = y[rows]
        total = np.bincount(yb, minlength=C).astype(np.int64)
        best = (-np.inf, -1, -1)  # gain, feature, edge
        for j in cand:
            j = int(j)
            nb = len(edges[j]) + 1
            bj = B[rows, j].astype(np.int64)
            hist = np.zeros((nb, C), dtype=np.int64)
            np.add.at(hist, (bj, yb), 1)
            e, gain = _gini_gain_curves(hist, total)
            if gain > best[0]:
                best = (gain, j, e)
        gain, j, e = best
        if j < 0 or gain <= min_gain:
            continue
        thr = float(edges[j][e])
        go_left = X[rows, j] <= thr
        nl = int(go_left.sum())
        if nl < min_samples_leaf or rows.shape[0] - nl < min_samples_leaf:
            continue

        feature[node_id] = j
        threshold[node_id] = thr
        tree_used.add(j)
        used = nd.used | {j}
        # push right first so left is materialised first (stable ids)
        stack.append(_BuildNode(rows[~go_left], nd.depth + 1, used, node_id, False))
        stack.append(_BuildNode(rows[go_left], nd.depth + 1, used, node_id, True))

    return Tree(
        feature=np.asarray(feature, dtype=np.int32),
        threshold=np.asarray(threshold, dtype=np.float32),
        left=np.asarray(left, dtype=np.int32),
        right=np.asarray(right, dtype=np.int32),
        value=np.stack(value).astype(np.float32),
        n_classes=C,
    )


def feature_importance(X: np.ndarray, y: np.ndarray, *, max_depth: int = 12,
                       n_classes: int | None = None) -> np.ndarray:
    """Impurity-based importances from one unconstrained tree (used by the
    top-k baselines to pick their global feature set)."""
    t = train_tree(X, y, max_depth=max_depth, n_classes=n_classes)
    imp = np.zeros(X.shape[1], dtype=np.float64)
    totals = t.value.sum(axis=1)

    def gini(v):
        s = v.sum()
        if s <= 0:
            return 0.0
        p = v / s
        return 1.0 - (p ** 2).sum()

    for i in range(t.n_nodes):
        f = t.feature[i]
        if f < 0:
            continue
        l, r = t.left[i], t.right[i]
        w, wl, wr = totals[i], totals[l], totals[r]
        imp[f] += w * gini(t.value[i]) - wl * gini(t.value[l]) - wr * gini(t.value[r])
    s = imp.sum()
    return imp / s if s > 0 else imp


def macro_f1(y_true: np.ndarray, y_pred: np.ndarray, n_classes: int) -> float:
    """Macro-averaged F1 (paper's headline metric)."""
    f1s = []
    for c in range(n_classes):
        tp = int(((y_pred == c) & (y_true == c)).sum())
        fp = int(((y_pred == c) & (y_true != c)).sum())
        fn = int(((y_pred != c) & (y_true == c)).sum())
        if tp + fp + fn == 0:
            continue
        prec = tp / (tp + fp) if tp + fp else 0.0
        rec = tp / (tp + fn) if tp + fn else 0.0
        f1s.append(0.0 if prec + rec == 0 else 2 * prec * rec / (prec + rec))
    return float(np.mean(f1s)) if f1s else 0.0

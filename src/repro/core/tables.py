"""Packed device tables for the partitioned-DT inference engine.

The data plane stores a partitioned DT as dense, SID-indexed tables
(paper Fig. 4): operator-selection tables (which op/field/predicate each
of the k register slots runs for the active subtree), and the model
tables (node compare-and-descend programs + per-leaf routing).  This
module packs a trained :class:`PartitionedDT` into flat numpy arrays the
JAX engine / Pallas kernels consume.

Encoding (S = #subtrees, M = max nodes over subtrees, k = slots):
  node_feat_slot (S, M) int32: local slot [0..k) for internal, -1 leaf
  node_thresh    (S, M) f32
  node_left/right(S, M) int32
  leaf_next_sid  (S, M) int32: next SID, or -1 for exit
  leaf_label     (S, M) int32
  slot_fid       (S, k) int32: global feature id per slot (-1 unused)
  slot_op        (S, k) int32   | per-slot op codes (operator-selection
  slot_field     (S, k) int32   | MAT contents, keyed by SID)
  slot_pred      (S, k) int32   |
  slot_init      (S, k) f32: register init value (0, or +inf for MIN)
  sid_partition  (S,) int32
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.features import REGISTRY
from repro.core.partition import EXIT, PartitionedDT


@dataclasses.dataclass
class PackedTables:
    node_feat_slot: np.ndarray
    node_thresh: np.ndarray
    node_left: np.ndarray
    node_right: np.ndarray
    leaf_next_sid: np.ndarray
    leaf_label: np.ndarray
    slot_fid: np.ndarray
    slot_op: np.ndarray
    slot_field: np.ndarray
    slot_pred: np.ndarray
    slot_init: np.ndarray
    sid_partition: np.ndarray
    n_partitions: int
    k: int
    max_depth: int      # max subtree depth (traversal iteration bound)

    @property
    def n_subtrees(self) -> int:
        return int(self.node_feat_slot.shape[0])

    @property
    def max_nodes(self) -> int:
        return int(self.node_feat_slot.shape[1])


def pack_tables(pdt: PartitionedDT) -> PackedTables:
    S = len(pdt.subtrees)
    M = max(max(st.tree.n_nodes for st in pdt.subtrees), 2)
    k = pdt.k

    node_feat_slot = np.full((S, M), -1, dtype=np.int32)
    node_thresh = np.zeros((S, M), dtype=np.float32)
    node_left = np.zeros((S, M), dtype=np.int32)
    node_right = np.zeros((S, M), dtype=np.int32)
    leaf_next_sid = np.full((S, M), EXIT, dtype=np.int32)
    # -1 sentinel on non-leaf rows (docs/PARITY.md §2); only leaf rows
    # are ever written with a real class below
    leaf_label = np.full((S, M), -1, dtype=np.int32)
    slot_fid = np.full((S, k), -1, dtype=np.int32)
    slot_op = np.zeros((S, k), dtype=np.int32)
    slot_field = np.zeros((S, k), dtype=np.int32)
    slot_pred = np.zeros((S, k), dtype=np.int32)
    slot_init = np.zeros((S, k), dtype=np.float32)
    sid_partition = np.zeros(S, dtype=np.int32)

    for st in pdt.subtrees:
        s = st.sid
        t = st.tree
        sid_partition[s] = st.partition
        used = list(map(int, st.used_features))
        if len(used) > k:
            raise ValueError(f"subtree {s} uses {len(used)} > k={k} features")
        fid_to_slot = {fid: j for j, fid in enumerate(used)}
        for j, fid in enumerate(used):
            spec = REGISTRY[fid]
            slot_fid[s, j] = fid
            slot_op[s, j] = spec.op
            slot_field[s, j] = spec.field
            slot_pred[s, j] = spec.pred
            slot_init[s, j] = spec.init_value
        for i in range(t.n_nodes):
            f = int(t.feature[i])
            if f >= 0:
                node_feat_slot[s, i] = fid_to_slot[f]
                node_thresh[s, i] = t.threshold[i]
                node_left[s, i] = t.left[i]
                node_right[s, i] = t.right[i]
            else:
                leaf_next_sid[s, i] = st.leaf_next_sid.get(i, EXIT)
                leaf_label[s, i] = st.leaf_label.get(i, -1)

    return PackedTables(
        node_feat_slot=node_feat_slot, node_thresh=node_thresh,
        node_left=node_left, node_right=node_right,
        leaf_next_sid=leaf_next_sid, leaf_label=leaf_label,
        slot_fid=slot_fid, slot_op=slot_op, slot_field=slot_field,
        slot_pred=slot_pred, slot_init=slot_init,
        sid_partition=sid_partition,
        n_partitions=pdt.n_partitions, k=k,
        max_depth=max(st.tree.max_depth for st in pdt.subtrees),
    )

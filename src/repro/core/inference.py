"""The partitioned-inference engine (paper Fig. 4, TPU-native).

Orchestrates the two data-plane phases per partition window:
  1. Feature Collection & Engineering — fill the k registers for each
     flow's active subtree (``kernels.ops``);
  2. Subtree Model Prediction — range-mark the registers and emit the
     action (next SID or exit class).
Between partitions the engine performs the "recirculation": SID update +
register reset, counted per flow for the bandwidth model.

Execution is unified behind the :class:`ExecutionBackend` protocol —
one device-resident partition walk (:func:`partition_walk`, a single
jitted ``jax.lax.scan`` over partitions) parameterised by the per-stage
step function:

* **fused** — dense jnp step (``ops.fused_step``): per-flow gathers of
  the SID-keyed tables, everything in one XLA computation.
* **pallas** (interpret mode off-TPU) — the Pallas kernels behind the
  in-jit SID dispatch (``ops.fused_step_pallas``):
  flows are argsorted/scattered into SID-homogeneous capacity blocks
  *inside* jit, so the MoE-style grouping costs zero host round trips
  and the walk still crosses the device→host boundary exactly once per
  batch.
* **looped** — host-side Python loop with a per-partition sync; the
  benchmark baseline and the per-op dispatch point.

All backends share :class:`EngineResult` semantics and must agree with
:meth:`PartitionedDT.predict` (the offline numpy oracle) — and, since
``kernels.ref.ordered_wsum`` pinned the reduction order, they agree
bit-exactly; property tests enforce this for every backend.  A flow
that never takes an exit action reports ``-1`` sentinels (labels and
exit partition) rather than masquerading as class 0 at partition 0;
``EngineResult.n_unterminated`` counts them.

Every backend also accepts ``compact=True``: early-exit compaction of
the recirculation walk (``kernels.compaction``) — after each hop only
the surviving flows are carried through feature-window rebuild +
traversal, via static power-of-two capacity buckets in-jit (walk
backends) or host fancy-indexing (looped).  Bit-identical to the dense
walk; ``compact=False`` remains the reference path.

Backend selection: ``Engine.run(win_pkts, impl=...)`` or the engine's
``impl=`` field; see :func:`get_backend` for the selection matrix.
``impl="auto"`` routes through the analytical cost model and
``impl="tuned"`` through the cached empirical autotuner
(``repro.tuning``) — both resolve a ``Plan`` (backend, Pallas
``block_b``, compaction + ladder floor) for the batch shape at hand and
attach it to ``EngineResult.plan``.  docs/ARCHITECTURE.md has the
end-to-end tour; docs/PARITY.md states the bit-exactness contract that
makes routing a pure speed decision.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionedDT
from repro.core.range_tables import RangeExecTables, pack_range_exec
from repro.core.tables import PackedTables, pack_tables
from repro.kernels import compaction, ops
from repro import obs


@dataclasses.dataclass
class EngineResult:
    labels: np.ndarray           # (B,) predicted class per flow; -1 if the
                                 #     flow never took an exit action
    recircs: np.ndarray          # (B,) partition transitions (control pkts)
    exit_partition: np.ndarray   # (B,) exit hop per flow; -1 sentinel as above
    regs_trace: list[np.ndarray] # per-partition register snapshots
    plan: "object | None" = None # repro.tuning.Plan when impl="auto"/"tuned"
                                 # resolved the backend; None for forced impls

    @property
    def n_unterminated(self) -> int:
        """Flows that never took an exit action (``-1`` sentinels).

        Non-zero only for corrupt/truncated models (e.g. depth-truncated
        DSE candidates whose final partition still routes to a SID) —
        a trained :class:`PartitionedDT` exits every flow by the last
        partition.  Surfaced so callers can distinguish "class 0 at
        partition 0" from "the walk fell off the end".
        """
        return int(np.count_nonzero(np.asarray(self.exit_partition) < 0))


# one partition stage (defined next to DeviceTables; re-exported here
# because backends and the streaming scheduler type against it)
StepFn = ops.StepFn


# ---------------------------------------------------------------------------
# engine options — every execution knob in one frozen bag
# ---------------------------------------------------------------------------

_IMPLS = (None, "auto", "tuned", "ref", "fused", "pallas", "looped")


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    """All engine execution knobs, in one frozen value.

    ``Engine.run`` / ``run_looped`` / ``run_streaming`` and the serving
    layer (``repro.serve``) all accept ``options=EngineOptions(...)``;
    each entry point reads the knobs that apply to it and ignores the
    rest (e.g. ``Engine.run`` never micro-batches, so ``micro_batch``
    is inert there).  The legacy per-call keywords (``impl=``,
    ``compact=``, ``mesh=``, ...) still work but emit a
    ``DeprecationWarning`` and cannot be mixed with ``options=``.

    ===============  =====================================================
    knob             meaning
    ===============  =====================================================
    impl             backend request: ``None`` (engine default), a fixed
                     backend (``fused``/``ref``/``pallas``/``looped``),
                     ``"auto"`` (cost model) or ``"tuned"`` (autotune
                     cache) — see ``repro.tuning``
    plan             a pre-resolved ``repro.tuning.Plan``; wins over
                     ``impl``/``compact``/``block_b`` (the plan already
                     carries all three)
    compact          early-exit compaction: True/False pinned, or
                     ``"auto"`` (the routing plan decides)
    compact_floor    smallest capacity bucket of the compaction ladder
    block_b          Pallas flow-block rows (None = kernel default;
                     only read when the resolved backend is pallas)
    micro_batch      streaming/serving chunk size (flows per dispatch)
    inflight         streaming pipeline depth (chunks in flight)
    donate           donate packet buffers to the walk (None = off-CPU)
    mesh             ``jax.sharding.Mesh`` to shard the flow axis over
    ===============  =====================================================
    """
    impl: str | None = None
    plan: "object | None" = None
    compact: bool | str = False
    compact_floor: int = compaction.COMPACT_FLOOR
    block_b: int | None = None
    micro_batch: int = 4096
    inflight: int = 2
    donate: bool | None = None
    mesh: "object | None" = None

    def __post_init__(self):
        if self.impl not in _IMPLS:
            raise ValueError(f"unknown impl {self.impl!r}; options: "
                             + ", ".join(str(i) for i in _IMPLS))
        if self.compact not in (True, False, "auto"):
            raise ValueError(
                f"compact must be True, False or 'auto', got {self.compact!r}")
        if self.compact_floor <= 0:
            raise ValueError("compact_floor must be positive")
        if self.block_b is not None and self.block_b <= 0:
            raise ValueError("block_b must be positive")
        if self.micro_batch <= 0:
            raise ValueError("micro_batch must be positive")
        if self.inflight <= 0:
            raise ValueError("inflight must be positive")

    def replace(self, **changes) -> "EngineOptions":
        """``dataclasses.replace`` as a method (frozen-friendly)."""
        return dataclasses.replace(self, **changes)


#: Sentinel distinguishing "legacy keyword not passed" from any real
#: value (None is meaningful for several knobs).
_UNSET = object()


def _legacy_options(options: EngineOptions | None, legacy: dict,
                    *, stacklevel: int = 3) -> EngineOptions:
    """Fold explicitly-passed legacy keywords into an EngineOptions.

    The deprecation shim shared by ``Engine.run``/``run_looped``/
    ``run_streaming`` and ``repro.serve.streaming``: legacy keywords
    still work (every pre-EngineOptions call site keeps its behaviour)
    but warn once per call site, and mixing them with ``options=`` is
    an error rather than a silent precedence rule.
    """
    passed = {key: v for key, v in legacy.items() if v is not _UNSET}
    if not passed:
        return options if options is not None else EngineOptions()
    if options is not None:
        raise ValueError(
            "pass options=EngineOptions(...) OR legacy keyword(s) "
            f"({', '.join(sorted(passed))}), not both")
    warnings.warn(
        "keyword(s) " + ", ".join(sorted(passed)) + " are deprecated; "
        "use options=EngineOptions(...) instead",
        DeprecationWarning, stacklevel=stacklevel)
    return EngineOptions(**passed)


def _walk_init(B: int) -> tuple[jnp.ndarray, ...]:
    """Initial flow-walk carry: ``(sid, done, labels, recircs, exit_p)``.

    ``labels`` / ``exit_partition`` start at the ``-1`` sentinel so a
    flow that never takes an exit action (non-terminating: corrupt
    tables, depth-truncated DSE candidates) is distinguishable from a
    legitimate class-0 verdict at partition 0.
    """
    return (
        jnp.zeros(B, jnp.int32),            # sid: all flows start at root
        jnp.zeros(B, jnp.bool_),            # done
        jnp.full(B, -1, jnp.int32),         # labels (sentinel)
        jnp.zeros(B, jnp.int32),            # recircs
        jnp.full(B, -1, jnp.int32),         # exit_partition (sentinel)
    )


def _hop_update(carry, p, action, S: int):
    """Shared recirculation bookkeeping for one hop (dense or compacted).

    ``action`` slots belonging to already-``done`` flows may carry any
    value (the compacted step leaves ``-1`` there) — everything is
    masked by ``active``.
    """
    sid, done, labels, recircs, exit_p = carry
    is_exit = action >= S
    active = ~done
    exiting = active & is_exit
    labels = jnp.where(exiting, action - S, labels)
    exit_p = jnp.where(exiting, p, exit_p)
    done = done | exiting
    cont = active & ~is_exit
    # recirculation: one control packet per transition, SID register
    # update; feature registers are rebuilt from scratch next window
    recircs = recircs + cont.astype(jnp.int32)
    sid = jnp.where(cont, action, sid)
    return sid, done, labels, recircs, exit_p


def _partition_walk(
    win_pkts: jnp.ndarray,       # (B, P, W, PKT_NFIELDS)
    dev: ops.DeviceTables,
    *,
    n_subtrees: int,
    with_trace: bool = False,
    step: StepFn = ops.fused_step,
    compact: bool = False,
    compact_floor: int = compaction.COMPACT_FLOOR,
):
    """Device-resident partition walk: scan partitions, carry flow state.

    Returns ``(labels, recircs, exit_partition, regs)`` — all int32
    except ``regs`` (P, B, k) f32, which is ``None`` unless
    ``with_trace``.  Actions ``>= n_subtrees`` exit with class
    ``action - n_subtrees``; smaller actions recirculate to that SID; a
    flow still active after the last partition keeps the ``-1``
    sentinels.  ``step`` is the backend's per-partition stage (dense jnp
    or Pallas kernels); the walk itself is backend-agnostic.

    With ``compact=True`` the walk early-exit-compacts between hops
    (``kernels.compaction``): survivors are gathered into the smallest
    power-of-two capacity bucket that fits them, the step runs on that
    prefix only, and verdicts scatter back to the original flow slots.
    Bit-identical to the dense walk; the register trace differs only in
    that exited flows report zero registers for the hops they skipped.
    """
    if compact:
        return _compacted_walk(win_pkts, dev, n_subtrees=n_subtrees,
                               with_trace=with_trace, step=step,
                               floor=compact_floor)
    B, P = win_pkts.shape[0], win_pkts.shape[1]
    S = n_subtrees

    def body(carry, xs):
        p, pkts = xs
        regs, action = step(pkts, carry[0], dev)
        return _hop_update(carry, p, action, S), (
            regs if with_trace else None)

    xs = (jnp.arange(P, dtype=jnp.int32), jnp.swapaxes(win_pkts, 0, 1))
    (sid, done, labels, recircs, exit_p), regs = jax.lax.scan(
        body, _walk_init(B), xs)
    return labels, recircs, exit_p, regs


def _compacted_walk(
    win_pkts: jnp.ndarray,       # (B, P, W, PKT_NFIELDS)
    dev: ops.DeviceTables,
    *,
    n_subtrees: int,
    with_trace: bool,
    step: StepFn,
    floor: int = compaction.COMPACT_FLOOR,
):
    """Early-exit-compacted walk: unrolled hops, shrinking active buffer.

    Hop 0 runs dense (every flow is active at the root); each later hop
    runs the step only on the compacted survivor prefix, in the smallest
    capacity bucket that fits (``lax.switch`` over a static power-of-two
    ladder ``(0, floor, 2*floor, …, B)`` — see ``kernels.compaction``).
    Unrolled rather than scanned because the per-hop buffer capacity is
    data-dependent; P is small (2-4 partitions), so the trace stays
    cheap.
    """
    B, P = win_pkts.shape[0], win_pkts.shape[1]
    caps = compaction.bucket_caps(B, floor)
    carry = _walk_init(B)
    trace = []
    for p in range(P):
        pkts = win_pkts[:, p]
        if p == 0:
            regs, action = step(pkts, carry[0], dev)
        else:
            regs, action = compaction.compacted_step(
                pkts, carry[0], carry[1], dev, step=step, caps=caps,
                with_regs=with_trace)
        carry = _hop_update(carry, p, action, n_subtrees)
        if with_trace:
            trace.append(regs)
    _, _, labels, recircs, exit_p = carry
    return labels, recircs, exit_p, (jnp.stack(trace) if with_trace
                                     else None)


_WALK_STATIC = ("n_subtrees", "with_trace", "step", "compact",
                "compact_floor")

partition_walk = jax.jit(_partition_walk, static_argnames=_WALK_STATIC)

# Donating the packet buffer lets back-to-back micro-batches reuse the
# same device allocation (streaming path).  CPU can't donate host numpy
# buffers usefully, so the streaming scheduler only picks this variant
# off-CPU.
partition_walk_donated = jax.jit(_partition_walk, static_argnames=_WALK_STATIC,
                                 donate_argnums=(0,))

# PR 1 names (step defaults to the dense jnp stage) — kept for callers
# that predate the backend layer.
fused_partition_walk = partition_walk
fused_partition_walk_donated = partition_walk_donated


# ---------------------------------------------------------------------------
# execution backends
# ---------------------------------------------------------------------------
@runtime_checkable
class ExecutionBackend(Protocol):
    """One engine execution strategy.

    Implementations must produce identical :class:`EngineResult`s (the
    shared correctness oracle is ``PartitionedDT.predict`` +
    ``kernels.ref``); they differ only in how the partition walk
    executes.  ``step`` is the jit-traceable per-partition stage for
    walk-based backends, or ``None`` when the backend does not run the
    shared walk (looped).
    """
    name: str
    step: StepFn | None

    def run(self, engine: "Engine", win_pkts: np.ndarray, *,
            with_trace: bool = True, compact: bool = False,
            compact_floor: int = compaction.COMPACT_FLOOR
            ) -> EngineResult: ...


def _record_walk(exit_p: np.ndarray, P: int, *, compact: bool,
                 compact_floor: int) -> None:
    """Per-hop survivor counts — and, when compacting, the capacity
    bucket each hop padded its survivors to — derived HOST-side from
    the already-fetched exit partitions.  A flow exiting at partition
    ``e`` is live for hops ``0..e``, so the survivor count entering
    hop ``p`` is ``B - |{exits < p}|``; no extra device work or syncs.
    """
    reg = obs.get_registry()
    B = int(exit_p.shape[0])
    exits = np.bincount(exit_p[exit_p >= 0], minlength=P)
    survivors = B - np.concatenate(([0], np.cumsum(exits)[:P - 1]))
    caps = compaction.bucket_caps(B, compact_floor) if compact else None
    for p in range(P):
        s = int(survivors[p])
        reg.counter(
            "engine_hop_survivors_total",
            "flows still walking when each hop starts",
            labels={"hop": str(p)}).inc(s)
        if caps is not None:
            cap = next(c for c in caps if c >= s)
            reg.counter(
                "engine_compact_bucket_total",
                "capacity-ladder bucket the hop's survivors padded to",
                labels={"hop": str(p), "cap": str(cap)}).inc()


@dataclasses.dataclass(frozen=True)
class WalkBackend:
    """Fully-jitted walk: ONE device→host transfer per batch.

    ``fused`` and ``pallas`` are both instances of this — they share the
    scan, the carry semantics, and the single ``jax.device_get``; only
    the per-partition ``step`` differs.
    """
    name: str
    step: StepFn

    def run(self, engine: "Engine", win_pkts: np.ndarray, *,
            with_trace: bool = True, compact: bool = False,
            compact_floor: int = compaction.COMPACT_FLOOR) -> EngineResult:
        P = engine._check_windows(win_pkts)
        with obs.span("engine/dispatch"):
            labels, recircs, exit_p, regs = partition_walk(
                jnp.asarray(win_pkts[:, :P]), engine.dev,
                n_subtrees=engine.ret.n_subtrees, with_trace=with_trace,
                step=self.step, compact=compact,
                compact_floor=compact_floor)
            obs.get_registry().counter(
                "engine_dispatches_total", "jitted walk calls issued",
                labels={"backend": self.name}).inc()
        with obs.span("engine/fetch"):
            # ONE device->host transfer for the whole batch
            labels, recircs, exit_p, regs = jax.device_get(
                (labels, recircs, exit_p, regs))
        _record_walk(np.asarray(exit_p), P, compact=compact,
                     compact_floor=compact_floor)
        trace = [] if regs is None else [regs[p] for p in range(P)]
        return EngineResult(labels, recircs, exit_p, trace)


@dataclasses.dataclass(frozen=True)
class LoopedBackend:
    """Host-side per-partition loop (one device→host sync per hop).

    Kept as the benchmark baseline and the per-op dispatch point: each
    hop calls ``ops.feature_window`` / ``ops.dt_traverse`` with the
    engine's per-op impl, so individual kernels can be exercised in
    isolation.
    """
    name: str = "looped"
    step: None = None

    @staticmethod
    def _op_impl(impl: str) -> str:
        if impl in ("pallas", "auto"):
            return impl
        return "ref"

    def run(self, engine: "Engine", win_pkts: np.ndarray, *,
            with_trace: bool = True, compact: bool = False,
            compact_floor: int = compaction.COMPACT_FLOOR) -> EngineResult:
        # compact_floor is a capacity-ladder knob; the looped backend
        # compacts by exact host fancy-indexing, so it has no ladder
        del compact_floor
        B = win_pkts.shape[0]
        P = engine._check_windows(win_pkts)
        impl = self._op_impl(engine.impl)
        S = engine.ret.n_subtrees
        k = engine.ret.k
        # the loop's carry lives on the HOST: one upload (sid + packets)
        # and one fetch (regs + action, or action alone) per hop — the
        # per-partition np.asarray/jnp.asarray ping-pong that used to mix
        # numpy and jnp mask arithmetic is gone
        sid = np.zeros(B, dtype=np.int32)
        done = np.zeros(B, dtype=bool)
        # int32 to match the walk backends: verdicts from any backend
        # concatenate without silent upcasts; -1 sentinels as in the walk
        labels = np.full(B, -1, dtype=np.int32)
        recircs = np.zeros(B, dtype=np.int32)
        exit_partition = np.full(B, -1, dtype=np.int32)
        regs_trace: list[np.ndarray] = []

        reg_obs = obs.get_registry()
        for p in range(P):
            reg_obs.counter(
                "engine_hop_survivors_total",
                "flows still walking when each hop starts",
                labels={"hop": str(p)}).inc(int(B - done.sum()))
            # host-side early-exit compaction: the looped analogue of the
            # walk backends' capacity buckets is plain fancy indexing
            rows = np.nonzero(~done)[0] if compact and p else np.arange(B)
            if rows.size:
                dense = rows.size == B
                pkts = jnp.asarray(win_pkts[:, p] if dense
                                   else win_pkts[rows, p])
                sid_d = jnp.asarray(sid[rows])
                regs_d = ops.feature_window(pkts, sid_d, engine.tables,
                                            impl=impl)
                action_d = ops.dt_traverse(regs_d, sid_d, engine.ret,
                                           impl=impl)
                reg_obs.counter(
                    "engine_dispatches_total",
                    "jitted walk calls issued",
                    labels={"backend": "looped"}).inc(2)
                if with_trace:
                    regs_h, action_h = jax.device_get((regs_d, action_d))
                else:
                    action_h = jax.device_get(action_d)
            if with_trace:
                if B and rows.size == B:
                    regs_trace.append(regs_h)
                else:
                    full = np.zeros((B, k), dtype=np.float32)
                    if rows.size:
                        full[rows] = regs_h
                    regs_trace.append(full)
            if not rows.size:
                continue
            action = np.full(B, -1, dtype=np.int32)
            action[rows] = action_h
            is_exit = action >= S
            active = ~done
            exiting = active & is_exit
            labels[exiting] = action[exiting] - S
            exit_partition[exiting] = p
            done |= exiting
            cont = active & ~is_exit
            recircs[cont] += 1           # one control packet per transition
            # "recirculation": update SID register, reset feature registers
            sid = np.where(cont, action, sid).astype(np.int32)
        return EngineResult(labels, recircs, exit_partition, regs_trace)


FUSED_BACKEND = WalkBackend(name="fused", step=ops.fused_step)
PALLAS_BACKEND = WalkBackend(name="pallas", step=ops.fused_step_pallas)
LOOPED_BACKEND = LoopedBackend()

_BACKENDS: dict[str, ExecutionBackend] = {
    "fused": FUSED_BACKEND,
    "pallas": PALLAS_BACKEND,
    "looped": LOOPED_BACKEND,
}


@functools.lru_cache(maxsize=None)
def pallas_backend(block_b: int = ops.BLOCK_B) -> WalkBackend:
    """Pallas walk backend with a tuned ``block_b`` (cached per size,
    so jit/streaming caches keyed on the step function stay warm).
    ``pallas_backend(BLOCK_B) is PALLAS_BACKEND``."""
    if block_b == ops.BLOCK_B:
        return PALLAS_BACKEND
    return WalkBackend(name=f"pallas[bb={block_b}]",
                       step=ops.pallas_step(block_b))


def backend_for_plan(plan) -> ExecutionBackend:
    """Resolve a :class:`repro.tuning.Plan` to its execution backend."""
    if plan.backend == "pallas":
        return pallas_backend(plan.block_b)
    return _BACKENDS[plan.backend]


def get_backend(impl: str = "auto", shape=None) -> ExecutionBackend:
    """Backend selection matrix (see docs/ARCHITECTURE.md):

    ==========  =====================================================
    impl        backend
    ==========  =====================================================
    auto        with ``shape`` (a ``repro.tuning.ShapeInfo``): the
                cost model's argmin backend for that workload;
                without: pallas on TPU, fused elsewhere (legacy
                platform default)
    tuned       resolved by ``Engine.run`` / ``run_streaming`` via the
                autotune cache; rejected here (needs an engine +
                batch to probe)
    fused, ref  fused (dense jnp walk)
    pallas      pallas (Pallas kernels + in-jit SID dispatch;
                interpret mode off-TPU)
    looped      looped (host loop, per-partition sync)
    ==========  =====================================================
    """
    if impl == "tuned":
        raise ValueError(
            "impl='tuned' is shape-dependent; use Engine.run / "
            "run_streaming (they resolve it through repro.tuning)")
    if impl == "auto":
        if shape is not None:
            from repro.tuning import choose_plan
            return backend_for_plan(choose_plan(shape))
        impl = "pallas" if ops._on_tpu() else "fused"
    if impl == "ref":
        impl = "fused"
    try:
        return _BACKENDS[impl]
    except KeyError:
        raise ValueError(
            f"unknown impl {impl!r}; options: auto, tuned, ref, "
            + ", ".join(sorted(_BACKENDS))) from None


@dataclasses.dataclass
class Engine:
    tables: PackedTables
    ret: RangeExecTables
    impl: str = "auto"
    _dev: ops.DeviceTables | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @classmethod
    def from_model(cls, pdt: PartitionedDT, impl: str = "auto") -> "Engine":
        return cls(tables=pack_tables(pdt), ret=pack_range_exec(pdt), impl=impl)

    @property
    def dev(self) -> ops.DeviceTables:
        """Device-resident MAT programs (uploaded once, then cached)."""
        if self._dev is None:
            self._dev = ops.device_tables(self.tables, self.ret)
        return self._dev

    def _check_windows(self, win_pkts: np.ndarray) -> int:
        P = win_pkts.shape[1]
        if P < self.tables.n_partitions:
            raise ValueError("fewer windows than partitions")
        return self.tables.n_partitions

    # ------------------------------------------------------------------
    # unified entry point
    # ------------------------------------------------------------------
    def run(self, win_pkts: np.ndarray, *, with_trace: bool = True,
            options: EngineOptions | None = None,
            impl: "str | None | object" = _UNSET,
            compact: "bool | str | object" = _UNSET) -> EngineResult:
        """``win_pkts``: (B, p, W, PKT_NFIELDS) from ``window_packets``.

        Execution knobs arrive as ``options=EngineOptions(...)``
        (``impl=``/``compact=`` remain as deprecated shims):

        * ``options.plan`` (a pre-resolved ``repro.tuning.Plan``) wins
          outright — backend, ``block_b`` and compaction come from it;
        * otherwise ``options.impl`` (falling back to the engine's
          default): a fixed backend name dispatches straight to
          :func:`get_backend`; ``"auto"`` routes through the cost model
          (``repro.tuning.costmodel``) using this batch's shape;
          ``"tuned"`` routes through the autotune cache
          (``repro.tuning.autotune``) — first call on a new (shape,
          host) times a cost-model shortlist, later calls are a lookup.

        Whenever a :class:`repro.tuning.Plan` decided the route it is
        attached as ``EngineResult.plan``.  ``compact=True`` enables
        early-exit compaction between hops, ``"auto"`` lets the plan
        decide (identical verdicts either way; the dense
        ``compact=False`` path remains the reference).  All backends
        are bit-identical, so routing can only change speed, never
        results.
        """
        opt = _legacy_options(options, {"impl": impl, "compact": compact})
        if opt.plan is not None:
            return self._run_plan(opt.plan, win_pkts, with_trace)
        impl = opt.impl or self.impl
        if impl in ("auto", "tuned") or opt.compact == "auto":
            from repro.tuning import get_plan
            plan = get_plan(self, win_pkts, impl=impl, compact=opt.compact)
            return self._run_plan(plan, win_pkts, with_trace)
        if impl == "pallas" and opt.block_b is not None:
            backend = pallas_backend(opt.block_b)
        else:
            backend = get_backend(impl)
        return backend.run(self, win_pkts, with_trace=with_trace,
                           compact=bool(opt.compact),
                           compact_floor=opt.compact_floor)

    def _run_plan(self, plan, win_pkts: np.ndarray,
                  with_trace: bool) -> EngineResult:
        res = backend_for_plan(plan).run(
            self, win_pkts, with_trace=with_trace,
            compact=plan.compact, compact_floor=plan.compact_floor)
        res.plan = plan
        return res

    # ------------------------------------------------------------------
    # streaming path (batches far beyond one device batch)
    # ------------------------------------------------------------------
    def run_streaming(self, win_pkts: np.ndarray, *,
                      options: EngineOptions | None = None,
                      micro_batch=_UNSET,
                      donate=_UNSET,
                      mesh=_UNSET,
                      impl=_UNSET,
                      inflight=_UNSET,
                      compact=_UNSET) -> EngineResult:
        """Chunk ``win_pkts`` into fixed-size padded micro-batches and
        run each through a walk backend; with ``options.mesh`` the
        micro-batch fans out across the mesh's flow-batch axis via
        ``shard_map``.  ``options.compact`` early-exit-compacts each
        chunk's walk; ``options.impl="auto"``/``"tuned"`` resolve the
        chunk's plan through ``repro.tuning``.  Legacy keywords are
        deprecated shims for ``options=``.  See
        ``repro.serve.streaming``."""
        opt = _legacy_options(options, {
            "micro_batch": micro_batch, "donate": donate, "mesh": mesh,
            "impl": impl, "inflight": inflight, "compact": compact})
        from repro.serve.streaming import run_streaming
        return run_streaming(self, win_pkts, options=opt)

    # ------------------------------------------------------------------
    # looped path (per-partition host sync; per-op dispatch + baseline)
    # ------------------------------------------------------------------
    def run_looped(self, win_pkts: np.ndarray, *, with_trace: bool = True,
                   options: EngineOptions | None = None,
                   compact=_UNSET) -> EngineResult:
        opt = _legacy_options(options, {"compact": compact})
        return LOOPED_BACKEND.run(self, win_pkts, with_trace=with_trace,
                                  compact=bool(opt.compact))

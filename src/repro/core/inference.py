"""The partitioned-inference engine (paper Fig. 4, TPU-native).

Orchestrates the two data-plane phases per partition window:
  1. Feature Collection & Engineering — ``kernels.ops.feature_window``
     fills the k registers for each flow's active subtree;
  2. Subtree Model Prediction — ``kernels.ops.dt_traverse`` range-marks
     the registers and emits the action (next SID or exit class).
Between partitions the engine performs the "recirculation": SID update +
register reset, counted per flow for the bandwidth model.

Two execution paths:

* **fused** (default) — the whole partition walk is ONE jitted
  ``jax.lax.scan`` over partitions (:func:`fused_partition_walk`).  The
  loop carry is ``(sid, done, labels, recircs, exit_partition)``; each
  step runs feature_window → dt_traverse → recirculation without
  leaving the device.  The only host↔device traffic per batch is the
  packet windows in and one ``jax.device_get`` of the verdicts out —
  the TPU analogue of keeping the per-packet loop inside the pipeline
  (pForest / Taurus style).
* **looped** — the original host-side Python loop with a per-partition
  device→host sync.  Kept as the dispatch point for the Pallas kernels
  (whose SID-grouping is host-side) and as the benchmark baseline.

The engine must agree exactly with :meth:`PartitionedDT.predict` (the
offline numpy oracle); property tests enforce this for both paths.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionedDT
from repro.core.range_tables import RangeExecTables, pack_range_exec
from repro.core.tables import PackedTables, pack_tables
from repro.kernels import ops


@dataclasses.dataclass
class EngineResult:
    labels: np.ndarray           # (B,) predicted class per flow
    recircs: np.ndarray          # (B,) partition transitions (control pkts)
    exit_partition: np.ndarray   # (B,)
    regs_trace: list[np.ndarray] # per-partition register snapshots


def _fused_partition_walk(
    win_pkts: jnp.ndarray,       # (B, P, W, PKT_NFIELDS)
    dev: ops.DeviceTables,
    *,
    n_subtrees: int,
    with_trace: bool = False,
):
    """Device-resident partition walk: scan partitions, carry flow state.

    Returns ``(labels, recircs, exit_partition, regs)`` — all int32
    except ``regs`` (P, B, k) f32, which is ``None`` unless
    ``with_trace``.  Actions ``>= n_subtrees`` exit with class
    ``action - n_subtrees``; smaller actions recirculate to that SID.
    """
    B, P = win_pkts.shape[0], win_pkts.shape[1]
    S = n_subtrees

    def step(carry, xs):
        sid, done, labels, recircs, exit_p = carry
        p, pkts = xs
        regs, action = ops.fused_step(pkts, sid, dev)
        is_exit = action >= S
        active = ~done
        exiting = active & is_exit
        labels = jnp.where(exiting, action - S, labels)
        exit_p = jnp.where(exiting, p, exit_p)
        done = done | exiting
        cont = active & ~is_exit
        # recirculation: one control packet per transition, SID register
        # update; feature registers are rebuilt from scratch next window
        recircs = recircs + cont.astype(jnp.int32)
        sid = jnp.where(cont, action, sid)
        return (sid, done, labels, recircs, exit_p), (
            regs if with_trace else None)

    init = (
        jnp.zeros(B, jnp.int32),            # sid: all flows start at root
        jnp.zeros(B, jnp.bool_),            # done
        jnp.zeros(B, jnp.int32),            # labels
        jnp.zeros(B, jnp.int32),            # recircs
        jnp.zeros(B, jnp.int32),            # exit_partition
    )
    xs = (jnp.arange(P, dtype=jnp.int32), jnp.swapaxes(win_pkts, 0, 1))
    (sid, done, labels, recircs, exit_p), regs = jax.lax.scan(step, init, xs)
    return labels, recircs, exit_p, regs


fused_partition_walk = functools.partial(
    jax.jit, static_argnames=("n_subtrees", "with_trace"),
)(_fused_partition_walk)

# Donating the packet buffer lets back-to-back micro-batches reuse the
# same device allocation (streaming path).  CPU can't donate host numpy
# buffers usefully, so the streaming scheduler only picks this variant
# off-CPU.
fused_partition_walk_donated = functools.partial(
    jax.jit, static_argnames=("n_subtrees", "with_trace"),
    donate_argnums=(0,),
)(_fused_partition_walk)


@dataclasses.dataclass
class Engine:
    tables: PackedTables
    ret: RangeExecTables
    impl: str = "auto"
    _dev: ops.DeviceTables | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @classmethod
    def from_model(cls, pdt: PartitionedDT, impl: str = "auto") -> "Engine":
        return cls(tables=pack_tables(pdt), ret=pack_range_exec(pdt), impl=impl)

    @property
    def dev(self) -> ops.DeviceTables:
        """Device-resident MAT programs (uploaded once, then cached)."""
        if self._dev is None:
            self._dev = ops.device_tables(self.tables, self.ret)
        return self._dev

    def _check_windows(self, win_pkts: np.ndarray) -> int:
        P = win_pkts.shape[1]
        if P < self.tables.n_partitions:
            raise ValueError("fewer windows than partitions")
        return self.tables.n_partitions

    # ------------------------------------------------------------------
    # fused path (default)
    # ------------------------------------------------------------------
    def run(self, win_pkts: np.ndarray, *, with_trace: bool = True
            ) -> EngineResult:
        """``win_pkts``: (B, p, W, PKT_NFIELDS) from ``window_packets``.

        Dispatch: ``impl="pallas"`` uses the looped path (the Pallas
        dt_traverse groups flows by SID on the host); everything else
        runs the fused, fully-jitted scan with a single device→host
        transfer per batch.
        """
        if self.impl == "pallas":
            return self.run_looped(win_pkts, with_trace=with_trace)
        P = self._check_windows(win_pkts)
        labels, recircs, exit_p, regs = fused_partition_walk(
            jnp.asarray(win_pkts[:, :P]), self.dev,
            n_subtrees=self.ret.n_subtrees, with_trace=with_trace)
        # ONE device->host transfer for the whole batch
        labels, recircs, exit_p, regs = jax.device_get(
            (labels, recircs, exit_p, regs))
        trace = [] if regs is None else [regs[p] for p in range(P)]
        return EngineResult(labels, recircs, exit_p, trace)

    # ------------------------------------------------------------------
    # streaming path (batches far beyond one device batch)
    # ------------------------------------------------------------------
    def run_streaming(self, win_pkts: np.ndarray, *,
                      micro_batch: int = 4096,
                      donate: bool | None = None) -> EngineResult:
        """Chunk ``win_pkts`` into fixed-size padded micro-batches and
        run each through the fused walk; see ``repro.serve.streaming``."""
        from repro.serve.streaming import run_streaming
        return run_streaming(self, win_pkts, micro_batch=micro_batch,
                             donate=donate)

    # ------------------------------------------------------------------
    # looped path (per-partition host sync; Pallas dispatch + baseline)
    # ------------------------------------------------------------------
    def run_looped(self, win_pkts: np.ndarray, *,
                   with_trace: bool = True) -> EngineResult:
        B = win_pkts.shape[0]
        self._check_windows(win_pkts)
        S = self.ret.n_subtrees
        sid = jnp.zeros(B, jnp.int32)
        done = np.zeros(B, dtype=bool)
        # int32 to match the fused path: verdicts from either engine
        # concatenate without silent upcasts
        labels = np.zeros(B, dtype=np.int32)
        recircs = np.zeros(B, dtype=np.int32)
        exit_partition = np.zeros(B, dtype=np.int32)
        regs_trace: list[np.ndarray] = []

        for p in range(self.tables.n_partitions):
            pkts = jnp.asarray(win_pkts[:, p])
            regs = ops.feature_window(pkts, sid, self.tables, impl=self.impl)
            if with_trace:
                regs_trace.append(np.asarray(regs))
            action = np.asarray(ops.dt_traverse(regs, sid, self.ret,
                                                impl=self.impl))
            is_exit = action >= S
            active = ~done
            exiting = active & is_exit
            labels[exiting] = action[exiting] - S
            exit_partition[exiting] = p
            done |= exiting
            cont = active & ~is_exit
            recircs[cont] += 1           # one control packet per transition
            # "recirculation": update SID register, reset feature registers
            sid = jnp.where(jnp.asarray(cont), jnp.asarray(action), sid)
        return EngineResult(labels, recircs, exit_partition, regs_trace)

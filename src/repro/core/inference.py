"""The partitioned-inference engine (paper Fig. 4, TPU-native).

Orchestrates the two data-plane phases per partition window:
  1. Feature Collection & Engineering — ``kernels.ops.feature_window``
     fills the k registers for each flow's active subtree;
  2. Subtree Model Prediction — ``kernels.ops.dt_traverse`` range-marks
     the registers and emits the action (next SID or exit class).
Between partitions the engine performs the "recirculation": SID update +
register reset, counted per flow for the bandwidth model.

The engine must agree exactly with :meth:`PartitionedDT.predict` (the
offline numpy oracle); a property test enforces this.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionedDT
from repro.core.range_tables import RangeExecTables, pack_range_exec
from repro.core.tables import PackedTables, pack_tables
from repro.kernels import ops


@dataclasses.dataclass
class EngineResult:
    labels: np.ndarray           # (B,) predicted class per flow
    recircs: np.ndarray          # (B,) partition transitions (control pkts)
    exit_partition: np.ndarray   # (B,)
    regs_trace: list[np.ndarray] # per-partition register snapshots


@dataclasses.dataclass
class Engine:
    tables: PackedTables
    ret: RangeExecTables
    impl: str = "auto"

    @classmethod
    def from_model(cls, pdt: PartitionedDT, impl: str = "auto") -> "Engine":
        return cls(tables=pack_tables(pdt), ret=pack_range_exec(pdt), impl=impl)

    def run(self, win_pkts: np.ndarray) -> EngineResult:
        """``win_pkts``: (B, p, W, PKT_NFIELDS) from ``window_packets``."""
        B, P = win_pkts.shape[0], win_pkts.shape[1]
        if P < self.tables.n_partitions:
            raise ValueError("fewer windows than partitions")
        S = self.ret.n_subtrees
        sid = jnp.zeros(B, jnp.int32)
        done = np.zeros(B, dtype=bool)
        labels = np.zeros(B, dtype=np.int64)
        recircs = np.zeros(B, dtype=np.int64)
        exit_partition = np.zeros(B, dtype=np.int64)
        regs_trace: list[np.ndarray] = []

        for p in range(self.tables.n_partitions):
            pkts = jnp.asarray(win_pkts[:, p])
            regs = ops.feature_window(pkts, sid, self.tables, impl=self.impl)
            regs_trace.append(np.asarray(regs))
            action = np.asarray(ops.dt_traverse(regs, sid, self.ret,
                                                impl=self.impl))
            is_exit = action >= S
            active = ~done
            exiting = active & is_exit
            labels[exiting] = action[exiting] - S
            exit_partition[exiting] = p
            done |= exiting
            cont = active & ~is_exit
            recircs[cont] += 1           # one control packet per transition
            # "recirculation": update SID register, reset feature registers
            sid = jnp.where(jnp.asarray(cont), jnp.asarray(action), sid)
        return EngineResult(labels, recircs, exit_partition, regs_trace)

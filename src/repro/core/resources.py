"""Analytical hardware resource model + feasibility testing (paper §3.2.1).

Target-specific constants model a Tofino1-class switch (Table 3 caption:
6.4 Mbit TCAM, 12 stages).  Constants are calibrated so that the paper's
anchor points hold: with 32-bit features, a k=4 one-shot model supports
~100K flows and k=6 ~65K (paper footnote 1); SpliDT reaches 1M flows
with small k / few partitions.

The model answers two questions for a candidate (model, target):
  * ``capacity``: max concurrent flows supportable, and
  * ``feasible(flows)``: does the design fit TCAM / stages / registers /
    recirculation bandwidth at the requested flow count.
"""
from __future__ import annotations

import dataclasses


from repro.core.partition import EXIT, PartitionedDT
from repro.core.rangemark import SubtreeRules, build_subtree_rules


@dataclasses.dataclass(frozen=True)
class Target:
    """Switch/SmartNIC resource envelope.

    ``reg_bits_per_stage`` ~ Tofino1's 80 x 128 Kb SRAM blocks per stage;
    §2.1's anchor ("four registers per flow exhausts a stage at 65K
    flows": 4 x 65K x 32b = 8.3 Mb) lands in the same range.
    """
    name: str = "tofino1"
    n_stages: int = 12
    tcam_bits: float = 6.4e6
    reg_bits_per_stage: float = 12.0e6
    recirc_gbps: float = 100.0
    sid_bits: int = 8
    counter_bits: int = 16
    dep_reg_bits: int = 32
    # fixed pipeline overhead for SpliDT: parser/hash + operator-selection
    # MATs + range-mark tables + model table + bookkeeping.  CONSTANT in
    # total tree depth: the same SID-keyed MATs serve every partition via
    # recirculation -- the paper's architectural win (§2.3).
    logic_stages: int = 4
    # one-shot baselines chain depth-ordered MATs spatially; ~4 tree
    # levels of range-marked matching fit one stage
    levels_per_stage: int = 4


TOFINO1 = Target()
PENSANDO = Target(name="pensando-dpu", n_stages=8, tcam_bits=4.0e6,
                  reg_bits_per_stage=5.5e6, recirc_gbps=50.0)


@dataclasses.dataclass
class ResourceReport:
    tcam_entries: int
    tcam_bits: float
    register_bits_per_flow: int
    stages_logic: int
    stages_register: int
    flow_capacity: int
    recirc_mbps: float
    feasible: bool
    reasons: list[str]


def model_rules(pdt: PartitionedDT, *, bits: int = 32,
                feature_ranges: dict[int, tuple[float, float]] | None = None,
                ) -> list[SubtreeRules]:
    """Range-marking rules for every subtree (class actions offset by the
    subtree count so exits and transitions share one action space)."""
    S = len(pdt.subtrees)
    rules = []
    for st in pdt.subtrees:
        action = {}
        for leaf, nxt in st.leaf_next_sid.items():
            if nxt == EXIT:
                action[leaf] = S + st.leaf_label[leaf]   # class actions
            else:
                action[leaf] = nxt                       # transition actions
        rules.append(build_subtree_rules(
            st.tree, action, bits=bits, feature_ranges=feature_ranges))
    return rules


def estimate(
    pdt: PartitionedDT,
    *,
    target: Target = TOFINO1,
    bits: int = 32,
    flows: int | None = None,
    recirc_mbps: float = 0.0,
    rules: list[SubtreeRules] | None = None,
    feature_ranges: dict[int, tuple[float, float]] | None = None,
) -> ResourceReport:
    """Resource usage + feasibility for a partitioned DT (paper §3.2.1)."""
    if rules is None:
        rules = model_rules(pdt, bits=bits, feature_ranges=feature_ranges)
    tcam_entries = int(sum(r.total_entries for r in rules))
    # feature-table entries match a register value (bits wide) + SID;
    # model-table entries match SID + range marks
    tcam_bits = float(sum(
        r.feature_entries * (bits + target.sid_bits) + r.model_entries * r.key_bits
        for r in rules))

    dep = pdt.dep_depth()
    # dependency-chain registers store intermediate values at the same
    # precision as the features (paper Fig. 12: 16/8-bit models support
    # ~2x/4x the flows -- total per-flow state scales with feature width)
    reg_bits = (pdt.k * bits + target.sid_bits + target.counter_bits
                + dep * min(target.dep_reg_bits, bits))
    stages_logic = target.logic_stages + dep
    stages_register = max(target.n_stages - stages_logic, 0)
    capacity = int(stages_register * target.reg_bits_per_stage // max(reg_bits, 1))

    reasons = []
    if tcam_bits > target.tcam_bits:
        reasons.append(f"TCAM {tcam_bits / 1e6:.2f}Mb > {target.tcam_bits / 1e6:.1f}Mb")
    if stages_register <= 0:
        reasons.append("no stages left for registers")
    if flows is not None and capacity < flows:
        reasons.append(f"capacity {capacity} < target flows {flows}")
    if recirc_mbps > target.recirc_gbps * 1e3:
        reasons.append("recirculation exceeds budget")
    return ResourceReport(
        tcam_entries=tcam_entries, tcam_bits=tcam_bits,
        register_bits_per_flow=int(reg_bits), stages_logic=stages_logic,
        stages_register=stages_register, flow_capacity=capacity,
        recirc_mbps=recirc_mbps, feasible=not reasons, reasons=reasons,
    )


def estimate_oneshot(
    n_features_used: int,
    tcam_entries: int,
    key_bits: int,
    *,
    target: Target = TOFINO1,
    bits: int = 32,
    dep_depth: int = 2,
    depth: int = 8,
    flows: int | None = None,
) -> ResourceReport:
    """Resource model for one-shot top-k baselines (NetBeacon/Leo style).

    All ``n_features_used`` stateful features must be resident for the
    whole flow (no SID register, no recirculation), and the single-pass
    DT consumes pipeline stages proportional to its depth -- the spatial
    execution model SpliDT's time-sharing removes.
    """
    reg_bits = (n_features_used * bits + target.counter_bits
                + dep_depth * target.dep_reg_bits)
    stages_model = -(-int(depth) // target.levels_per_stage)
    stages_logic = 3 + dep_depth + stages_model
    stages_register = max(target.n_stages - stages_logic, 0)
    capacity = int(stages_register * target.reg_bits_per_stage // max(reg_bits, 1))
    tcam_bits = float(tcam_entries * (bits + key_bits))
    reasons = []
    if tcam_bits > target.tcam_bits:
        reasons.append("TCAM over budget")
    if flows is not None and capacity < flows:
        reasons.append(f"capacity {capacity} < target flows {flows}")
    return ResourceReport(
        tcam_entries=tcam_entries, tcam_bits=tcam_bits,
        register_bits_per_flow=int(reg_bits), stages_logic=stages_logic,
        stages_register=stages_register, flow_capacity=capacity,
        recirc_mbps=0.0, feasible=not reasons, reasons=reasons,
    )

"""Design-space exploration via Bayesian optimisation (paper §3.2.1).

HyperMapper is not available offline, so we implement the BO loop it
provides: a Gaussian-process surrogate (RBF kernel, pure numpy
Cholesky), Expected-Improvement acquisition over randomly sampled
candidates, a feasibility surrogate (GP classifier on the resource
model's verdict) multiplied into the acquisition -- HyperMapper's
"feasibility testing" feature -- and batched proposals per iteration
(the paper runs 16 parallel evaluations).

Search space (paper: model hyperparameters):
  * number of partitions  p   in [1, max_partitions]
  * features per subtree  k   in [1, k_max]
  * per-partition depths  d_i in [1, depth_max]
Objectives: maximise F1 at a given flow target, subject to hardware
feasibility; sweeping flow targets yields the Pareto frontier
(F1 vs flows) of Fig. 6.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import numpy as np

from repro import obs
from repro.core.partition import train_partitioned_dt
from repro.core.recirc import ENVIRONMENTS, recirc_bandwidth
from repro.core.resources import Target, TOFINO1, estimate
from repro.core.tree import macro_f1


# --------------------------------------------------------------------------
# Gaussian-process surrogate (pure numpy)
# --------------------------------------------------------------------------
class GP:
    def __init__(self, length_scale: float = 0.35, noise: float = 1e-3):
        self.ls = length_scale
        self.noise = noise
        self._X: np.ndarray | None = None

    def _k(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        d2 = ((A[:, None, :] - B[None, :, :]) ** 2).sum(-1)
        return np.exp(-0.5 * d2 / self.ls ** 2)

    def fit(self, X: np.ndarray, y: np.ndarray):
        self._X = X
        self._ymu, self._ysd = float(y.mean()), float(y.std() + 1e-9)
        yn = (y - self._ymu) / self._ysd
        K = self._k(X, X) + self.noise * np.eye(len(X))
        self._L = np.linalg.cholesky(K)
        self._alpha = np.linalg.solve(
            self._L.T, np.linalg.solve(self._L, yn))
        return self

    def predict(self, Xq: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        Ks = self._k(Xq, self._X)
        mu = Ks @ self._alpha
        v = np.linalg.solve(self._L, Ks.T)
        var = np.clip(1.0 - (v ** 2).sum(0), 1e-9, None)
        return mu * self._ysd + self._ymu, np.sqrt(var) * self._ysd


def expected_improvement(mu: np.ndarray, sd: np.ndarray, best: float) -> np.ndarray:
    z = (mu - best) / sd
    # standard normal pdf/cdf without scipy
    pdf = np.exp(-0.5 * z ** 2) / np.sqrt(2 * np.pi)
    cdf = 0.5 * (1.0 + _erf(z / np.sqrt(2.0)))
    return (mu - best) * cdf + sd * pdf


def _erf(x: np.ndarray) -> np.ndarray:
    # Abramowitz & Stegun 7.1.26
    sign = np.sign(x)
    x = np.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    y = 1.0 - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
                - 0.284496736) * t + 0.254829592) * t * np.exp(-x * x)
    return sign * y


# --------------------------------------------------------------------------
# SpliDT configuration space
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Config:
    k: int
    partition_sizes: tuple[int, ...]

    @property
    def n_partitions(self) -> int:
        return len(self.partition_sizes)

    @property
    def depth(self) -> int:
        return int(sum(self.partition_sizes))


@dataclasses.dataclass
class Evaluation:
    config: Config
    f1: float
    feasible: bool
    flow_capacity: int
    tcam_entries: int
    register_bits: int
    recirc_mbps: float
    n_subtrees: int
    unique_features: int


@dataclasses.dataclass
class SearchSpace:
    max_partitions: int = 6
    k_max: int = 6
    depth_max: int = 10

    @property
    def dim(self) -> int:
        return 2 + self.max_partitions

    def sample(self, rng: np.random.Generator) -> Config:
        p = int(rng.integers(1, self.max_partitions + 1))
        k = int(rng.integers(1, self.k_max + 1))
        depths = tuple(int(rng.integers(1, self.depth_max + 1)) for _ in range(p))
        return Config(k, depths)

    def encode(self, c: Config) -> np.ndarray:
        x = np.zeros(self.dim)
        x[0] = c.n_partitions / self.max_partitions
        x[1] = c.k / self.k_max
        for i, d in enumerate(c.partition_sizes):
            x[2 + i] = d / self.depth_max
        return x


def make_splidt_evaluator(
    Xw_tr: np.ndarray, y_tr: np.ndarray,
    Xw_te: np.ndarray, y_te: np.ndarray,
    *,
    n_classes: int,
    flows: int,
    target: Target = TOFINO1,
    bits: int = 32,
    env_name: str = "HD",
    feature_ranges: dict[int, tuple[float, float]] | None = None,
    trainer: str = "numpy",
    win_pkts_te: np.ndarray | None = None,
) -> Callable[[Config], Evaluation]:
    """The paper's per-configuration pipeline: train (Algorithm 1) ->
    evaluate F1 -> generate rules -> resource/feasibility check.

    ``trainer`` selects the subtree grower passed through to
    :func:`train_partitioned_dt` (``"numpy"`` or the jitted ``"jax"``
    fleet -- structurally identical models either way).

    ``win_pkts_te``: optional window-*packet* tensor for the test split
    (``flows.windows.window_packets`` over the same window count as
    ``Xw_te``).  When given, the returned evaluator grows an
    ``evaluate_batch`` attribute that scores a whole candidate batch
    through the jitted engine in ONE vmapped dispatch
    (``repro.fit.batched.fleet_predict``); :func:`bayes_search` picks
    it up automatically.  Labels are bit-identical to
    ``PartitionedDT.predict`` (docs/PARITY.md), so serial and batched
    evaluation produce the same ``Evaluation``s.
    """

    env = ENVIRONMENTS[env_name]

    def _train(cfg: Config, max_dep):
        if cfg.n_partitions > Xw_tr.shape[1]:
            raise ValueError("config needs more windows than the dataset has")
        return train_partitioned_dt(
            Xw_tr[:, :cfg.n_partitions], y_tr,
            partition_sizes=list(cfg.partition_sizes), k=cfg.k,
            n_classes=n_classes, max_dep_depth=max_dep, trainer=trainer)

    def _finish(pdt, pred, recircs):
        f1 = macro_f1(y_te, pred, n_classes)
        bw = recirc_bandwidth(recircs, flows, env)
        rep = estimate(pdt, target=target, bits=bits, flows=flows,
                       recirc_mbps=bw.mean_mbps,
                       feature_ranges=feature_ranges)
        return pdt, f1, bw, rep

    def _evaluation(cfg, pdt, f1, bw, rep) -> Evaluation:
        return Evaluation(
            config=cfg, f1=f1, feasible=rep.feasible,
            flow_capacity=rep.flow_capacity, tcam_entries=rep.tcam_entries,
            register_bits=rep.register_bits_per_flow,
            recirc_mbps=bw.mean_mbps, n_subtrees=len(pdt.subtrees),
            unique_features=len(pdt.unique_features()),
        )

    def evaluate(cfg: Config) -> Evaluation:
        def attempt(max_dep):
            pdt = _train(cfg, max_dep)
            pred, recircs, _ = pdt.predict(Xw_te[:, :cfg.n_partitions],
                                           return_trace=True)
            return _finish(pdt, pred, recircs)

        pdt, f1, bw, rep = attempt(None)
        if not rep.feasible and pdt.dep_depth() > 0:
            # at high flow targets dependency registers bind: retrain on
            # dependency-free features (paper: registers vs k trade-off)
            pdt2, f12, bw2, rep2 = attempt(0)
            if rep2.feasible:
                pdt, f1, bw, rep = pdt2, f12, bw2, rep2
        return _evaluation(cfg, pdt, f1, bw, rep)

    if win_pkts_te is not None:

        def _attempt_batch(cfgs: list[Config], max_deps: list):
            """Train each config, then score ALL of them in one
            vmapped engine dispatch."""
            from repro.fit.batched import fleet_predict
            pdts = [_train(c, d) for c, d in zip(cfgs, max_deps)]
            P = max(p.n_partitions for p in pdts)
            labels, recircs, _ = fleet_predict(pdts, win_pkts_te[:, :P])
            return [_finish(p, labels[i], recircs[i])
                    for i, p in enumerate(pdts)]

        def evaluate_batch(cfgs: list[Config]) -> list[Evaluation]:
            if not cfgs:
                return []
            results = _attempt_batch(cfgs, [None] * len(cfgs))
            # feasibility fallback, batched the same way: retrain the
            # dependency-bound failures on dependency-free features
            redo = [i for i, (pdt, _, _, rep) in enumerate(results)
                    if not rep.feasible and pdt.dep_depth() > 0]
            if redo:
                retried = _attempt_batch([cfgs[i] for i in redo],
                                         [0] * len(redo))
                for i, res2 in zip(redo, retried):
                    if res2[3].feasible:
                        results[i] = res2
            return [_evaluation(c, *res) for c, res in zip(cfgs, results)]

        evaluate.evaluate_batch = evaluate_batch

    return evaluate


@dataclasses.dataclass
class BOResult:
    history: list[Evaluation]
    best: Evaluation | None
    iterations_to_best: int

    def pareto(self) -> list[Evaluation]:
        """Non-dominated (F1, flow_capacity) among feasible evals."""
        feas = [e for e in self.history if e.feasible]
        out = []
        for e in feas:
            if not any(o.f1 >= e.f1 and o.flow_capacity >= e.flow_capacity
                       and (o.f1 > e.f1 or o.flow_capacity > e.flow_capacity)
                       for o in feas):
                out.append(e)
        return sorted(out, key=lambda e: -e.f1)


def bayes_search(
    evaluate: Callable[[Config], Evaluation],
    space: SearchSpace,
    *,
    n_iterations: int = 30,
    batch: int = 4,
    n_init: int = 8,
    n_candidates: int = 256,
    seed: int = 0,
    evaluate_batch: Callable[[list[Config]], list[Evaluation]] | None = None,
) -> BOResult:
    """BO loop: GP surrogate on F1, GP feasibility model, EI acquisition.

    Each iteration proposes exactly ``batch`` *unseen* configs: the
    acquisition ranking is walked past the top-``batch`` entries to
    replace duplicates, topping up with fresh random samples if the
    whole candidate pool is exhausted (historically an iteration could
    silently evaluate fewer than ``batch`` -- or zero -- candidates
    when sampling collided with ``seen``).

    ``evaluate_batch`` (or an ``evaluate_batch`` attribute on
    ``evaluate``, as produced by :func:`make_splidt_evaluator` with
    ``win_pkts_te=``) scores each proposal batch in one call -- the
    paper's 16 parallel evaluations -- instead of looping
    ``evaluate`` per candidate.  History order (and therefore the GP
    state, the RNG stream, and ``BOResult``) is identical either way.
    """
    rng = np.random.default_rng(seed)
    history: list[Evaluation] = []
    seen: set[Config] = set()
    if evaluate_batch is None:
        evaluate_batch = getattr(evaluate, "evaluate_batch", None)

    def pick_fresh(ranked: list[Config], want: int) -> list[Config]:
        """First ``want`` unseen configs off the ranking; top up with
        random draws (bounded) when the ranking runs dry."""
        picked: list[Config] = []
        for c in ranked:
            if c in seen or c in picked:
                continue
            picked.append(c)
            if len(picked) == want:
                return picked
        for _ in range(50 * max(want, 1)):
            if len(picked) == want:
                break
            c = space.sample(rng)
            if c not in seen and c not in picked:
                picked.append(c)
        return picked

    def run_batch(cfgs: list[Config]):
        seen.update(cfgs)
        reg_obs = obs.get_registry()
        t0 = time.perf_counter() if obs.enabled() else 0.0
        with obs.span("dse/round"):
            if evaluate_batch is not None:
                fresh = evaluate_batch(cfgs)
            else:
                fresh = [evaluate(c) for c in cfgs]
        history.extend(fresh)
        reg_obs.counter("dse_evals_total",
                        "candidate configs evaluated").inc(len(fresh))
        reg_obs.counter(
            "dse_feasible_total", "evaluations meeting resource bounds",
        ).inc(sum(1 for e in fresh if e.feasible))
        if obs.enabled() and fresh:
            dt = time.perf_counter() - t0
            reg_obs.histogram(
                "dse_round_seconds", "wall-clock per BO candidate round",
                edges=obs.exp_edges(1e-3, 1e3, 13)).record(dt)
            if dt > 0:
                reg_obs.gauge(
                    "dse_candidates_per_s",
                    "throughput of the latest candidate round",
                ).set(len(fresh) / dt)

    run_batch(pick_fresh([space.sample(rng) for _ in range(n_init)], n_init))

    for _ in range(n_iterations):
        X = np.stack([space.encode(e.config) for e in history])
        y = np.asarray([e.f1 if e.feasible else 0.0 for e in history])
        feas = np.asarray([1.0 if e.feasible else 0.0 for e in history])
        gp_f1 = GP().fit(X, y)
        gp_feas = GP(length_scale=0.5).fit(X, feas)
        best = float(y.max(initial=0.0))

        cands = [space.sample(rng) for _ in range(n_candidates)]
        Xc = np.stack([space.encode(c) for c in cands])
        mu, sd = gp_f1.predict(Xc)
        pf, _ = gp_feas.predict(Xc)
        acq = expected_improvement(mu, sd, best) * np.clip(pf, 0.05, 1.0)
        order = np.argsort(acq)[::-1]
        run_batch(pick_fresh([cands[int(i)] for i in order], batch))

    feas_hist = [e for e in history if e.feasible]
    best_eval = max(feas_hist, key=lambda e: e.f1, default=None)
    it_best = history.index(best_eval) + 1 if best_eval else len(history)
    return BOResult(history=history, best=best_eval, iterations_to_best=it_best)

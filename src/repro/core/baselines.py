"""One-shot top-k baselines (NetBeacon- / Leo-style, paper §5.1).

Both baselines select a fixed global top-k stateful feature set and run a
single-pass DT over whole-flow statistics:

  * NetBeacon-style ("nb"): deeper trees, importance-ranked top-k,
    range-marking TCAM encoding (their own algorithm).
  * Leo-style ("leo"): depth-constrained trees whose TCAM footprint is a
    power-of-two block grid (Leo allocates fixed rule blocks), modelled
    as entries rounded up to the next power of two.

Fidelity note: NetBeacon's multi-phase inference (exponentially growing
packet counts with *retained* statistics and the same top-k features per
phase) converges to whole-flow features at the final phase; we evaluate
the final phase, which is the baseline's best case.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.rangemark import build_subtree_rules
from repro.core.resources import ResourceReport, Target, TOFINO1, estimate_oneshot
from repro.core.tree import Tree, feature_importance, macro_f1, train_tree


@dataclasses.dataclass
class OneShotModel:
    tree: Tree
    feature_ids: np.ndarray     # the global top-k set
    k: int
    depth: int
    style: str                  # "nb" | "leo"
    tcam_entries: int
    key_bits: int

    def predict(self, X_full: np.ndarray) -> np.ndarray:
        return self.tree.predict(X_full)

    def f1(self, X_full: np.ndarray, y: np.ndarray, n_classes: int) -> float:
        return macro_f1(y, self.predict(X_full), n_classes)

    def resources(self, *, target: Target = TOFINO1, bits: int = 32,
                  flows: int | None = None) -> ResourceReport:
        n_used = len(self.tree.used_features())
        from repro.core.features import max_dep_depth
        dep = max_dep_depth(self.tree.used_features())
        return estimate_oneshot(
            max(n_used, 1), self.tcam_entries, self.key_bits,
            target=target, bits=bits, flows=flows,
            dep_depth=dep, depth=self.tree.max_depth)


def train_oneshot_topk(
    X_full: np.ndarray,
    y: np.ndarray,
    *,
    k: int,
    depth: int,
    style: str = "nb",
    n_classes: int | None = None,
    bits: int = 32,
    importances: np.ndarray | None = None,
) -> OneShotModel:
    """Train a top-k one-shot baseline on whole-flow features."""
    C = int(n_classes if n_classes is not None else y.max() + 1)
    if importances is None:
        importances = feature_importance(X_full, y, n_classes=C)
    topk = np.argsort(importances)[::-1][:k]
    t = train_tree(X_full, y, max_depth=depth, allowed_features=topk,
                   n_classes=C)
    leaf_action = {int(i): int(t.value[i].argmax())
                   for i in np.nonzero(t.feature < 0)[0]}
    rules = build_subtree_rules(t, leaf_action, bits=bits, sid_bits=0)
    entries = rules.total_entries
    if style == "leo":
        entries = int(2 ** np.ceil(np.log2(max(entries, 1))))
    return OneShotModel(
        tree=t, feature_ids=np.asarray(topk), k=k, depth=depth, style=style,
        tcam_entries=entries, key_bits=rules.key_bits,
    )


def best_oneshot_for_flows(
    X_tr: np.ndarray, y_tr: np.ndarray, X_te: np.ndarray, y_te: np.ndarray,
    *,
    flows: int,
    style: str,
    n_classes: int,
    target: Target = TOFINO1,
    bits: int = 32,
    k_grid=(1, 2, 3, 4, 6),
    depth_grid=(3, 5, 8, 10, 13),
) -> tuple[OneShotModel | None, float]:
    """Grid-search the baseline family for the best feasible model at a
    flow target (paper: 'the best-performing model each baseline can
    support using all available hardware resources')."""
    imp = feature_importance(X_tr, y_tr, n_classes=n_classes)
    best, best_f1 = None, -1.0
    for k in k_grid:
        for d in depth_grid:
            m = train_oneshot_topk(X_tr, y_tr, k=k, depth=d, style=style,
                                   n_classes=n_classes, bits=bits,
                                   importances=imp)
            rep = m.resources(target=target, bits=bits, flows=flows)
            if not rep.feasible:
                continue
            f1 = m.f1(X_te, y_te, n_classes)
            if f1 > best_f1:
                best, best_f1 = m, f1
    return best, best_f1

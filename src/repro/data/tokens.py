"""Token data pipeline for LM training.

No corpus ships offline, so the source is a seeded sparse Markov chain
over the vocabulary — enough structure that a ~100M model's loss drops
well below the uniform floor within a few hundred steps (the end-to-end
example's acceptance check), while staying fully deterministic.

Production-shaped pipeline features:
  * deterministic per-step batches (``batch_at(step)``) -> resuming from
    a checkpoint replays the exact stream position (recovery semantics);
  * background prefetch thread with a bounded buffer (overlaps host data
    generation with device compute);
  * device placement hook (shard batches onto the mesh as they arrive).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator

import numpy as np


class MarkovText:
    """Sparse first-order Markov chain token source."""

    def __init__(self, vocab: int, branching: int = 8, seed: int = 0):
        self.vocab = vocab
        rng = np.random.default_rng(seed)
        # each token can transition to `branching` successors
        self.succ = rng.integers(0, vocab, size=(vocab, branching))
        logits = rng.normal(size=(vocab, branching)) * 1.5
        p = np.exp(logits)
        self.p = p / p.sum(axis=1, keepdims=True)

    def sample(self, rng: np.random.Generator, batch: int, seq: int
               ) -> np.ndarray:
        out = np.empty((batch, seq), dtype=np.int32)
        cur = rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        for t in range(1, seq):
            choice = (rng.random(batch)[:, None] <
                      np.cumsum(self.p[cur], axis=1)).argmax(axis=1)
            cur = self.succ[cur, choice]
            out[:, t] = cur
        return out


class TokenPipeline:
    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0,
                 place: Callable[[dict], Any] | None = None):
        self.source = MarkovText(vocab, seed=seed)
        self.batch, self.seq = batch, seq
        self.seed = seed
        self.place = place or (lambda b: b)

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a given step (resume-safe)."""
        rng = np.random.default_rng((self.seed, step))
        toks = self.source.sample(rng, self.batch, self.seq)
        return self.place({"tokens": toks, "labels": toks.copy()})

    def iterate(self, start_step: int = 0, prefetch: int = 2
                ) -> Iterator[dict]:
        """Prefetching iterator starting at ``start_step``."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def worker():
            s = start_step
            while not stop.is_set():
                try:
                    q.put(self.batch_at(s), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=worker, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

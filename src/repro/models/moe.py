"""Mixture-of-Experts FFN: top-k routing, shared experts, EP sharding.

Dispatch is capacity-based scatter/gather (GShard-style dropping, MaxText
convention): tokens are grouped (one group per sequence — groups ride the
data axis), each group routes its tokens into per-expert buffers of
capacity ``C = ceil(S * top_k / E * capacity_factor)`` via cumsum
position assignment, expert GEMMs run as batched einsums over the expert
dim (sharded on the "model" axis = expert parallelism; XLA inserts the
all-to-alls at the data->expert sharding boundary), and outputs gather
back with gate weighting.

Experts whose count doesn't divide the EP axis are padded (e.g. Qwen's
60 -> 64 on a 16-way axis); pad experts are masked out of routing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg
from repro.distributed.pspec import ParamDef
from repro.models.layers import COMPUTE_DTYPE, shard


def padded_experts(m: MoECfg, ep: int = 16) -> int:
    e = m.n_experts
    return ((e + ep - 1) // ep) * ep if e % ep else e


def moe_defs(d_model: int, m: MoECfg) -> dict:
    E = padded_experts(m)
    F = m.d_ff_expert
    d = {
        "router": ParamDef((d_model, E), ("embed", "expert")),
        "wg": ParamDef((E, d_model, F), ("expert", "embed", "expert_mlp")),
        "wu": ParamDef((E, d_model, F), ("expert", "embed", "expert_mlp")),
        "wd": ParamDef((E, F, d_model), ("expert", "expert_mlp", "embed")),
    }
    if m.n_shared:
        Fs = m.d_ff_shared
        d["shared"] = {
            "wg": ParamDef((d_model, Fs), ("embed", "mlp")),
            "wu": ParamDef((d_model, Fs), ("embed", "mlp")),
            "wd": ParamDef((Fs, d_model), ("mlp", "embed")),
        }
    return d


def _moe_decode_einsum(p, x, m: MoECfg, E: int):
    """§Perf decode path: einsum dispatch over ONE global token group.

    The scatter/gather dispatch cannot be partitioned by GSPMD across
    the (data -> expert) sharding boundary — measured ~1 GB/layer of
    involuntary buffer replication at decode_32k.  One-hot EINSUM
    dispatch partitions cleanly: the token contraction becomes a psum of
    the small (E, C, D) buffer (~33 MB/layer for DeepSeek).  Dense
    one-hot tensors are only affordable at decode token counts — the
    wrapper routes here when B*T is small.
    """
    B, T, D = x.shape
    N = B * T
    k = m.top_k
    xf = x.reshape(N, D).astype(COMPUTE_DTYPE)
    logits = (xf @ p["router"].astype(COMPUTE_DTYPE)).astype(jnp.float32)
    if E > m.n_experts:
        logits = jnp.where(jnp.arange(E)[None] >= m.n_experts, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                    # (N, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    C = min(N, max(int(N * k / m.n_experts * 2.0), 16))  # dropless at decode
    oh = jax.nn.one_hot(eidx, E, dtype=jnp.int32)           # (N, k, E)
    pos = jnp.cumsum(oh.reshape(N * k, E), axis=0).reshape(N, k, E) - 1
    pos = (pos * oh).sum(-1)                                # (N, k)
    keep = pos < C
    # dispatch mask (N, k, E, C) -> combine over k: (N, E, C)
    disp = (oh[..., None] * jax.nn.one_hot(jnp.where(keep, pos, C - 1), C,
                                           dtype=jnp.int32)[:, :, None, :])
    disp = disp * keep[:, :, None, None].astype(jnp.int32)
    gated = (disp * gate[:, :, None, None]).sum(1)          # (N, E, C) f32
    disp_b = disp.sum(1).astype(COMPUTE_DTYPE)              # (N, E, C)
    buf = jnp.einsum("nec,nd->ecd", disp_b, xf)
    buf = shard(buf, "model", None, None)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                               p["wg"].astype(COMPUTE_DTYPE)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["wu"].astype(COMPUTE_DTYPE))
    h = shard(h, "model", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(COMPUTE_DTYPE))
    out = jnp.einsum("nec,ecd->nd", gated.astype(COMPUTE_DTYPE), out_buf)
    me = probs.mean(axis=0)
    ce = jax.nn.one_hot(eidx, E).sum(axis=1).mean(axis=0)
    aux = (me * ce).sum() * m.n_experts
    if m.n_shared:
        s = p["shared"]
        g = jax.nn.silu(xf @ s["wg"].astype(COMPUTE_DTYPE))
        out = out + (g * (xf @ s["wu"].astype(COMPUTE_DTYPE))
                     ) @ s["wd"].astype(COMPUTE_DTYPE)
    return out.reshape(B, T, D).astype(x.dtype), aux.astype(jnp.float32)


_DECODE_EINSUM_MAX_TOKENS = 1024
_EINSUM_DECODE = True    # §Perf switch; base dry-run layout disables it


def set_einsum_decode(v: bool) -> None:
    global _EINSUM_DECODE
    _EINSUM_DECODE = bool(v)


def moe_ffn(p, x: jnp.ndarray, m: MoECfg,
            dropless: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, D) -> (out (B, T, D), aux load-balance loss scalar).

    ``dropless``: inference mode — capacity is widened to min(T, 16 x
    the balanced load), so no token drops at small/decode batch sizes
    (prefix-causal serving); at very long prefill this caps the buffer
    and reverts to (mild) capacity dropping, documented in DESIGN.md.
    """
    B, T, D = x.shape
    E = p["router"].shape[1]
    if dropless and _EINSUM_DECODE and B * T <= _DECODE_EINSUM_MAX_TOKENS:
        return _moe_decode_einsum(p, x, m, E)
    k = m.top_k
    C = max(int(T * k / m.n_experts * m.capacity_factor), 1)
    if dropless:
        C = min(T, max(C, 16))
    xc = x.astype(COMPUTE_DTYPE)

    # --- routing (f32) ----------------------------------------------------
    logits = jnp.einsum("btd,de->bte", xc, p["router"].astype(COMPUTE_DTYPE)
                        ).astype(jnp.float32)
    if E > m.n_experts:   # mask pad experts out of routing
        pad_mask = jnp.arange(E) >= m.n_experts
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # (B, T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)              # renormalise

    # aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))                             # (E,)
    ce = jax.nn.one_hot(expert_idx, E).sum(axis=2).mean(axis=(0, 1))
    aux = (me * ce).sum() * m.n_experts

    # --- capacity assignment (per group = per sequence) --------------------
    oh = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)      # (B, T, k, E)
    # order: token-major then slot-major, standard GShard priority
    ohf = oh.reshape(B, T * k, E)
    pos = jnp.cumsum(ohf, axis=1) - 1                        # (B, T*k, E)
    pos = (pos * ohf).sum(-1).reshape(B, T, k)               # (B, T, k)
    keep = pos < C
    eidx = expert_idx                                        # (B, T, k)

    # --- dispatch: scatter tokens into (B, E, C, D) buffers ----------------
    buf = jnp.zeros((B, E, C, D), COMPUTE_DTYPE)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None, None], (B, T, k))
    pos_c = jnp.where(keep, pos, C - 1)
    contrib = jnp.where(keep[..., None],
                        jnp.broadcast_to(xc[:, :, None, :], (B, T, k, D)), 0.0)
    buf = buf.at[bidx, eidx, pos_c].add(contrib)
    buf = shard(buf, ("pod", "data"), "model", None, None)   # EP boundary

    # --- expert GEMMs (batched over experts; EP on "model") ----------------
    wg = p["wg"].astype(COMPUTE_DTYPE)
    wu = p["wu"].astype(COMPUTE_DTYPE)
    wd = p["wd"].astype(COMPUTE_DTYPE)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, wg))
    h = h * jnp.einsum("becd,edf->becf", buf, wu)
    h = shard(h, ("pod", "data"), "model", None, None)
    out_buf = jnp.einsum("becf,efd->becd", h, wd)
    out_buf = shard(out_buf, ("pod", "data"), "model", None, None)

    # --- combine: gather back + gate-weighted sum over k -------------------
    gathered = out_buf[bidx, eidx, pos_c]                    # (B, T, k, D)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    out = (gathered * gate_vals[..., None].astype(COMPUTE_DTYPE)).sum(axis=2)

    if m.n_shared:
        s = p["shared"]
        g = jax.nn.silu(xc @ s["wg"].astype(COMPUTE_DTYPE))
        out = out + (g * (xc @ s["wu"].astype(COMPUTE_DTYPE))
                     ) @ s["wd"].astype(COMPUTE_DTYPE)
    return out.astype(x.dtype), aux.astype(jnp.float32)

"""Shared layer library: norms, rotary, GQA attention (train/prefill/
decode, causal / prefix-LM / sliding-window), gated MLPs.

All functions are pure; parameters are nested dicts declared via
``distributed.pspec.ParamDef``.  Compute dtype is bf16 with f32 softmax
and norm statistics (MaxText convention); params stay f32 (the optimizer
and FSDP sharding own their memory layout).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.pspec import ParamDef

COMPUTE_DTYPE = jnp.bfloat16

Params = Any

# --- scan unrolling (dry-run mode) -----------------------------------------
# XLA's HLO cost analysis counts a while-loop body ONCE regardless of trip
# count, which would corrupt the roofline table.  The dry-run sets
# set_unroll(True) so layer stacks lower as straight-line code with exact
# FLOP/byte accounting; training/serving keep the compact scan form.
_UNROLL_SCANS = False


def set_unroll(v: bool) -> None:
    global _UNROLL_SCANS
    _UNROLL_SCANS = bool(v)


def scan_layers(body, carry, xs, length: int | None = None):
    """jax.lax.scan, or an unrolled Python loop under dry-run mode."""
    if not _UNROLL_SCANS:
        return jax.lax.scan(body, carry, xs)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and jax.tree.leaves(ys[0]):
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = ys[0] if ys else None
    return carry, stacked


# activation layout mode: "tp" (default; heads/mlp constraints on the
# "model" axis) or "fsdp2d" (§Perf: no TP — the "model" axis becomes a
# second data axis; activation constraints drop "model" and the batch
# rides all axes).
_LAYOUT = "tp"


def set_layout(mode: str) -> None:
    global _LAYOUT
    assert mode in ("tp", "fsdp2d")
    global BATCH_AXES
    _LAYOUT = mode
    BATCH_AXES = (("pod", "data", "model") if mode == "fsdp2d"
                  else ("pod", "data"))


def shard(x: jnp.ndarray, *axes) -> jnp.ndarray:
    """Sharding constraint filtered to the axes of the ambient mesh.

    No-op outside a mesh context (CPU unit tests); on the production
    mesh, unknown axis names (e.g. "pod" on the single-pod mesh) are
    dropped from the spec so the same model code serves every mesh.
    Under the fsdp2d layout, lone "model" activation constraints are
    dropped (the model axis carries batch, not heads).
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    if _LAYOUT == "fsdp2d":
        axes = tuple(None if a == "model" else a for a in axes)
    sizes = dict(mesh.shape)

    def keep(a, dim):
        if a is None:
            return None
        if isinstance(a, (tuple, list)):
            kept = tuple(n for n in a if n in sizes)
            total = 1
            for n in kept:
                total *= sizes[n]
            return kept if kept and dim % total == 0 else None
        if a in sizes and dim % sizes[a] == 0:
            return a
        return None

    spec = P(*[keep(a, d) for a, d in zip(axes, x.shape)])
    return jax.lax.with_sharding_constraint(x, spec)


BATCH_AXES = ("pod", "data")  # logical batch -> these mesh axes


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm_def(d: int) -> ParamDef:
    return ParamDef((d,), ("embed",), init="ones")


def rmsnorm(scale: jnp.ndarray, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def groupnorm(x: jnp.ndarray, n_groups: int, eps: float = 1e-5) -> jnp.ndarray:
    """GroupNorm over the last dim (RWKV6 head-wise ln_x), no affine."""
    *lead, d = x.shape
    xf = x.astype(jnp.float32).reshape(*lead, n_groups, d // n_groups)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return out.reshape(*lead, d).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, T, H, Dh) with even Dh; positions: (B, T) int32."""
    dh = x.shape[-1]
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freq        # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
# §Perf iteration 1 (EXPERIMENTS.md): blockwise online-softmax attention.
# The naive path materialises (B, H, Tq, Tk) f32 probabilities -- the
# dominant HBM-bytes term of every train/prefill cell.  The blockwise
# path streams KV in blocks with a running (max, denom, acc) carry, so
# per-step footprint is (B, H, Tq, BLOCK) and total attention bytes drop
# ~Tk/BLOCK-fold.  Enabled when Tk >= _BLOCKWISE_MIN (off for smoke-test
# shapes, on for the 4k-512k assigned shapes).
_BLOCKWISE_MIN = 2048
_KV_BLOCK = 512


def set_blockwise_min(n: int) -> None:
    """Test/benchmark hook: threshold for the blockwise attention path."""
    global _BLOCKWISE_MIN
    _BLOCKWISE_MIN = n


# §Perf switch: slice sliding-window decode to the last `window` cache
# positions (base dry-run layout disables it for a faithful baseline)
_WINDOW_SLICE = True


def set_window_slice(v: bool) -> None:
    global _WINDOW_SLICE
    _WINDOW_SLICE = bool(v)


@dataclasses.dataclass(frozen=True)
class AttnShape:
    n_heads: int
    n_kv: int
    d_head: int


def attention_defs(d_model: int, a: AttnShape) -> dict:
    return {
        "wq": ParamDef((d_model, a.n_heads, a.d_head), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d_model, a.n_kv, a.d_head), ("embed", "kv", "head_dim")),
        "wv": ParamDef((d_model, a.n_kv, a.d_head), ("embed", "kv", "head_dim")),
        "wo": ParamDef((a.n_heads, a.d_head, d_model), ("heads", "head_dim", "embed")),
    }


def attend(
    q: jnp.ndarray,                # (B, Tq, Hq, Dh)
    k: jnp.ndarray,                # (B, Tk, Hkv, Dh)
    v: jnp.ndarray,                # (B, Tk, Hkv, Dv)
    *,
    causal: bool = True,
    q_offset: jnp.ndarray | int = 0,
    kv_len: jnp.ndarray | None = None,   # valid cache length (decode)
    prefix_len: jnp.ndarray | int = 0,   # prefix-LM bidirectional span
    window: int = 0,                     # sliding window (0 = full)
    scale: float | None = None,
) -> jnp.ndarray:
    """Grouped-query attention with composable masking.  f32 softmax.

    KV heads are broadcast to the full query-head count before the score
    einsum so the head dim stays shardable on the "model" axis (a
    4-KV-head split reshape would force replication under GSPMD).
    """
    B, Tq, Hq, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else Dh ** -0.5
    if Tk >= _BLOCKWISE_MIN and Tq > 1:
        return _attend_blockwise(
            q, k, v, causal=causal, q_offset=q_offset, kv_len=kv_len,
            prefix_len=prefix_len, window=window, scale=scale)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        k = shard(k, BATCH_AXES, None, "model", None)
        v = shard(v, BATCH_AXES, None, "model", None)
    logits = jnp.einsum("bthd,bshd->bhts", q, k,
                        preferred_element_type=jnp.float32) * scale

    qpos = q_offset + jnp.arange(Tq)[:, None]          # (Tq, 1)
    kpos = jnp.arange(Tk)[None, :]                     # (1, Tk)
    mask = jnp.ones((Tq, Tk), dtype=bool)
    if causal:
        cm = kpos <= qpos
        if not isinstance(prefix_len, int) or prefix_len != 0:
            cm |= kpos < prefix_len
        mask &= cm
    if window:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhts,bshd->bthd", probs, v)
    return out


def _attend_blockwise(q, k, v, *, causal, q_offset, kv_len, prefix_len,
                      window, scale, block=None):
    """Online-softmax attention over KV blocks (FlashAttention schedule
    in pure JAX; the TPU kernel equivalent fuses this into VMEM tiles).

    Mathematically identical to :func:`attend`'s naive path; property
    tests assert allclose.  Each block step is rematerialised so the
    backward pass never holds more than one block's logits.
    """
    B, Tq, Hq, Dh = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
        k = shard(k, BATCH_AXES, None, "model", None)
        v = shard(v, BATCH_AXES, None, "model", None)
    blk = block or _KV_BLOCK
    blk = min(blk, Tk)
    pad = (-Tk) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (Tk + pad) // blk
    kb = k.reshape(B, nb, blk, Hq, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, blk, Hq, Dh).transpose(1, 0, 2, 3, 4)
    qpos = q_offset + jnp.arange(Tq)[:, None]              # (Tq, 1)
    qf = q.astype(jnp.float32)

    def step(carry, xs):
        acc, m, denom = carry
        kv_i, (ki, vi) = xs
        lg = jnp.einsum("bthd,bshd->bhts", qf, ki.astype(jnp.float32)) * scale
        kpos = kv_i * blk + jnp.arange(blk)[None, :]
        mask = kpos < Tk
        if causal:
            cm = kpos <= qpos
            if not isinstance(prefix_len, int) or prefix_len != 0:
                cm |= kpos < prefix_len
            mask &= cm
        if window:
            mask &= kpos > qpos - window
        if kv_len is not None:
            mask &= kpos < kv_len
        lg = jnp.where(mask[None, None], lg, -1e30)
        m_new = jnp.maximum(m, lg.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(lg - m_new[..., None])
        denom = denom * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhts,bshd->bhtd", p, vi.astype(jnp.float32))
        return (acc, m_new, denom), None

    init = (jnp.zeros((B, Hq, Tq, Dh), jnp.float32),
            jnp.full((B, Hq, Tq), -jnp.inf, jnp.float32),
            jnp.zeros((B, Hq, Tq), jnp.float32))
    step = jax.checkpoint(step)
    # scan_layers so the dry-run's unroll mode sees exact per-block costs
    (acc, m, denom), _ = scan_layers(step, init,
                                     (jnp.arange(nb), (kb, vb)), length=nb)
    out = acc / jnp.maximum(denom, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(v.dtype)


def attention_block(
    p: Params,
    x: jnp.ndarray,                 # (B, T, D)
    *,
    shape: AttnShape,
    rope_theta: float = 10000.0,
    positions: jnp.ndarray | None = None,
    causal: bool = True,
    prefix_len=0,
    window: int = 0,
    cache: dict | None = None,      # {"k","v" (B, S, Hkv, Dh), "len"}
) -> tuple[jnp.ndarray, dict | None]:
    """Self-attention with optional KV cache (prefill fills, decode appends)."""
    B, T, _ = x.shape
    xc = x.astype(COMPUTE_DTYPE)
    q = jnp.einsum("btd,dhk->bthk", xc, p["wq"].astype(COMPUTE_DTYPE))
    k = jnp.einsum("btd,dhk->bthk", xc, p["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("btd,dhk->bthk", xc, p["wv"].astype(COMPUTE_DTYPE))
    q = shard(q, BATCH_AXES, None, "model", None)
    k = shard(k, BATCH_AXES, None, "model", None)
    v = shard(v, BATCH_AXES, None, "model", None)

    if cache is None:
        pos = positions if positions is not None else (
            jnp.broadcast_to(jnp.arange(T)[None], (B, T)))
        if rope_theta:
            q, k = rope(q, pos, rope_theta), rope(k, pos, rope_theta)
        out = attend(q, k, v, causal=causal, prefix_len=prefix_len,
                     window=window)
        new_cache = None
    else:
        cur = cache["len"]
        pos = cur + jnp.arange(T)[None] + jnp.zeros((B, 1), jnp.int32)
        if rope_theta:
            q, k = rope(q, pos, rope_theta), rope(k, pos, rope_theta)
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), cur, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), cur, axis=1)
        S = ck.shape[1]
        if window and _WINDOW_SLICE and S > 2 * window and T <= window:
            # §Perf (long_500k): sliding-window decode only ever attends
            # to the last `window` positions — slice them out instead of
            # masking the whole 500k cache (bytes drop ~S/window-fold)
            start = jnp.clip(cur + T - window, 0, S - window)
            ck_w = jax.lax.dynamic_slice_in_dim(ck, start, window, axis=1)
            cv_w = jax.lax.dynamic_slice_in_dim(cv, start, window, axis=1)
            out = attend(q, ck_w, cv_w, causal=True, q_offset=cur - start,
                         kv_len=cur + T - start, prefix_len=prefix_len,
                         window=window)
        else:
            out = attend(q, ck, cv, causal=True, q_offset=cur,
                         kv_len=cur + T, prefix_len=prefix_len,
                         window=window)
        new_cache = {"k": ck, "v": cv, "len": cur + T}
    out = jnp.einsum("bthk,hkd->btd", out, p["wo"].astype(COMPUTE_DTYPE))
    return out.astype(x.dtype), new_cache


def init_kv_cache(batch: int, max_len: int, shape: AttnShape,
                  dtype=COMPUTE_DTYPE) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, shape.n_kv, shape.d_head), dtype),
        "v": jnp.zeros((batch, max_len, shape.n_kv, shape.d_head), dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_defs(d_model: int, d_ff: int, act: str) -> dict:
    if act in ("silu", "relu_sq"):   # gated
        return {
            "wg": ParamDef((d_model, d_ff), ("embed", "mlp")),
            "wu": ParamDef((d_model, d_ff), ("embed", "mlp")),
            "wd": ParamDef((d_ff, d_model), ("mlp", "embed")),
        }
    return {
        "wi": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "wd": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }


def mlp(p: Params, x: jnp.ndarray, act: str) -> jnp.ndarray:
    xc = x.astype(COMPUTE_DTYPE)
    if "wg" in p:
        g = xc @ p["wg"].astype(COMPUTE_DTYPE)
        u = xc @ p["wu"].astype(COMPUTE_DTYPE)
        g = shard(g, BATCH_AXES, None, "model")
        if act == "relu_sq":
            h = jnp.square(jax.nn.relu(g)) * u
        else:
            h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(xc @ p["wi"].astype(COMPUTE_DTYPE))
        h = shard(h, BATCH_AXES, None, "model")
    out = h @ p["wd"].astype(COMPUTE_DTYPE)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------
def embed_defs(vocab: int, d_model: int) -> ParamDef:
    return ParamDef((vocab, d_model), ("vocab", "embed"), init="embed")


def embed(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    out = jnp.take(table, tokens, axis=0).astype(COMPUTE_DTYPE)
    return shard(out, BATCH_AXES, None, None)


def logits(table_or_head: jnp.ndarray, x: jnp.ndarray,
           transpose: bool) -> jnp.ndarray:
    """Final projection; vocab dim sharded over 'model'."""
    w = table_or_head.astype(COMPUTE_DTYPE)
    out = jnp.einsum("btd,vd->btv" if transpose else "btd,dv->btv", x, w)
    return shard(out, BATCH_AXES, None, "model")


def cross_entropy(lg: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token NLL with f32 logsumexp (vocab may be sharded)."""
    lg = lg.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    tgt = jnp.take_along_axis(lg, targets[..., None], axis=-1)[..., 0]
    nll = lse - tgt
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()

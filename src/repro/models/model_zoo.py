"""Model registry: uniform API over the 10 assigned architectures.

    zoo = get_model(cfg)
    defs   = zoo.param_defs(cfg)                      # ParamDef tree
    loss   = zoo.loss_fn(cfg, params, batch)          # train
    lg, c  = zoo.prefill(cfg, params, batch, cache)   # inference-prefill
    lg, c  = zoo.decode(cfg, params, batch, cache)    # one-token decode
    cache  = zoo.init_cache(cfg, batch, max_len)
    batch  = input_specs(cfg, shape)                  # ShapeDtypeStructs
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, Family, ShapeCfg
from repro.distributed import pspec
from repro.models import mamba2, rwkv, transformer, whisper


@dataclasses.dataclass(frozen=True)
class Zoo:
    param_defs: Callable
    loss_fn: Callable
    forward: Callable
    init_cache: Callable


def get_model(cfg: ArchConfig) -> Zoo:
    if cfg.family == Family.SSM:
        return Zoo(rwkv.param_defs, rwkv.loss_fn, rwkv.forward,
                   rwkv.init_cache)
    if cfg.family == Family.HYBRID:
        return Zoo(mamba2.param_defs, mamba2.loss_fn, mamba2.forward,
                   mamba2.init_cache)
    if cfg.family == Family.AUDIO:
        return Zoo(whisper.param_defs, whisper.loss_fn, whisper.forward,
                   whisper.init_cache)
    return Zoo(transformer.param_defs, transformer.loss_fn,
               transformer.forward, transformer.init_cache)


def param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    """Total (or routing-active) parameter count from the ParamDef tree."""
    defs = get_model(cfg).param_defs(cfg)
    total = pspec.param_count(defs)
    if active_only and cfg.moe is not None:
        m = cfg.moe
        from repro.models.moe import padded_experts
        E = padded_experts(m)
        per_expert = 3 * cfg.d_model * m.d_ff_expert
        n_moe_layers = cfg.n_layers - m.first_dense_layers
        inactive = (E - m.top_k) * per_expert * n_moe_layers
        total -= inactive
    return total


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins -- no allocation)
# ---------------------------------------------------------------------------
def input_specs(cfg: ArchConfig, shape: ShapeCfg) -> dict[str, Any]:
    """Abstract inputs for a (train | prefill | decode) step.

    Decode batches carry ONE new token; the KV/state cache of
    ``shape.seq_len`` is built separately via ``abstract_cache``.
    """
    B = shape.global_batch
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        T = 1
    elif cfg.family == Family.AUDIO:
        T = max(shape.seq_len // cfg.dec_ratio, 8)   # decoder text length
    elif cfg.family == Family.VLM and shape.kind != "decode":
        T = shape.seq_len - cfg.n_image_tokens       # text tokens after prefix
    else:
        T = shape.seq_len
    batch: dict[str, Any] = {"tokens": sds((B, T), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = sds((B, T), jnp.int32)
    if cfg.family == Family.AUDIO and shape.kind != "decode":
        batch["frames"] = sds((B, shape.seq_len, cfg.d_model), jnp.bfloat16)
    if cfg.family == Family.VLM and shape.kind != "decode":
        batch["img_embeds"] = sds((B, cfg.n_image_tokens, cfg.d_model),
                                  jnp.bfloat16)
    return batch


def abstract_cache(cfg: ArchConfig, shape: ShapeCfg):
    """ShapeDtypeStruct tree of the decode cache (length = shape.seq_len)."""
    zoo = get_model(cfg)
    cache = jax.eval_shape(
        lambda: zoo.init_cache(cfg, shape.global_batch, shape.seq_len))
    return cache


def concrete_batch(cfg: ArchConfig, shape: ShapeCfg, seed: int = 0) -> dict:
    """Materialised random batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, s in input_specs(cfg, shape).items():
        if s.dtype == jnp.int32:
            arr = rng.integers(0, cfg.vocab, size=s.shape).astype(np.int32)
        else:
            arr = rng.normal(size=s.shape).astype(np.float32)
        out[name] = jnp.asarray(arr, s.dtype)
    return out

"""RWKV6 "Finch" (arXiv:2404.05892): attention-free decoder with
data-dependent per-channel decay, executed through the chunked
linear-recurrence kernel (``kernels.ops.chunk_scan``, bonus form).

Decode state per layer: time-mix token-shift (B, D), channel-mix
token-shift (B, D), and the recurrent matrix state (B, H, dk, dv) --
O(1) in context length, which is what makes the ``long_500k`` cell
runnable (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.pspec import ParamDef, stack_tree
from repro.kernels import ops
from repro.models import layers as L
from repro.models.layers import COMPUTE_DTYPE

LORA_RANK = 32
DECAY_LORA_RANK = 64
_MIX = ("r", "k", "v", "w", "g")


def _head_dims(cfg: ArchConfig) -> tuple[int, int]:
    hd = cfg.ssm.head_dim
    return cfg.d_model // hd, hd


def _layer_defs(cfg: ArchConfig) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    H, hd = _head_dims(cfg)
    tm: dict[str, Any] = {
        "mu_x": ParamDef((D,), ("embed",), init="zeros"),
        "w0": ParamDef((D,), ("embed",), init="zeros"),
        "decay_a": ParamDef((D, DECAY_LORA_RANK), ("embed", "lora"), scale=0.01),
        "decay_b": ParamDef((DECAY_LORA_RANK, D), ("lora", "embed"), scale=0.01),
        "bonus": ParamDef((H, hd), ("heads", "head_dim"), init="zeros"),
        "wo": ParamDef((D, D), ("heads", "embed")),
    }
    for m in _MIX:
        tm[f"mu_{m}"] = ParamDef((D,), ("embed",), init="zeros")
        tm[f"lora_a_{m}"] = ParamDef((D, LORA_RANK), ("embed", "lora"), scale=0.01)
        tm[f"lora_b_{m}"] = ParamDef((LORA_RANK, D), ("lora", "embed"), scale=0.01)
        if m != "w":
            tm[f"w_{m}"] = ParamDef((D, D), ("embed", "heads"))
    cm = {
        "mu_k": ParamDef((D,), ("embed",), init="zeros"),
        "mu_r": ParamDef((D,), ("embed",), init="zeros"),
        "wk": ParamDef((D, F), ("embed", "mlp")),
        "wv": ParamDef((F, D), ("mlp", "embed")),
        "wr": ParamDef((D, D), ("embed", "heads")),
    }
    return {"ln1": L.rmsnorm_def(D), "tm": tm,
            "ln2": L.rmsnorm_def(D), "cm": cm}


def param_defs(cfg: ArchConfig) -> dict:
    return {
        "embed": L.embed_defs(cfg.vocab, cfg.d_model),
        "layers": stack_tree(_layer_defs(cfg), cfg.n_layers),
        "ln_f": L.rmsnorm_def(cfg.d_model),
        "head": ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }


def _shift(x: jnp.ndarray, prev: jnp.ndarray | None) -> jnp.ndarray:
    """Token shift: x_{t-1}; position 0 uses carried state (or zero)."""
    first = jnp.zeros_like(x[:, :1]) if prev is None else prev[:, None, :]
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(p, name, x, xs_delta, xxx):
    mu = p[f"mu_{name}"].astype(COMPUTE_DTYPE)
    lora = jnp.tanh(xxx @ p[f"lora_a_{name}"].astype(COMPUTE_DTYPE))
    lora = lora @ p[f"lora_b_{name}"].astype(COMPUTE_DTYPE)
    return x + xs_delta * (mu + lora)


def _time_mix(cfg, p, x, state, impl):
    """state: None (train) or dict {shift (B, D), S (B*H, dk, dv)}."""
    B, T, D = x.shape
    H, hd = _head_dims(cfg)
    xc = x.astype(COMPUTE_DTYPE)
    prev = None if state is None else state["shift"]
    xs_delta = _shift(xc, prev) - xc
    xxx = xc + xs_delta * p["mu_x"].astype(COMPUTE_DTYPE)
    r = _ddlerp(p, "r", xc, xs_delta, xxx) @ p["w_r"].astype(COMPUTE_DTYPE)
    k = _ddlerp(p, "k", xc, xs_delta, xxx) @ p["w_k"].astype(COMPUTE_DTYPE)
    v = _ddlerp(p, "v", xc, xs_delta, xxx) @ p["w_v"].astype(COMPUTE_DTYPE)
    g = _ddlerp(p, "g", xc, xs_delta, xxx) @ p["w_g"].astype(COMPUTE_DTYPE)
    xw = _ddlerp(p, "w", xc, xs_delta, xxx)
    wlog = (p["w0"].astype(jnp.float32)
            + (jnp.tanh(xw @ p["decay_a"].astype(COMPUTE_DTYPE))
               @ p["decay_b"].astype(COMPUTE_DTYPE)).astype(jnp.float32))
    decay = jnp.exp(-jnp.exp(wlog))                        # (B, T, D) in (0,1)

    def heads(t):  # (B, T, D) -> (B*H, T, hd)
        return (t.reshape(B, T, H, hd).transpose(0, 2, 1, 3)
                .reshape(B * H, T, hd))

    bonus = jnp.broadcast_to(p["bonus"].astype(jnp.float32)[None],
                             (B, H, hd)).reshape(B * H, hd)
    s0 = None if state is None else state["S"]
    o, s_new = ops.chunk_scan(
        heads(r).astype(jnp.float32), heads(k).astype(jnp.float32),
        heads(v).astype(jnp.float32), heads(decay),
        bonus=bonus, state=s0, chunk=cfg.ssm.chunk, impl=impl)
    o = (o.reshape(B, H, T, hd).transpose(0, 2, 1, 3).reshape(B, T, D))
    o = L.groupnorm(o, H, eps=64e-5) * jax.nn.silu(g)
    out = (o.astype(COMPUTE_DTYPE) @ p["wo"].astype(COMPUTE_DTYPE)).astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {"shift": xc[:, -1, :], "S": s_new}
    return out, new_state


def _channel_mix(p, x, state):
    xc = x.astype(COMPUTE_DTYPE)
    prev = None if state is None else state["shift"]
    xs_delta = _shift(xc, prev) - xc
    xk = xc + xs_delta * p["mu_k"].astype(COMPUTE_DTYPE)
    xr = xc + xs_delta * p["mu_r"].astype(COMPUTE_DTYPE)
    k = jnp.square(jax.nn.relu(xk @ p["wk"].astype(COMPUTE_DTYPE)))
    k = L.shard(k, L.BATCH_AXES, None, "model")
    kv = k @ p["wv"].astype(COMPUTE_DTYPE)
    out = jax.nn.sigmoid(xr @ p["wr"].astype(COMPUTE_DTYPE)) * kv
    new_state = None if state is None else {"shift": xc[:, -1, :]}
    return out.astype(x.dtype), new_state


def _block(cfg, p, x, state, impl):
    tm_state = None if state is None else state["tm"]
    cm_state = None if state is None else state["cm"]
    a, tm_new = _time_mix(cfg, p["tm"], L.rmsnorm(p["ln1"], x, cfg.norm_eps),
                          tm_state, impl)
    x = x + a
    b, cm_new = _channel_mix(p["cm"], L.rmsnorm(p["ln2"], x, cfg.norm_eps),
                             cm_state)
    x = x + b
    new_state = None if state is None else {"tm": tm_new, "cm": cm_new}
    return x, new_state


def forward(cfg: ArchConfig, params, batch: dict, *, mode: str = "train",
            cache=None, impl: str = "auto"):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x = L.shard(x, L.BATCH_AXES, None, None)
    remat = mode == "train"

    def body(carry, xs):
        h = carry
        p, st = xs
        h, new_st = _block(cfg, p, h, st, impl)
        return h, new_st

    if remat:
        body = jax.checkpoint(body)
    x, new_states = L.scan_layers(body, x, (params["layers"], cache),
                                  length=cfg.n_layers)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    lg = L.logits(params["head"], x, transpose=False)
    return lg, new_states, jnp.float32(0.0)


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Recurrent state -- O(1) in ``max_len`` (the SSM long-context win)."""
    del max_len
    H, hd = _head_dims(cfg)
    one = {
        "tm": {"shift": jnp.zeros((batch, cfg.d_model), COMPUTE_DTYPE),
               "S": jnp.zeros((batch * H, hd, hd), jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, cfg.d_model), COMPUTE_DTYPE)},
    }
    return jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), one)


def loss_fn(cfg: ArchConfig, params, batch: dict):
    lg, _, _ = forward(cfg, params, batch, mode="train")
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return L.cross_entropy(lg[:, :-1], jnp.maximum(labels[:, 1:], 0),
                           mask[:, 1:])

"""Generic decoder-only transformer covering the dense/GQA family
(tinyllama, minitron, granite, stablelm), the MoE family (qwen2-moe,
deepseek-v2 incl. MLA), and the VLM backbone (paligemma prefix-LM).

Layers are homogeneous and scanned (stacked params -> one compiled block,
O(1) HLO size in depth); DeepSeek's leading dense-FFN layer(s) run
outside the scan.  Training wraps the block in ``jax.checkpoint``
(configurable remat policy).

Modes:
  train   — causal forward, next-token CE loss
  prefill — causal forward filling a KV cache of length seq_len
  decode  — T new tokens against an existing cache
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.configs.base import ArchConfig
from repro.distributed.pspec import ParamDef, stack_tree
from repro.models import layers as L
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models.layers import AttnShape, COMPUTE_DTYPE

REMAT_POLICY = jax.checkpoint_policies.save_only_these_names(
    "attn_out", "mlp_out")

# §Perf: remat is a memory<->compute trade.  Under the FSDP-2D train
# layout the per-chip activation footprint is small (batch 1 seq/chip),
# so remat only wastes FLOPs and an extra FSDP weight-gather pass.
_USE_REMAT = True


def set_remat(v: bool) -> None:
    global _USE_REMAT
    _USE_REMAT = bool(v)


def _attn_shape(cfg: ArchConfig) -> AttnShape:
    return AttnShape(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)


def _layer_defs(cfg: ArchConfig, dense_ffn_width: int | None = None) -> dict:
    d: dict[str, Any] = {"ln1": L.rmsnorm_def(cfg.d_model),
                         "ln2": L.rmsnorm_def(cfg.d_model)}
    if cfg.mla is not None:
        d["attn"] = mla_lib.mla_defs(cfg)
    else:
        d["attn"] = L.attention_defs(cfg.d_model, _attn_shape(cfg))
    if dense_ffn_width is not None:
        d["mlp"] = L.mlp_defs(cfg.d_model, dense_ffn_width, cfg.act)
    elif cfg.moe is not None:
        d["moe"] = moe_lib.moe_defs(cfg.d_model, cfg.moe)
    else:
        d["mlp"] = L.mlp_defs(cfg.d_model, cfg.d_ff, cfg.act)
    return d


def _n_dense_lead(cfg: ArchConfig) -> int:
    return cfg.moe.first_dense_layers if cfg.moe else 0


def param_defs(cfg: ArchConfig) -> dict:
    n_lead = _n_dense_lead(cfg)
    defs: dict[str, Any] = {
        "embed": L.embed_defs(cfg.vocab, cfg.d_model),
        "layers": stack_tree(_layer_defs(cfg), cfg.n_layers - n_lead),
        "ln_f": L.rmsnorm_def(cfg.d_model),
    }
    if n_lead:
        defs["lead_layers"] = stack_tree(
            _layer_defs(cfg, dense_ffn_width=cfg.moe.d_ff_dense), n_lead)
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    if cfg.n_image_tokens:
        # stub projection applied to precomputed patch embeddings
        defs["img_proj"] = ParamDef((cfg.d_model, cfg.d_model),
                                    ("embed", None))
    return defs


def _block(cfg: ArchConfig, p, x, cache, *, mode: str, prefix_len=0):
    """One decoder layer.  cache: per-layer dict or None."""
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        attn_out, new_cache = mla_lib.mla_attention(
            p["attn"], h, cfg, cache=cache,
            absorbed=(mode == "decode"))
    else:
        attn_out, new_cache = L.attention_block(
            p["attn"], h, shape=_attn_shape(cfg), rope_theta=cfg.rope_theta,
            prefix_len=prefix_len, window=cfg.sliding_window, cache=cache)
    attn_out = checkpoint_name(attn_out, "attn_out")
    x = x + attn_out
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if "moe" in p:
        ffn_out, aux = moe_lib.moe_ffn(p["moe"], h, cfg.moe,
                                       dropless=(mode != "train"))
    else:
        ffn_out, aux = L.mlp(p["mlp"], h, cfg.act), jnp.float32(0.0)
    ffn_out = checkpoint_name(ffn_out, "mlp_out")
    return x + ffn_out, new_cache, aux


def _scan_layers(cfg, stacked, x, caches, *, mode, prefix_len, remat):
    """lax.scan over stacked layer params (and stacked caches)."""
    block = functools.partial(_block, cfg, mode=mode, prefix_len=prefix_len)
    if remat and _USE_REMAT:
        block = jax.checkpoint(block, policy=REMAT_POLICY)

    def body(carry, xs):
        x, aux = carry
        p, cache = xs
        x, new_cache, a = block(p, x, cache)
        return (x, aux + a), new_cache

    (x, aux), new_caches = L.scan_layers(body, (x, jnp.float32(0.0)),
                                         (stacked, caches))
    return x, aux, new_caches


def forward(
    cfg: ArchConfig,
    params,
    batch: dict,
    *,
    mode: str = "train",
    cache=None,
):
    """Returns (logits, new_cache, aux_loss).

    batch: tokens (B, T) int32; optionally img_embeds (B, N_img, D) for
    the VLM (prefix-LM over the image span).
    """
    tokens = batch["tokens"]
    B, T = tokens.shape
    x = L.embed(params["embed"], tokens)
    prefix_len = 0
    if cfg.n_image_tokens and "img_embeds" in batch:
        img = batch["img_embeds"].astype(COMPUTE_DTYPE)
        img = img @ params["img_proj"].astype(COMPUTE_DTYPE)
        x = jnp.concatenate([img, x], axis=1)
        prefix_len = cfg.n_image_tokens
    if cfg.arch_id.startswith("paligemma") or cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)   # gemma convention
    x = L.shard(x, L.BATCH_AXES, None, None)

    remat = mode == "train"
    aux = jnp.float32(0.0)
    n_lead = _n_dense_lead(cfg)
    if n_lead:
        lead_cache = None if cache is None else cache["lead"]
        x, a, new_lead = _scan_layers(
            cfg, params["lead_layers"], x, lead_cache,
            mode=mode, prefix_len=prefix_len, remat=remat)
        aux += a
    scan_cache = None if cache is None else cache["layers"]
    x, a, new_caches = _scan_layers(
        cfg, params["layers"], x, scan_cache,
        mode=mode, prefix_len=prefix_len, remat=remat)
    aux += a

    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        lg = L.logits(params["embed"], x, transpose=True)
    else:
        lg = L.logits(params["head"], x, transpose=False)
    new_cache = None
    if cache is not None:
        new_cache = {"layers": new_caches}
        if n_lead:
            new_cache["lead"] = new_lead
    return lg, new_cache, aux


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Stacked (L, ...) caches for the scanned layers."""
    def one(n):
        if cfg.mla is not None:
            c = mla_lib.init_mla_cache(cfg, batch, max_len)
        else:
            c = L.init_kv_cache(batch, max_len, _attn_shape(cfg))
        return jax.tree.map(lambda x: jnp.stack([x] * n), c)

    n_lead = _n_dense_lead(cfg)
    out = {"layers": one(cfg.n_layers - n_lead)}
    if n_lead:
        out["lead"] = one(n_lead)
    return out


def loss_fn(cfg: ArchConfig, params, batch: dict):
    lg, _, aux = forward(cfg, params, batch, mode="train")
    labels = batch["labels"]
    if cfg.n_image_tokens and "img_embeds" in batch:
        # loss only over text positions
        pad = jnp.full((labels.shape[0], cfg.n_image_tokens), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    mask = (labels >= 0).astype(jnp.float32)
    loss = L.cross_entropy(lg[:, :-1], jnp.maximum(labels[:, 1:], 0),
                           mask[:, 1:])
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux / cfg.n_layers
    return loss

"""Whisper-style encoder-decoder (arXiv:2212.04356).

The conv frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, T_enc, d_model).  Encoder: bidirectional
self-attention with sinusoidal positions.  Decoder: causal self-attention
+ cross-attention to the encoder output, learned positions.  Decode
caches both the self-attention KV and the (fixed) cross-attention KV.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.pspec import ParamDef, stack_tree
from repro.models import layers as L
from repro.models.layers import AttnShape, COMPUTE_DTYPE

MAX_DEC_POS = 65536   # covers decode_32k; whisper's 448 is a runtime limit


def _shape(cfg: ArchConfig) -> AttnShape:
    return AttnShape(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)


def _enc_layer_defs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.rmsnorm_def(cfg.d_model),
        "attn": L.attention_defs(cfg.d_model, _shape(cfg)),
        "ln2": L.rmsnorm_def(cfg.d_model),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, cfg.act),
    }


def _dec_layer_defs(cfg: ArchConfig) -> dict:
    d = _enc_layer_defs(cfg)
    d["ln_x"] = L.rmsnorm_def(cfg.d_model)
    d["xattn"] = L.attention_defs(cfg.d_model, _shape(cfg))
    return d


def param_defs(cfg: ArchConfig) -> dict:
    return {
        "embed": L.embed_defs(cfg.vocab, cfg.d_model),
        "dec_pos": ParamDef((MAX_DEC_POS, cfg.d_model), (None, "embed"),
                            init="embed"),
        "enc_layers": stack_tree(_enc_layer_defs(cfg), cfg.enc_layers),
        "dec_layers": stack_tree(_dec_layer_defs(cfg), cfg.n_layers),
        "ln_enc": L.rmsnorm_def(cfg.d_model),
        "ln_f": L.rmsnorm_def(cfg.d_model),
    }


def _sinusoid(T: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (dim / (d // 2)))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_block(cfg, p, x):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, _ = L.attention_block(p["attn"], h, shape=_shape(cfg), rope_theta=0.0,
                             causal=False)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.mlp(p["mlp"], h, cfg.act)


def encode(cfg: ArchConfig, params, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T_enc, d_model) stub embeddings -> encoder output."""
    x = frames.astype(COMPUTE_DTYPE) + _sinusoid(
        frames.shape[1], cfg.d_model).astype(COMPUTE_DTYPE)[None]
    x = L.shard(x, L.BATCH_AXES, None, None)

    def body(carry, p):
        return _enc_block(cfg, p, carry), None

    body_fn = jax.checkpoint(body)
    x, _ = L.scan_layers(body_fn, x, params["enc_layers"],
                         length=cfg.enc_layers)
    return L.rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def _xattn_kv(p, enc_out):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"].astype(COMPUTE_DTYPE))
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"].astype(COMPUTE_DTYPE))
    return k, v


def _dec_block(cfg, p, x, enc_out, cache, xkv=None):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_cache = L.attention_block(
        p["attn"], h, shape=_shape(cfg), rope_theta=0.0, cache=cache)
    x = x + a
    # cross attention (precomputed KV at decode time)
    h = L.rmsnorm(p["ln_x"], x, cfg.norm_eps)
    q = jnp.einsum("btd,dhk->bthk", h.astype(COMPUTE_DTYPE),
                   p["xattn"]["wq"].astype(COMPUTE_DTYPE))
    if xkv is None:
        k, v = _xattn_kv(p["xattn"], enc_out)
    else:
        k, v = xkv
    a = L.attend(q, k, v, causal=False)
    a = jnp.einsum("bthk,hkd->btd", a, p["xattn"]["wo"].astype(COMPUTE_DTYPE))
    x = x + a.astype(x.dtype)
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.mlp(p["mlp"], h, cfg.act), new_cache


def forward(cfg: ArchConfig, params, batch: dict, *, mode: str = "train",
            cache=None):
    """batch: frames (B, T_enc, D) [train/prefill], tokens (B, T_dec)."""
    tokens = batch["tokens"]
    B, T = tokens.shape
    if "frames" in batch:
        # train/prefill: run the encoder; at prefill also precompute the
        # per-layer cross-attention KV and store it in the cache
        enc_out = encode(cfg, params, batch["frames"])
        xkv_fresh = None
        if cache is not None:
            xkv_fresh = jax.lax.map(
                lambda p: _xattn_kv(p["xattn"], enc_out),
                params["dec_layers"])
    else:
        enc_out = cache["enc_out"]
        xkv_fresh = None
    offset = 0 if cache is None else cache["len"]
    pos_emb = jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], offset if cache is not None else 0, T, axis=0)
    x = L.embed(params["embed"], tokens) + pos_emb[None].astype(COMPUTE_DTYPE)
    x = L.shard(x, L.BATCH_AXES, None, None)

    self_cache = None if cache is None else cache["self"]
    xkv_cache = None if cache is None else (
        xkv_fresh if xkv_fresh is not None else cache["xkv"])

    def body(carry, xs):
        h = carry
        p, sc, xkv = xs
        h, new_sc = _dec_block(cfg, p, h, enc_out, sc, xkv)
        return h, new_sc

    if mode == "train":
        body = jax.checkpoint(body)
    x, new_self = L.scan_layers(body, x,
                                (params["dec_layers"], self_cache, xkv_cache),
                                length=cfg.n_layers)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    lg = L.logits(params["embed"], x, transpose=True)   # tied head
    new_cache = None
    if cache is not None:
        new_cache = {"self": new_self, "xkv": xkv_cache,
                     "enc_out": enc_out, "len": cache["len"] + T}
    return lg, new_cache, jnp.float32(0.0)


def make_cache(cfg: ArchConfig, params, frames: jnp.ndarray,
               max_len: int) -> dict:
    """Build the decode cache: encoder output + per-layer cross KV."""
    enc_out = encode(cfg, params, frames)

    xkv = jax.lax.map(lambda p: _xattn_kv(p["xattn"], enc_out),
                      params["dec_layers"])
    B = frames.shape[0]
    self_one = L.init_kv_cache(B, max_len, _shape(cfg))
    self_c = jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), self_one)
    return {"self": self_c, "xkv": xkv, "enc_out": enc_out,
            "len": jnp.zeros((), jnp.int32)}


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    """Shape-only cache (dry-run): encoder length = max_len // dec_ratio...
    encoder output and cross-KV sized by the shape's frame count."""
    t_enc = max(max_len // cfg.dec_ratio, 1)
    self_one = L.init_kv_cache(batch, max_len, _shape(cfg))
    self_c = jax.tree.map(lambda x: jnp.stack([x] * cfg.n_layers), self_one)
    sh = _shape(cfg)
    xkv = (jnp.zeros((cfg.n_layers, batch, t_enc, sh.n_kv, sh.d_head),
                     COMPUTE_DTYPE),
           jnp.zeros((cfg.n_layers, batch, t_enc, sh.n_kv, sh.d_head),
                     COMPUTE_DTYPE))
    return {"self": self_c, "xkv": xkv,
            "enc_out": jnp.zeros((batch, t_enc, cfg.d_model), COMPUTE_DTYPE),
            "len": jnp.zeros((), jnp.int32)}


def loss_fn(cfg: ArchConfig, params, batch: dict):
    lg, _, _ = forward(cfg, params, batch, mode="train")
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return L.cross_entropy(lg[:, :-1], jnp.maximum(labels[:, 1:], 0),
                           mask[:, 1:])

"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Queries and KV are produced through low-rank latents; only the
``kv_lora``-dim latent + shared rope key are cached at decode time
(the MLA memory win: 512+64 floats/token vs 2*128*192 for plain MHA).

Two execution forms:
  * direct (train/prefill): latents are up-projected to per-head K/V and
    standard attention runs;
  * absorbed (decode): W_UK is folded into the query and W_UV into the
    output projection, so attention runs directly in latent space and
    NO per-step recomputation of the full K/V history is needed.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, MLACfg
from repro.distributed.pspec import ParamDef
from repro.models.layers import (
    BATCH_AXES, COMPUTE_DTYPE, rmsnorm, rmsnorm_def, rope, shard,
)


def mla_defs(cfg: ArchConfig) -> dict:
    m = cfg.mla
    H, D = cfg.n_heads, cfg.d_model
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wq_a": ParamDef((D, m.q_lora_rank), ("embed", "lora")),
        "q_norm": rmsnorm_def(m.q_lora_rank),
        "wq_b": ParamDef((m.q_lora_rank, H, qk), ("lora", "heads", "head_dim")),
        "wkv_a": ParamDef((D, m.kv_lora_rank + m.qk_rope_dim), ("embed", "lora")),
        "kv_norm": rmsnorm_def(m.kv_lora_rank),
        "wk_b": ParamDef((m.kv_lora_rank, H, m.qk_nope_dim),
                         ("lora", "heads", "head_dim")),
        "wv_b": ParamDef((m.kv_lora_rank, H, m.v_head_dim),
                         ("lora", "heads", "head_dim")),
        "wo": ParamDef((H, m.v_head_dim, D), ("heads", "head_dim", "embed")),
    }


def _project_latents(p, x, m: MLACfg, cfg: ArchConfig):
    xc = x.astype(COMPUTE_DTYPE)
    q_lat = rmsnorm(p["q_norm"], xc @ p["wq_a"].astype(COMPUTE_DTYPE),
                    cfg.norm_eps)
    q = jnp.einsum("btl,lhd->bthd", q_lat, p["wq_b"].astype(COMPUTE_DTYPE))
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    kv = xc @ p["wkv_a"].astype(COMPUTE_DTYPE)
    c_kv = rmsnorm(p["kv_norm"], kv[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = kv[..., m.kv_lora_rank:][:, :, None, :]   # shared across heads
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(
    p, x: jnp.ndarray, cfg: ArchConfig, *,
    cache: dict | None = None,
    absorbed: bool = True,
) -> tuple[jnp.ndarray, dict | None]:
    """MLA self-attention; cache holds {c_kv (B,S,R), k_rope (B,S,1,dr), len}."""
    m = cfg.mla
    B, T, _ = x.shape
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    q_nope, q_rope, c_kv, k_rope = _project_latents(p, x, m, cfg)

    if cache is None:
        pos = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        q_rope = rope(q_rope, pos, cfg.rope_theta)
        k_rope = rope(k_rope, pos, cfg.rope_theta)
        k_nope = jnp.einsum("bsl,lhd->bshd", c_kv,
                            p["wk_b"].astype(COMPUTE_DTYPE))
        v = jnp.einsum("bsl,lhd->bshd", c_kv, p["wv_b"].astype(COMPUTE_DTYPE))
        lg = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope,
                         preferred_element_type=jnp.float32)
              + jnp.einsum("bthd,bsxd->bhts", q_rope,
                           jnp.broadcast_to(k_rope, (B, T, 1, m.qk_rope_dim)),
                           preferred_element_type=jnp.float32)) * scale
        mask = jnp.tril(jnp.ones((T, T), bool))
        lg = jnp.where(mask[None, None], lg, -1e30)
        pr = jax.nn.softmax(lg, axis=-1).astype(COMPUTE_DTYPE)
        out = jnp.einsum("bhts,bshd->bthd", pr, v)
        new_cache = None
    else:
        cur = cache["len"]
        pos = cur + jnp.arange(T)[None] + jnp.zeros((B, 1), jnp.int32)
        q_rope = rope(q_rope, pos, cfg.rope_theta)
        k_rope = rope(k_rope, pos, cfg.rope_theta)
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), cur, axis=1)
        ckr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), cur, axis=1)
        S = ckv.shape[1]
        if absorbed:
            # fold W_UK into q: q_lat (B,T,H,R); attention in latent space
            q_lat = jnp.einsum("bthd,lhd->bthl", q_nope,
                               p["wk_b"].astype(COMPUTE_DTYPE))
            lg = (jnp.einsum("bthl,bsl->bhts", q_lat, ckv,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bthd,bsxd->bhts", q_rope, ckr,
                               preferred_element_type=jnp.float32)) * scale
        else:
            k_nope = jnp.einsum("bsl,lhd->bshd", ckv,
                                p["wk_b"].astype(COMPUTE_DTYPE))
            lg = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bthd,bsxd->bhts", q_rope, ckr,
                               preferred_element_type=jnp.float32)) * scale
        qpos = cur + jnp.arange(T)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = (kpos <= qpos) & (kpos < cur + T)
        lg = jnp.where(mask[None, None], lg, -1e30)
        pr = jax.nn.softmax(lg, axis=-1).astype(COMPUTE_DTYPE)
        if absorbed:
            o_lat = jnp.einsum("bhts,bsl->bthl", pr, ckv)    # latent output
            out = jnp.einsum("bthl,lhd->bthd", o_lat,
                             p["wv_b"].astype(COMPUTE_DTYPE))
        else:
            v = jnp.einsum("bsl,lhd->bshd", ckv, p["wv_b"].astype(COMPUTE_DTYPE))
            out = jnp.einsum("bhts,bshd->bthd", pr, v)
        new_cache = {"c_kv": ckv, "k_rope": ckr, "len": cur + T}

    out = shard(out, BATCH_AXES, None, "model", None)
    out = jnp.einsum("bthd,hdo->bto", out, p["wo"].astype(COMPUTE_DTYPE))
    return out.astype(x.dtype), new_cache


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=COMPUTE_DTYPE) -> dict:
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, 1, m.qk_rope_dim), dtype),
        "len": jnp.zeros((), jnp.int32),
    }

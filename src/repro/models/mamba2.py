"""Mamba2 / SSD blocks (arXiv:2405.21060) + the Zamba2 hybrid
(arXiv:2411.15242): a Mamba2 backbone with ONE shared transformer block
re-invoked every N layers.

The SSD recurrence runs through ``kernels.ops.chunk_scan`` (GLA form,
scalar-per-head decay broadcast over state channels).  Decode state:
depthwise-conv tail (B, conv_dim-1, C) + matrix state (B*H, N, hd) --
O(1) in context, so ``long_500k`` runs; at 500k the shared attention
block operates in sliding-window mode (cfg.sliding_window).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.pspec import ParamDef, stack_tree
from repro.kernels import ops
from repro.models import layers as L
from repro.models.layers import AttnShape, COMPUTE_DTYPE


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_ch = d_inner + 2 * s.state_dim     # conv over (x, B, C)
    return d_inner, n_heads, conv_ch


def mamba_defs(cfg: ArchConfig) -> dict:
    s = cfg.ssm
    D = cfg.d_model
    d_inner, H, conv_ch = _dims(cfg)
    in_dim = 2 * d_inner + 2 * s.state_dim + H   # z, x, B, C, dt
    return {
        "ln": L.rmsnorm_def(D),
        "w_in": ParamDef((D, in_dim), ("embed", "mlp")),
        "conv_w": ParamDef((s.conv_dim, conv_ch), ("conv", "mlp"), scale=0.5),
        "conv_b": ParamDef((conv_ch,), ("mlp",), init="zeros"),
        "a_log": ParamDef((H,), ("heads",), init="zeros"),
        "dt_bias": ParamDef((H,), ("heads",), init="zeros"),
        "d_skip": ParamDef((H,), ("heads",), init="ones"),
        "out_norm": L.rmsnorm_def(d_inner),
        "w_out": ParamDef((d_inner, D), ("mlp", "embed")),
    }


def _causal_conv(xbc, w, b, tail):
    """Depthwise causal conv; ``tail``: (B, conv_dim-1, C) carry or None."""
    K = w.shape[0]
    if tail is None:
        pad = jnp.zeros_like(xbc[:, :K - 1])
    else:
        pad = tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)        # (B, T+K-1, C)
    out = sum(xp[:, i:i + xbc.shape[1]] * w[i][None, None] for i in range(K))
    new_tail = xp[:, -(K - 1):] if tail is not None else None
    return jax.nn.silu(out + b[None, None]), new_tail


def mamba_mixer(cfg: ArchConfig, p, x, state, impl):
    """One Mamba2 mixer.  state: None or {conv (B,K-1,C), S (B*H, N, hd)}."""
    s = cfg.ssm
    B, T, D = x.shape
    d_inner, H, conv_ch = _dims(cfg)
    N, hd = s.state_dim, s.head_dim
    xc = L.rmsnorm(p["ln"], x, cfg.norm_eps).astype(COMPUTE_DTYPE)
    proj = xc @ p["w_in"].astype(COMPUTE_DTYPE)
    z, xbc, dt = jnp.split(proj, [d_inner, d_inner + conv_ch], axis=-1)
    conv_tail = None if state is None else state["conv"]
    xbc, new_tail = _causal_conv(xbc, p["conv_w"].astype(COMPUTE_DTYPE),
                                 p["conv_b"].astype(COMPUTE_DTYPE), conv_tail)
    xs, Bs, Cs = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)[None, None])
    a = jnp.exp(-dt * jnp.exp(p["a_log"].astype(jnp.float32))[None, None])

    # map to the chunk-scan form: per-head q=C, k=B (shared), v = x * dt
    v = (xs.reshape(B, T, H, hd).astype(jnp.float32)
         * dt[..., None]).transpose(0, 2, 1, 3).reshape(B * H, T, hd)
    q = jnp.broadcast_to(Cs.astype(jnp.float32)[:, None], (B, H, T, N)
                         ).reshape(B * H, T, N)
    k = jnp.broadcast_to(Bs.astype(jnp.float32)[:, None], (B, H, T, N)
                         ).reshape(B * H, T, N)
    decay = jnp.broadcast_to(
        a.transpose(0, 2, 1)[..., None], (B, H, T, N)).reshape(B * H, T, N)
    s0 = None if state is None else state["S"]
    o, s_new = ops.chunk_scan(q, k, v, decay, bonus=None, state=s0,
                              chunk=s.chunk, impl=impl)
    o = o.reshape(B, H, T, hd).transpose(0, 2, 1, 3)
    o = o + p["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs.reshape(B, T, H, hd).astype(jnp.float32)
    o = o.reshape(B, T, d_inner).astype(COMPUTE_DTYPE)
    o = L.rmsnorm(p["out_norm"], o * jax.nn.silu(z), cfg.norm_eps)
    out = (o @ p["w_out"].astype(COMPUTE_DTYPE)).astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {"conv": new_tail.astype(state["conv"].dtype), "S": s_new}
    return x + out, new_state


# ---------------------------------------------------------------------------
# Zamba2 hybrid: Mamba2 backbone + ONE shared attention block
# ---------------------------------------------------------------------------
def _attn_shape(cfg: ArchConfig) -> AttnShape:
    return AttnShape(cfg.n_heads, cfg.n_kv_heads, cfg.head_dim)


def shared_block_defs(cfg: ArchConfig) -> dict:
    return {
        "ln1": L.rmsnorm_def(cfg.d_model),
        "attn": L.attention_defs(cfg.d_model, _attn_shape(cfg)),
        "ln2": L.rmsnorm_def(cfg.d_model),
        "mlp": L.mlp_defs(cfg.d_model, cfg.d_ff, cfg.act),
    }


def param_defs(cfg: ArchConfig) -> dict:
    defs: dict[str, Any] = {
        "embed": L.embed_defs(cfg.vocab, cfg.d_model),
        "mamba_layers": stack_tree(mamba_defs(cfg), cfg.n_layers),
        "ln_f": L.rmsnorm_def(cfg.d_model),
        "head": ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab")),
    }
    if cfg.shared_attn_every:
        defs["shared"] = shared_block_defs(cfg)
    return defs


def _shared_block(cfg, p, x, cache):
    h = L.rmsnorm(p["ln1"], x, cfg.norm_eps)
    a, new_cache = L.attention_block(
        p["attn"], h, shape=_attn_shape(cfg), rope_theta=cfg.rope_theta,
        window=cfg.sliding_window, cache=cache)
    x = x + a
    h = L.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + L.mlp(p["mlp"], h, cfg.act), new_cache


def forward(cfg: ArchConfig, params, batch: dict, *, mode: str = "train",
            cache=None, impl: str = "auto"):
    tokens = batch["tokens"]
    x = L.embed(params["embed"], tokens)
    x = L.shard(x, L.BATCH_AXES, None, None)
    remat = mode == "train"
    every = cfg.shared_attn_every or cfg.n_layers
    n_groups = cfg.n_layers // every
    assert cfg.n_layers % every == 0

    # reshape stacked mamba params (L, ...) -> (G, every, ...)
    def regroup(t):
        return t.reshape((n_groups, every) + t.shape[1:])

    grouped = jax.tree.map(regroup, params["mamba_layers"])
    m_state = None if cache is None else jax.tree.map(regroup, cache["mamba"])
    a_cache = None if cache is None else cache["attn"]

    def inner(carry, xs):
        h = carry
        p, st = xs
        h, new_st = mamba_mixer(cfg, p, h, st, impl)
        return h, new_st

    def group(carry, xs):
        h = carry
        gp, gst, shared_cache = xs
        h, new_st = L.scan_layers(inner, h, (gp, gst), length=every)
        if cfg.shared_attn_every:
            h, new_sc = _shared_block(cfg, params["shared"], h, shared_cache)
        else:
            new_sc = shared_cache
        return h, (new_st, new_sc)

    if remat:
        group = jax.checkpoint(group)
    x, (new_m, new_a) = L.scan_layers(group, x, (grouped, m_state, a_cache),
                                      length=n_groups)
    x = L.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    lg = L.logits(params["head"], x, transpose=False)
    new_cache = None
    if cache is not None:
        def ungroup(t):
            return t.reshape((cfg.n_layers,) + t.shape[2:])
        new_cache = {"mamba": jax.tree.map(ungroup, new_m), "attn": new_a}
    return lg, new_cache, jnp.float32(0.0)


def init_cache(cfg: ArchConfig, batch: int, max_len: int) -> dict:
    s = cfg.ssm
    d_inner, H, conv_ch = _dims(cfg)
    m_one = {
        "conv": jnp.zeros((batch, s.conv_dim - 1, conv_ch), COMPUTE_DTYPE),
        "S": jnp.zeros((batch * H, s.state_dim, s.head_dim), jnp.float32),
    }
    out = {"mamba": jax.tree.map(
        lambda x: jnp.stack([x] * cfg.n_layers), m_one)}
    if cfg.shared_attn_every:
        n_groups = cfg.n_layers // cfg.shared_attn_every
        a_one = L.init_kv_cache(batch, max_len, _attn_shape(cfg))
        out["attn"] = jax.tree.map(lambda x: jnp.stack([x] * n_groups), a_one)
    else:
        out["attn"] = None
    return out


def loss_fn(cfg: ArchConfig, params, batch: dict):
    lg, _, _ = forward(cfg, params, batch, mode="train")
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    return L.cross_entropy(lg[:, :-1], jnp.maximum(labels[:, 1:], 0),
                           mask[:, 1:])

"""Forward-compat aliases for newer JAX APIs on pinned 0.4.x wheels.

The codebase targets the current JAX mesh/pallas surface
(``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``jax.set_mesh``, ``jax.sharding.get_abstract_mesh``,
``pallas.tpu.CompilerParams``).  The hermetic toolchain pins
jax 0.4.37, where those spell differently or don't exist yet.  This
module adds ONLY missing attributes — on a current jax every branch is
a no-op — so the same source runs on both.  It is imported for its
side effects from ``repro/__init__.py``.
"""
from __future__ import annotations

import enum
import inspect

import jax
import jax.sharding


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    orig = getattr(jax, "make_mesh", None)
    if orig is None:
        def orig(axis_shapes, axis_names, *, devices=None):
            import numpy as _np
            devs = devices if devices is not None else jax.devices()
            n = int(_np.prod(axis_shapes))
            return jax.sharding.Mesh(
                _np.asarray(devs[:n]).reshape(axis_shapes), axis_names)
    elif "axis_types" in inspect.signature(orig).parameters:
        return

    def make_mesh(axis_shapes, axis_names, *args, axis_types=None, **kw):
        # 0.4.x meshes have no axis types; Auto is the only behaviour
        return orig(axis_shapes, axis_names, *args, **kw)

    jax.make_mesh = make_mesh


def _install_set_mesh() -> None:
    if hasattr(jax, "set_mesh"):
        return
    # Mesh is itself a context manager that installs the legacy global
    # mesh, which is exactly what 0.4.x sharding constraints consume.
    jax.set_mesh = lambda mesh: mesh


def _install_get_abstract_mesh() -> None:
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return
    from jax._src import mesh as _mesh_lib

    def get_abstract_mesh():
        # the legacy ambient mesh: .empty/.shape match what callers use
        return _mesh_lib.thread_resources.env.physical_mesh

    jax.sharding.get_abstract_mesh = get_abstract_mesh


def _install_pallas_params() -> None:
    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:  # pallas not available at all: nothing to alias
        return
    if not hasattr(pltpu, "CompilerParams") and hasattr(
            pltpu, "TPUCompilerParams"):
        pltpu.CompilerParams = pltpu.TPUCompilerParams


def _install_cost_analysis() -> None:
    # 0.4.x returns a list of per-computation dicts; current jax returns
    # one flat dict.  Normalise to the flat-dict contract callers use.
    Compiled = jax.stages.Compiled
    orig = Compiled.cost_analysis
    if getattr(orig, "_repro_normalised", False):
        return

    def cost_analysis(self):
        out = orig(self)
        if isinstance(out, list):
            return out[0] if out else {}
        return out

    cost_analysis._repro_normalised = True
    Compiled.cost_analysis = cost_analysis


def install() -> None:
    _install_axis_type()
    _install_make_mesh()
    _install_set_mesh()
    _install_get_abstract_mesh()
    _install_pallas_params()
    _install_cost_analysis()


install()

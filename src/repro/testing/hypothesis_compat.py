"""Property-testing front-end: real ``hypothesis`` when available,
otherwise a deterministic random-sampling fallback.

The suite's property tests import ``given``/``settings``/``strategies``
from here instead of from ``hypothesis`` directly.  With the dev extras
installed (``pip install -e .[dev]``, as CI does) this module is a pure
re-export and tests get full shrinking/replay behaviour.  In hermetic
environments without hypothesis the fallback below keeps the suite
collectable and still exercises each property on a seeded sample of the
input space — strictly better than an ImportError at collection time.

The fallback implements only the subset this repo uses: ``@given`` over
positional strategies, ``@settings(max_examples=..., deadline=...)``,
``assume``, and the ``integers`` / ``floats`` / ``booleans`` /
``sampled_from`` / ``lists`` / ``just`` strategies.  Draws are seeded
per-test (stable across runs) and a falsifying example is reported in
the failure message.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import HealthCheck, assume, given, settings
    from hypothesis import strategies

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import functools
    import random
    import types
    import zlib

    HAVE_HYPOTHESIS = False

    class _Unsatisfied(Exception):
        """Raised by :func:`assume` to discard the current example."""

    def assume(condition) -> bool:
        if not condition:
            raise _Unsatisfied()
        return True

    class HealthCheck:  # minimal placeholder for settings(...) kwargs
        all = staticmethod(lambda: [])

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_from(self, rng: random.Random):
            return self._draw(rng)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(100):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise _Unsatisfied()
            return _Strategy(draw)

    def _integers(min_value=None, max_value=None):
        lo = -(2 ** 31) if min_value is None else int(min_value)
        hi = 2 ** 31 - 1 if max_value is None else int(max_value)

        def draw(rng):
            # bias toward the boundaries, where tree/range bugs live
            r = rng.random()
            if r < 0.05:
                return lo
            if r < 0.1:
                return hi
            return rng.randint(lo, hi)
        return _Strategy(draw)

    def _floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    def _booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[rng.randrange(len(seq))])

    def _just(value):
        return _Strategy(lambda rng: value)

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example_from(rng) for _ in range(n)]
        return _Strategy(draw)

    strategies = types.SimpleNamespace(
        integers=_integers, floats=_floats, booleans=_booleans,
        sampled_from=_sampled_from, just=_just, lists=_lists,
    )

    _DEFAULT_MAX_EXAMPLES = 50

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_compat_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                ran = 0
                for _ in range(n * 5):
                    if ran >= n:
                        break
                    vals = ()
                    try:
                        vals = tuple(s.example_from(rng) for s in strats)
                        fn(*args, *vals, **kwargs)
                    except _Unsatisfied:
                        continue
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example (compat shim): "
                            f"{fn.__name__}{vals!r}") from e
                    ran += 1
                if n > 0 and ran == 0:
                    raise AssertionError(
                        f"{fn.__name__}: no examples satisfied assume()/"
                        f"filter() — the property was never checked")
            # pytest must not mistake the drawn parameters for fixtures:
            # drop the __wrapped__ link so inspect.signature sees
            # (*args, **kwargs) instead of the inner test's params
            del wrapper.__wrapped__
            return wrapper
        return deco

__all__ = ["HAVE_HYPOTHESIS", "HealthCheck", "assume", "given",
           "settings", "strategies"]

# Test-support helpers importable from the installed package.

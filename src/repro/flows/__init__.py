from repro.flows.synthetic import FlowDataset, make_dataset  # noqa: F401
from repro.flows.windows import window_features, full_flow_features  # noqa: F401

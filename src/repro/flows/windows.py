"""CICFlowMeter-style windowed feature extraction.

The paper modifies CICFlowMeter to emit feature statistics at every
window boundary and reset flow state afterwards (§5 Dataset Generation).
This module is the offline analogue: it slices each flow into ``p``
uniform windows (the data plane parses the flow size from the transport
header -- Homa/NDP style -- to know the boundaries) and computes the full
N-feature vector per window.

Window semantics mirror the data plane exactly:
  * windows are uniform: ``len // p`` packets, remainder to the LAST
    window (so every window is non-empty for flows with len >= p);
  * the dependency chain is cleared at each window boundary, so the
    first packet of every window has IAT = 0;
  * padding packets have valid = 0 and contribute to nothing;
  * features are computed with the SAME f32 kernel math as the runtime
    engine (``kernels.ref.feature_window_ref``), so training-time
    thresholds and inference-time register values agree bit-exactly --
    the switch analogue is that CICFlowMeter and the pipeline both see
    integer registers.  ``core.features.compute_feature`` remains the
    independent (f64 numpy) semantic oracle for unit tests.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.features import (
    FEATURE_TABLE, N_FEATURES, PKT_IAT, PKT_NFIELDS, REGISTRY,
)
from repro.flows.synthetic import FlowDataset
from repro.kernels.ref import feature_window_ref

_FLOW_BATCH = 2048


def window_bounds(length: int, p: int) -> list[tuple[int, int]]:
    """Uniform window [start, end) bounds; remainder goes to last window."""
    base = max(length // p, 1)
    bounds = []
    for w in range(p):
        lo = min(w * base, length)
        hi = length if w == p - 1 else min((w + 1) * base, length)
        bounds.append((lo, hi))
    return bounds


def _all_feature_rows(n: int) -> tuple[jnp.ndarray, ...]:
    """Slot tables covering ALL registry features (k = N_FEATURES)."""
    op = np.tile(FEATURE_TABLE[:, 0], (n, 1))
    field = np.tile(FEATURE_TABLE[:, 1], (n, 1))
    pred = np.tile(FEATURE_TABLE[:, 2], (n, 1))
    init = np.tile(np.asarray([s.init_value for s in REGISTRY], np.float32),
                   (n, 1))
    return (jnp.asarray(op), jnp.asarray(field), jnp.asarray(pred),
            jnp.asarray(init))


def _features_jnp(win: np.ndarray) -> np.ndarray:
    """(m, W, F) window packets -> (m, N_FEATURES) via the engine's math."""
    m = win.shape[0]
    out = np.empty((m, N_FEATURES), dtype=np.float32)
    for lo in range(0, m, _FLOW_BATCH):
        hi = min(lo + _FLOW_BATCH, m)
        rows = _all_feature_rows(hi - lo)
        out[lo:hi] = np.asarray(
            feature_window_ref(jnp.asarray(win[lo:hi]), *rows))
    return out


def window_features(ds: FlowDataset, p: int) -> np.ndarray:
    """Per-window features: returns ``(n_flows, p, N_FEATURES)``.

    Computed from the exact same padded window tensor the runtime engine
    consumes, so offline (training) features and runtime registers are
    bit-identical.
    """
    wp = window_packets(ds, p)                   # (n, p, W, F)
    n = ds.n_flows
    out = np.zeros((n, p, N_FEATURES), dtype=np.float32)
    for w in range(p):
        out[:, w] = _features_jnp(wp[:, w])
    return out


def window_packets(ds: FlowDataset, p: int) -> np.ndarray:
    """Window-major packet tensor for the data-plane engine.

    Returns ``(n_flows, p, W_max, PKT_NFIELDS)`` with per-window padding
    (valid=0) and the dependency chain cleared at window starts
    (first-packet IAT = 0), matching :func:`window_features` semantics.
    """
    n = ds.n_flows
    w_max = 1
    for L in np.unique(ds.lengths):
        for lo, hi in window_bounds(int(L), p):
            w_max = max(w_max, hi - lo)
    out = np.zeros((n, p, w_max, PKT_NFIELDS), dtype=np.float32)
    for L in np.unique(ds.lengths):
        rows = np.nonzero(ds.lengths == L)[0]
        pk = ds.packets[rows]
        for w, (lo, hi) in enumerate(window_bounds(int(L), p)):
            if hi <= lo:
                continue
            win = pk[:, lo:hi].copy()
            win[:, 0, PKT_IAT] = 0.0
            out[rows, w, :hi - lo] = win
    return out


def full_flow_features(ds: FlowDataset) -> np.ndarray:
    """Whole-flow features (the one-shot baselines' best case)."""
    return window_features(ds, 1)[:, 0, :]


def quantize_features(X: np.ndarray, bits: int) -> np.ndarray:
    """Reduce feature bit precision (paper Fig. 12).

    Features are stored in ``bits``-wide registers.  Counters and sums
    are heavy-tailed, so narrow registers hold them LOG-encoded (switch
    ASICs implement this with a leading-zero/priority encoder, the same
    primitive range marking uses): q = round(log1p(x - min) * scale).
    Linear 8-bit quantisation would collapse the low-magnitude range
    where most of the discrimination lives.
    """
    if bits >= 32:
        return X
    lo = X.min(axis=tuple(range(X.ndim - 1)), keepdims=True)
    y = np.log1p(np.maximum(X - lo, 0.0))
    hi = y.max(axis=tuple(range(X.ndim - 1)), keepdims=True)
    span = np.maximum(hi, 1e-9)
    levels = float(2 ** bits - 1)
    q = np.round(y / span * levels)
    return (np.expm1(q / levels * span) + lo).astype(np.float32)

"""Synthetic labelled flow generators.

The paper evaluates on CIC-* security datasets (D1-D7) which are not
redistributable/offline.  We generate synthetic flow datasets with the
same *structure*: multi-class, ~41 windowed stateful features, and --
crucially for SpliDT -- **temporal signatures**: classes behave
differently in different phases of the flow, so features computed on
later windows carry information that whole-flow or first-window top-k
features miss.  Class profiles are built as a shared base + sparse
per-class, per-phase deltas, which also reproduces the paper's observed
*feature sparsity per subtree* (Table 1: ~6-7% of features per subtree).

Datasets (analogues of the paper's D1-D3):
    d1: 19 classes (CIC-IoMT-like),  d2: 4 classes,  d3: 13 classes.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, NamedTuple

import numpy as np

from repro.core.features import (
    FLAG_ACK, FLAG_FIN, FLAG_PSH, FLAG_RST, FLAG_SYN, FLAG_URG,
    PKT_DIR, PKT_FLAGS, PKT_IAT, PKT_NFIELDS, PKT_SIZE, PKT_TS, PKT_VALID,
)

N_PHASES = 3  # early / middle / late flow behaviour


@dataclasses.dataclass
class FlowDataset:
    packets: np.ndarray     # (n_flows, max_len, PKT_NFIELDS) float32, padded
    lengths: np.ndarray     # (n_flows,) int32
    labels: np.ndarray      # (n_flows,) int64
    n_classes: int
    name: str

    @property
    def n_flows(self) -> int:
        return int(self.labels.shape[0])

    def split(self, frac: float = 0.7, seed: int = 0):
        rng = np.random.default_rng(seed)
        idx = rng.permutation(self.n_flows)
        cut = int(self.n_flows * frac)
        tr, te = idx[:cut], idx[cut:]
        mk = lambda i: FlowDataset(self.packets[i], self.lengths[i],
                                   self.labels[i], self.n_classes, self.name)
        return mk(tr), mk(te)


@dataclasses.dataclass
class _Phase:
    size_mu: float        # lognormal ln-mean of packet size
    size_sigma: float
    iat_scale: float      # exponential IAT scale (seconds)
    p_bwd: float          # probability a packet is backward
    p_syn: float
    p_ack: float
    p_fin: float
    p_rst: float
    p_psh: float
    p_urg: float


def _base_phase(rng: np.random.Generator) -> _Phase:
    return _Phase(
        size_mu=rng.uniform(5.0, 6.5),
        size_sigma=rng.uniform(0.3, 0.8),
        iat_scale=10 ** rng.uniform(-4.0, -1.5),
        p_bwd=rng.uniform(0.2, 0.6),
        p_syn=0.02, p_ack=0.7, p_fin=0.02, p_rst=0.01, p_psh=0.3, p_urg=0.005,
    )


_DELTA_KEYS = ["size_mu", "size_sigma", "iat_scale", "p_bwd",
               "p_syn", "p_ack", "p_fin", "p_rst", "p_psh", "p_urg"]


def _perturb(ph: _Phase, rng: np.random.Generator, n_deltas: int) -> _Phase:
    """Sparse perturbation: change only a few behaviour parameters."""
    d = dataclasses.asdict(ph)
    keys = rng.choice(_DELTA_KEYS, size=n_deltas, replace=False)
    for key in keys:
        v = d[key]
        if key == "size_mu":
            d[key] = float(np.clip(v + rng.normal(0, 0.9), 4.0, 7.3))
        elif key == "size_sigma":
            d[key] = float(np.clip(v * rng.uniform(0.4, 2.5), 0.1, 1.5))
        elif key == "iat_scale":
            d[key] = float(np.clip(v * 10 ** rng.normal(0, 0.8), 1e-5, 1.0))
        else:
            d[key] = float(np.clip(v * rng.uniform(0.2, 4.0) + rng.uniform(0, 0.1), 0.0, 0.95))
    return _Phase(**d)


_DATASETS = {"d1": (19, 0xD1), "d2": (4, 0xD2), "d3": (13, 0xD3)}


def _synth_packets(
    profiles: list[list[_Phase]],
    labels: np.ndarray,
    lengths: np.ndarray,
    rng: np.random.Generator,
) -> np.ndarray:
    """Render per-class phase profiles into a padded packet tensor.

    Consumes ``rng`` in flow-major, phase-minor order — the exact call
    sequence :func:`make_dataset` always used, so extracting this helper
    changes no existing dataset bit.
    """
    n_flows = int(labels.shape[0])
    max_l = int(lengths.max())
    pkts = np.zeros((n_flows, max_l, PKT_NFIELDS), dtype=np.float32)

    for i in range(n_flows):
        L = int(lengths[i])
        prof = profiles[int(labels[i])]
        bounds = [0, L // 3, 2 * L // 3, L]
        ts = 0.0
        row = pkts[i]
        for ph in range(N_PHASES):
            lo, hi = bounds[ph], bounds[ph + 1]
            w = hi - lo
            if w <= 0:
                continue
            p = prof[ph]
            sizes = np.clip(rng.lognormal(p.size_mu, p.size_sigma, w), 40, 1500)
            iats = rng.exponential(p.iat_scale, w)
            if lo == 0:
                iats[0] = 0.0   # first packet of the flow has no IAT
            dirs = (rng.random(w) < p.p_bwd).astype(np.float32)
            flags = (
                (rng.random(w) < p.p_syn) * FLAG_SYN
                + (rng.random(w) < p.p_ack) * FLAG_ACK
                + (rng.random(w) < p.p_fin) * FLAG_FIN
                + (rng.random(w) < p.p_rst) * FLAG_RST
                + (rng.random(w) < p.p_psh) * FLAG_PSH
                + (rng.random(w) < p.p_urg) * FLAG_URG
            ).astype(np.float32)
            tss = ts + np.cumsum(iats)
            ts = float(tss[-1])
            row[lo:hi, PKT_TS] = tss
            row[lo:hi, PKT_SIZE] = sizes
            row[lo:hi, PKT_DIR] = dirs
            row[lo:hi, PKT_FLAGS] = flags
            row[lo:hi, PKT_IAT] = iats
            row[lo:hi, PKT_VALID] = 1.0
        # first packet of a flow always SYN-ish (handshake realism)
        row[0, PKT_FLAGS] = float(int(row[0, PKT_FLAGS]) | FLAG_SYN)
    return pkts


def make_dataset(
    name: str,
    n_flows: int = 6000,
    *,
    seed: int | None = None,
    min_len: int = 12,
    max_len: int = 192,
) -> FlowDataset:
    """Generate a labelled synthetic flow dataset.

    Half of each class's identity lives in later phases: classes are
    grouped into "families" that share the early-phase profile and only
    diverge mid/late flow, which is exactly the regime where windowed
    partitioned inference has an edge over first-k-packets top-k models.
    """
    if name not in _DATASETS:
        raise ValueError(f"unknown dataset {name!r}; options {sorted(_DATASETS)}")
    n_classes, ds_seed = _DATASETS[name]
    rng = np.random.default_rng(ds_seed if seed is None else seed)

    # class profiles: families share phase-0; members diverge in phases 1-2
    n_families = max(2, n_classes // 3)
    family_phase0 = [_base_phase(rng) for _ in range(n_families)]
    profiles: list[list[_Phase]] = []
    for c in range(n_classes):
        fam = c % n_families
        p0 = _perturb(family_phase0[fam], rng, n_deltas=1)   # nearly shared
        p1 = _perturb(p0, rng, n_deltas=3)
        p2 = _perturb(p1, rng, n_deltas=3)
        profiles.append([p0, p1, p2])

    labels = rng.integers(0, n_classes, size=n_flows)
    lengths = np.clip(
        np.exp(rng.normal(np.log(40.0), 0.7, size=n_flows)).astype(np.int64),
        min_len, max_len,
    ).astype(np.int32)
    pkts = _synth_packets(profiles, labels, lengths, rng)
    return FlowDataset(pkts, lengths, labels.astype(np.int64), n_classes, name)


# ---------------------------------------------------------------------------
# exit-rate profile workloads (early-exit compaction's scenario axis)
# ---------------------------------------------------------------------------
EXIT_PROFILES = ("front", "uniform", "back")


def _separated_phase(c: int, n_classes: int) -> _Phase:
    """A strongly class-separated phase: disjoint behaviour parameters,
    so a depth-few subtree isolates the class the first time it sees
    this phase (pure leaves -> exit)."""
    t = c / max(n_classes - 1, 1)
    # separation lives ONLY in low-noise features — tightly clustered
    # sizes (µ-gap/σ > 10) and all-forward vs all-backward direction —
    # so the trained subtree's leaves come out PURE (=> exit) instead of
    # keeping stragglers that force recirculation; flag probabilities
    # stay at base-like constants to deny the tree noisy split features
    return _Phase(
        size_mu=4.3 + 2.8 * t,              # disjoint lognormal size means
        size_sigma=0.05,
        iat_scale=10 ** (-4.0 + 2.2 * t),
        p_bwd=0.0 if t < 0.5 else 1.0,
        p_syn=0.02, p_ack=0.7, p_fin=0.02, p_rst=0.01, p_psh=0.3,
        p_urg=0.005,
    )


def make_profile_dataset(
    profile: str,
    n_flows: int = 3000,
    *,
    n_classes: int = 4,
    seed: int = 0,
    min_len: int = 24,
    max_len: int = 96,
) -> FlowDataset:
    """Synthetic workload with a controlled per-partition exit-rate shape.

    The compaction speedup of the recirculation walk depends entirely on
    WHEN flows exit, so benchmarks/tests need workloads that pin that
    axis.  Each class diverges from a shared no-signal base at a chosen
    phase; a trained :class:`PartitionedDT` can only exit a flow once
    its class has diverged, so the divergence phase dictates the exit
    partition:

    ``front``    every class diverges in phase 0 -> exits front-loaded
                 at partition 0 (the paper's common case — compaction's
                 best case);
    ``uniform``  classes spread evenly over divergence phases -> exits
                 spread across partitions (the last phase always gets
                 >= 2 classes, otherwise the lone remaining class goes
                 pure-by-elimination and exits a partition early);
    ``back``     classes are indistinguishable until the final phase ->
                 nearly every flow recirculates to the last partition
                 (compaction's adversarial worst case: nothing to skip).

    Keep ``n_classes`` modest relative to the subtree depth used for
    training: a greedy depth-d subtree must isolate every diverged class
    on one branch to exit it, so too many classes per phase push exits a
    partition later than the profile intends.
    """
    if profile not in EXIT_PROFILES:
        raise ValueError(
            f"unknown profile {profile!r}; options {EXIT_PROFILES}")
    rng = np.random.default_rng(np.random.SeedSequence([0xE817, seed]))
    base = [_base_phase(rng) for _ in range(N_PHASES)]   # shared: no signal
    diverge = {
        "front": lambda c: 0,
        # even spread, extras to the LAST phase (see docstring)
        "uniform": lambda c: (N_PHASES - 1
                              - ((n_classes - 1 - c) * N_PHASES) // n_classes),
        "back": lambda c: N_PHASES - 1,
    }[profile]
    profiles = [
        [base[ph] if ph < diverge(c) else _separated_phase(c, n_classes)
         for ph in range(N_PHASES)]
        for c in range(n_classes)
    ]
    labels = rng.integers(0, n_classes, size=n_flows)
    lengths = np.clip(
        np.exp(rng.normal(np.log(48.0), 0.5, size=n_flows)).astype(np.int64),
        min_len, max_len,
    ).astype(np.int32)
    pkts = _synth_packets(profiles, labels, lengths, rng)
    return FlowDataset(pkts, lengths, labels.astype(np.int64), n_classes,
                       f"profile_{profile}")


# ---------------------------------------------------------------------------
# replayable packet-arrival streams (flow-table serving workloads)
# ---------------------------------------------------------------------------
ARRIVAL_PROFILES = ("steady", "bursty")


class PacketBatch(NamedTuple):
    """One tick's worth of interleaved packet arrivals.

    The wire-level unit the flow-table server ingests: packets from
    many flows, in global arrival order.  ``flow_len`` is the in-band
    flow length (Homa/NDP-style — the data plane parses it from the
    transport header to know the window boundaries, exactly as
    ``window_bounds`` assumes).  ``pkts`` rows keep the FLOW-RELATIVE
    fields (timestamps, IATs) the training pipeline saw; ``arrival`` is
    the global wall-clock time used only for interleaving and
    timeout/eviction.
    """
    flow_id: np.ndarray    # (n,) int64 dataset row of each packet's flow
    flow_len: np.ndarray   # (n,) int32 total packets of that flow
    pkts: np.ndarray       # (n, PKT_NFIELDS) f32 flow-relative packet rows
    arrival: np.ndarray    # (n,) f64 global arrival time, non-decreasing

    @property
    def n_packets(self) -> int:
        return int(self.flow_id.shape[0])


@dataclasses.dataclass
class PacketStream:
    """A seeded, replayable arrival-ordered packet stream over a dataset.

    Produced by :func:`make_packet_stream`; a pure function of
    ``(dataset, seed, profile)``, so any consumer (tests, benchmarks,
    the serving layer) can replay the identical interleaving.
    """
    flow_id: np.ndarray    # (n_pkts,) int64
    flow_len: np.ndarray   # (n_pkts,) int32
    pkts: np.ndarray       # (n_pkts, PKT_NFIELDS) f32
    arrival: np.ndarray    # (n_pkts,) f64 sorted ascending
    labels: np.ndarray     # (n_flows,) ground truth, indexed by flow_id
    profile: str

    @property
    def n_packets(self) -> int:
        return int(self.flow_id.shape[0])

    @property
    def n_flows(self) -> int:
        return int(self.labels.shape[0])

    def slice(self, lo: int, hi: int) -> PacketBatch:
        return PacketBatch(self.flow_id[lo:hi], self.flow_len[lo:hi],
                           self.pkts[lo:hi], self.arrival[lo:hi])

    def ticks(self, pkts_per_tick: int) -> Iterator[PacketBatch]:
        """Replay the stream in fixed-size arrival-order ticks."""
        if pkts_per_tick <= 0:
            raise ValueError("pkts_per_tick must be positive")
        for lo in range(0, self.n_packets, pkts_per_tick):
            yield self.slice(lo, min(lo + pkts_per_tick, self.n_packets))


def make_packet_stream(
    ds: FlowDataset,
    *,
    seed: int = 0,
    profile: str = "steady",
    concurrency: float = 32.0,
    burst_size: int = 16,
) -> PacketStream:
    """Interleave a dataset's flows into one arrival-ordered stream.

    Each flow keeps its internal packet timing (the flow-relative
    ``PKT_TS`` cumsum the generator produced) and is given a global
    start offset; packets are then merged by global arrival time.
    ``concurrency`` scales how many flows overlap on average (total
    flow airtime divided by the stream's span).  Profiles:

    ``steady``  flow starts are uniform over the span — resident-flow
                count hovers around ``concurrency``;
    ``bursty``  flows arrive in clusters of ~``burst_size`` (burst
                centres uniform over the span, small in-burst jitter) —
                the flow table sees spiky occupancy and the eviction
                path actually fires.

    Per-flow packet order in the stream always matches packet order in
    the dataset (ties broken flow-major), so folding the stream through
    the flow table reproduces the offline windows bit-for-bit.
    """
    if profile not in ARRIVAL_PROFILES:
        raise ValueError(
            f"unknown profile {profile!r}; options {ARRIVAL_PROFILES}")
    rng = np.random.default_rng(np.random.SeedSequence([0x57EA, seed]))
    n = ds.n_flows
    lengths = ds.lengths.astype(np.int64)
    durations = ds.packets[np.arange(n), lengths - 1, PKT_TS].astype(np.float64)
    span = max(float(durations.sum()) / max(concurrency, 1e-9), 1e-9)
    if profile == "steady":
        starts = rng.uniform(0.0, span, size=n)
    else:
        n_bursts = max(1, n // max(burst_size, 1))
        centres = rng.uniform(0.0, span, size=n_bursts)
        starts = (centres[rng.integers(0, n_bursts, size=n)]
                  + rng.exponential(span / (8.0 * n_bursts), size=n))

    total = int(lengths.sum())
    flow_id = np.repeat(np.arange(n, dtype=np.int64), lengths)
    flow_len = np.repeat(lengths.astype(np.int32), lengths)
    pkts = np.concatenate(
        [ds.packets[i, :lengths[i]] for i in range(n)], axis=0)
    local_ts = pkts[:, PKT_TS].astype(np.float64)
    arrival = np.repeat(starts, lengths) + local_ts
    # stable sort: equal arrivals keep flow-major order, so a flow's
    # packets never reorder
    order = np.argsort(arrival, kind="stable")
    assert order.shape[0] == total
    return PacketStream(flow_id=flow_id[order], flow_len=flow_len[order],
                        pkts=pkts[order], arrival=arrival[order],
                        labels=ds.labels.copy(), profile=profile)

"""Analytical cost model for the engine's execution backends.

The paper's DSE framework picks *model* shapes per hardware target; this
module does the same for the *execution* path.  PR 2/3 showed the
fastest backend flips with batch size B, subtree count S, compaction
profile, and device count — a one-line platform check (``pallas`` on
TPU, ``fused`` elsewhere) leaves that regime-dependence on the table,
exactly the way one-shot Leo/NetBeacon deployments cannot exploit
pForest-style per-phase switching.

The model is a per-hop work estimate in microseconds::

    cost(plan, shape) = fixed dispatch overhead
                      + sum over hops p of
                          feature-window rebuild (B_p * W * k)
                        + traversal               (backend-specific)
                        + routing overhead        (sort / sync / grid)

where ``B_p`` is the number of flow slots the hop actually processes:
the full batch for a dense walk, the compaction bucket capacity for a
compacted walk (driven by the shape's per-hop survivor profile).  The
backend-specific terms:

* **fused**  — dense per-flow gathers of the SID-keyed tables plus a
  dense range match: ``B_p * (k*T + 2*L*k + 2*L)`` gather traffic and
  ``B_p * (k*T + L*k)`` compare work, one jitted call per batch.
* **pallas** — the in-jit SID dispatch (argsort + scatter: ``B_p *
  log2(B_p)``) plus block-dense kernel work over the capacity bound
  ``ceil(B_p/block_b) + S`` blocks (``kernels.dispatch``), plus a
  per-grid-step launch cost that dominates in interpret mode (the
  grid is executed sequentially off-TPU).
* **looped** — the fused math plus a host sync and two dispatches per
  hop (the per-partition ``device_get``).

Coefficients are *fitted*, not guessed: :func:`fit_coefficients` solves
a non-negative least-squares over (work-term, measured-μs) samples, and
:func:`calibrate` collects those samples from micro-benchmarks of the
actual engine on the actual host.  The defaults baked into
:data:`DEFAULT_COEFFS` were fitted that way on the 2-core CPU dev
container (see ``benchmarks/bench_engine.py``); on a real TPU, run
:func:`calibrate` (or the autotuner, which measures end-to-end) rather
than trusting CPU-fitted constants.

The model is intentionally coarse — its job is *routing* (pick the
argmin backend, decide whether compaction pays), not prediction.  The
empirical autotuner (``repro.tuning.autotune``) uses it to shortlist
candidates before timing them, and replaces it entirely once a timed
winner is cached.

Doctest (shape-only, no timing — safe anywhere)::

    >>> from repro.tuning.costmodel import ShapeInfo, choose_plan
    >>> shape = ShapeInfo(B=4096, S=9, k=4, P=3, W=24, T=16, L=16)
    >>> plan = choose_plan(shape)
    >>> plan.backend in ("looped", "fused", "pallas")
    True
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Iterable, Sequence

import numpy as np

from repro.kernels.compaction import COMPACT_FLOOR, bucket_caps
from repro.kernels.dispatch import capacity_blocks
from repro.kernels.dt_traverse import BLOCK_B

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.inference import Engine

BACKENDS = ("looped", "fused", "pallas")

#: block_b candidates the model (and the tuner) consider for the pallas
#: step.  128 matches the kernel default (fp32 VPU lane tiling); smaller
#: blocks waste less capacity padding at small B / large S, larger ones
#: amortise per-block launch cost at large B.
BLOCK_B_CANDIDATES = (64, 128, 256)

#: Compaction-ladder floors the tuner sweeps for compact=True plans.
#: Smaller floors chase thinner survivor tails; below the Pallas block
#: size the gather/scatter overhead wins (see kernels.compaction).
COMPACT_FLOOR_CANDIDATES = (64, 128, 256)


@dataclasses.dataclass(frozen=True)
class ShapeInfo:
    """Everything the cost model needs to know about one workload.

    B          flows per batch (per *chunk* for streaming)
    S          total subtrees across all partitions (tables are SID-keyed)
    k          feature registers per flow
    P          partitions (recirculation hops)
    W          packets per window
    T          max thresholds per register slot (padded table width)
    L          max leaves per subtree (padded table height)
    n_devices  data-parallel shards the batch splits over (1 = single)
    survivors  optional per-hop active-flow fractions, ``survivors[p]``
               in (0, 1] = fraction of B still undecided entering hop p
               (``survivors[0]`` is always 1.0).  None = assume no early
               exits (conservative: compaction is modelled as pure
               overhead).
    """
    B: int
    S: int
    k: int
    P: int
    W: int
    T: int
    L: int
    n_devices: int = 1
    survivors: tuple[float, ...] | None = None

    def __post_init__(self):
        for f in ("B", "S", "k", "P", "W", "T", "L", "n_devices"):
            v = getattr(self, f)
            if v < (0 if f == "B" else 1):
                bound = "non-negative" if f == "B" else "positive"
                raise ValueError(f"{f} must be {bound}, got {v}")
        if self.survivors is not None and len(self.survivors) != self.P:
            raise ValueError(
                f"survivors must have one entry per hop "
                f"({self.P}), got {len(self.survivors)}")

    @classmethod
    def from_engine(cls, engine: "Engine", win_pkts=None, *,
                    B: int | None = None, W: int | None = None,
                    n_devices: int = 1,
                    survivors: Sequence[float] | None = None) -> "ShapeInfo":
        """Read (S, k, P, T, L) off an engine's packed tables.

        ``B``/``W`` come from ``win_pkts`` (B, P, W, F) when given
        (explicit ``B``/``W`` override); without windows BOTH must be
        passed — the packed tables do not record the window width, and
        guessing it would mis-scale the dominant feature-window cost
        term.
        """
        if win_pkts is not None:
            B = win_pkts.shape[0] if B is None else B
            W = int(win_pkts.shape[2]) if W is None else W
        elif B is None or W is None:
            raise ValueError("need win_pkts, or explicit B and W")
        ret = engine.ret
        return cls(B=int(B), S=int(ret.n_subtrees), k=int(ret.k),
                   P=int(engine.tables.n_partitions), W=int(W),
                   T=int(ret.max_thresholds), L=int(ret.max_leaves),
                   n_devices=int(n_devices),
                   survivors=None if survivors is None else tuple(survivors))

    def key(self) -> str:
        """Stable cache-key fragment (survivors excluded: the tuner keys
        on the static shape, not the data-dependent exit pattern)."""
        return (f"B{self.B}-S{self.S}-k{self.k}-P{self.P}-W{self.W}"
                f"-T{self.T}-L{self.L}-d{self.n_devices}")


@dataclasses.dataclass(frozen=True)
class Plan:
    """One resolved execution configuration.

    ``backend`` ∈ {looped, fused, pallas}; ``block_b`` only matters for
    pallas; ``compact``/``compact_floor`` configure the early-exit
    compaction ladder.  ``source`` records who decided ("costmodel",
    "timed", "cache", "forced") and ``est_us`` the model's estimate (or
    the measured time for timed/cache plans).
    """
    backend: str
    block_b: int = BLOCK_B
    compact: bool = False
    compact_floor: int = COMPACT_FLOOR
    source: str = "costmodel"
    est_us: float | None = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"options {BACKENDS}")

    def describe(self) -> str:
        bits = [self.backend]
        if self.backend == "pallas":
            bits.append(f"block_b={self.block_b}")
        if self.compact:
            bits.append(f"compact(floor={self.compact_floor})")
        bits.append(f"source={self.source}")
        if self.est_us is not None:
            bits.append(f"~{self.est_us:.0f}us")
        return " ".join(bits)


# ---------------------------------------------------------------------------
# coefficients
# ---------------------------------------------------------------------------
#: Work-term names, in the order `work_terms` emits them.  Each
#: coefficient is μs per unit of its term.
TERMS = (
    "call",         # per jitted dispatch (fixed)
    "sync",         # per host<->device round trip (looped: one per hop)
    "fw",           # feature-window rebuild, per flow*W*k element
    "tr_dense",     # dense range-match + table gather, per flow*(kT+Lk)
    "tr_pallas",    # block-dense kernel work, per padded flow*(kT+Lk)
    "grid",         # per pallas grid step (launch; huge in interpret)
    "sort",         # per flow*log2(B) of in-jit argsort (dispatch/compact)
)


@dataclasses.dataclass(frozen=True)
class Coefficients:
    """μs-per-unit weights for each term in :data:`TERMS`."""
    call: float
    sync: float
    fw: float
    tr_dense: float
    tr_pallas: float
    grid: float
    sort: float

    def vector(self) -> np.ndarray:
        return np.array([getattr(self, t) for t in TERMS], dtype=np.float64)

    @classmethod
    def from_vector(cls, v: Sequence[float]) -> "Coefficients":
        return cls(**{t: float(x) for t, x in zip(TERMS, v)})


#: Fitted per backend family on the 2-core CPU dev container via
#: :func:`calibrate` over d2 models spanning S∈[13, 21], B∈[256, 4096]
#: (see ``benchmarks/bench_engine.py`` and
#: ``tests/test_tuning.py::test_default_coefficients_route_sanely``).
#: Notes on the CPU entries: the pallas row is the *interpret-mode*
#: path (its ``grid`` term is the per-block interpreter overhead that
#: keeps the router off pallas at scale off-TPU); looped's huge
#: ``call``/``sync`` reflect the eager per-op dispatch train of a
#: host-synced hop, not a single jitted launch.  The TPU entries are
#: *estimates* seeded from the known kernel economics (block-dense
#: traversal beats gather-heavy dense math; grid steps are pipelined,
#: not interpreted) — refit with :func:`calibrate` on real hardware
#: before trusting absolute numbers there.
DEFAULT_COEFFS: dict[str, dict[str, Coefficients]] = {
    "cpu": {
        "fused": Coefficients(call=550.0, sync=250.0, fw=8.5e-3,
                              tr_dense=4.8e-3, tr_pallas=4.8e-3,
                              grid=4000.0, sort=1.5e-3),
        "pallas": Coefficients(call=500.0, sync=250.0, fw=2e-3,
                               tr_dense=4.8e-3, tr_pallas=8e-3,
                               grid=30.0, sort=0.75),
        "looped": Coefficients(call=28000.0, sync=14000.0, fw=8e-2,
                               tr_dense=4.8e-3, tr_pallas=4.8e-3,
                               grid=4000.0, sort=1.5e-3),
    },
    "tpu": {
        "fused": Coefficients(call=30.0, sync=150.0, fw=2e-5,
                              tr_dense=1.2e-4, tr_pallas=1.2e-4,
                              grid=2.0, sort=5e-5),
        "pallas": Coefficients(call=30.0, sync=150.0, fw=8e-6,
                               tr_dense=1.2e-4, tr_pallas=3e-5,
                               grid=2.0, sort=5e-5),
        "looped": Coefficients(call=500.0, sync=300.0, fw=2e-5,
                               tr_dense=1.2e-4, tr_pallas=1.2e-4,
                               grid=2.0, sort=5e-5),
    },
}


def default_coefficients(backend: str) -> Coefficients:
    """Per-backend platform defaults (CPU-fitted / TPU-estimated).

    Each backend family gets its own weights because the terms mean
    different things per path: looped's "call" is a train of eager op
    dispatches, fused's is one jitted launch, and pallas off-TPU pays
    the interpreter per grid step.
    """
    import jax
    platform = "tpu" if jax.default_backend() == "tpu" else "cpu"
    return DEFAULT_COEFFS[platform][backend]


# ---------------------------------------------------------------------------
# per-plan work terms
# ---------------------------------------------------------------------------
def _hop_rows(shape: ShapeInfo, plan: Plan) -> list[int]:
    """Flow slots each hop processes on ONE device shard.

    Dense walk: the full per-shard batch every hop.  Compacted walk:
    hop 0 is dense, later hops run the smallest capacity-ladder bucket
    that fits the surviving flows (``kernels.compaction.bucket_caps``),
    which is exactly what the compacted walk executes.  The looped
    backend compacts by host fancy-indexing, so its hop size is the
    survivor count itself.
    """
    Bd = -(-shape.B // shape.n_devices)          # per-shard batch
    surv = shape.survivors or (1.0,) * shape.P
    rows = []
    caps = bucket_caps(Bd, plan.compact_floor) if plan.compact else None
    for p in range(shape.P):
        n = Bd if p == 0 else int(math.ceil(surv[p] * Bd))
        if plan.compact and p > 0:
            if plan.backend == "looped":
                rows.append(n)
            else:
                rows.append(next(c for c in caps if c >= n))
        else:
            rows.append(Bd)
    return rows


def work_terms(shape: ShapeInfo, plan: Plan) -> np.ndarray:
    """Decompose one (shape, plan) into per-term work units.

    Returns a vector aligned with :data:`TERMS`; ``estimate_us`` is its
    dot product with a coefficient vector.  Kept separate so
    :func:`fit_coefficients` can build a design matrix from measured
    samples.
    """
    s, k = shape, shape.k
    unit = k * s.T + s.L * k                     # compare work per flow
    gather = k * s.T + 2 * s.L * k + 2 * s.L     # table rows pulled per flow
    w = dict.fromkeys(TERMS, 0.0)
    hops = _hop_rows(shape, plan)

    if plan.backend == "looped":
        # two dispatches (feature_window + dt_traverse) and one
        # device_get per hop; dense math on the survivor rows
        w["call"] = 2.0 * s.P
        w["sync"] = float(s.P)
        for n in hops:
            w["fw"] += n * s.W * k
            w["tr_dense"] += n * (unit + gather)
        return _vec(w)

    # walk backends: ONE dispatch per batch; compaction adds an in-jit
    # argsort per hop past the first
    w["call"] = 1.0
    sort_hops = range(1, s.P) if plan.compact else ()
    Bd = -(-s.B // s.n_devices)
    for p in sort_hops:
        w["sort"] += Bd * math.log2(max(Bd, 2))

    if plan.backend == "fused":
        for n in hops:
            w["fw"] += n * s.W * k
            w["tr_dense"] += n * (unit + gather)
        return _vec(w)

    # pallas: blocked feature kernel + SID dispatch + block-dense match
    bb = plan.block_b
    for n in hops:
        if n == 0:
            continue                             # drained ladder rung
        fw_blocks = -(-n // min(bb, max(n, 1)))
        nb = capacity_blocks(n, s.S, bb)
        w["fw"] += fw_blocks * min(bb, n) * s.W * k
        w["sort"] += n * math.log2(max(n, 2))    # sid argsort + scatter
        w["tr_pallas"] += nb * bb * unit
        w["grid"] += fw_blocks + nb
    return _vec(w)


def _vec(w: dict) -> np.ndarray:
    return np.array([w[t] for t in TERMS], dtype=np.float64)


def estimate_us(shape: ShapeInfo, plan: Plan,
                coeffs: Coefficients | None = None) -> float:
    """Model estimate (μs per batch) for running ``shape`` under ``plan``."""
    c = coeffs or default_coefficients(plan.backend)
    return float(work_terms(shape, plan) @ c.vector())


# ---------------------------------------------------------------------------
# serving tick estimate (the flow-table server's per-ingest shape)
# ---------------------------------------------------------------------------
#: Tick-engine families the flow-table server routes between: "fused"
#: runs the whole rank loop + hop drain inside one jitted tick step
#: (kernels.tick_step), "legacy" dispatches per rank and per drain
#: round with a host sync in between.
TICK_ENGINES = ("fused", "legacy")


def tick_work_terms(shape: ShapeInfo, plan: Plan, *, ranks: int = 4,
                    drains: float = 1.0,
                    tick_engine: str = "fused") -> np.ndarray:
    """Per-:data:`TERMS` work units for ONE flow-table ingest tick.

    ``shape.B`` is the padded rank width (slots touched per tick),
    ``shape.W`` should be 1 (the incremental fold sees one packet per
    slot per rank), ``ranks`` the tick's rank-chain depth (max packets
    of any one flow), and ``drains`` the expected extra hop rounds from
    empty trailing windows.  The per-rank *work* terms are identical
    for both tick engines — only the dispatch/sync pattern differs:

    * ``legacy`` — one admission reset + one fold call per rank + one
      hop call **and host sync** per traverse round;
    * ``fused``  — one admission scatter + ONE tick-step call + ONE
      bulk verdict fetch, whatever the rank count or drain depth.

    On a CPU host the ~0.5 ms ``call`` coefficient makes the fused tick
    the winner for every non-trivial tick; the term split keeps the
    decision honest if the coefficients are refit on hardware where
    dispatch is cheap and the scan's serialization might matter.
    """
    if tick_engine not in TICK_ENGINES:
        raise ValueError(f"unknown tick engine {tick_engine!r}; "
                         f"options {TICK_ENGINES}")
    s, k = shape, shape.k
    unit = k * s.T + s.L * k
    gather = k * s.T + 2 * s.L * k + 2 * s.L
    B = max(int(s.B), 1)
    hops = ranks + drains                        # traverse rounds / tick
    w = dict.fromkeys(TERMS, 0.0)
    if tick_engine == "legacy":
        w["call"] = 1.0 + ranks + hops
        w["sync"] = float(hops)
    else:
        w["call"] = 2.0
        w["sync"] = 1.0
    w["fw"] = float(ranks) * B * k               # one packet per fold
    if plan.backend == "pallas":
        bb = plan.block_b
        nb = capacity_blocks(B, s.S, bb)
        fw_blocks = -(-B // min(bb, B))
        w["grid"] = ranks * fw_blocks + hops * nb
        w["sort"] = hops * B * math.log2(max(B, 2))
        w["tr_pallas"] = hops * nb * bb * unit
    else:
        w["tr_dense"] = hops * B * (unit + gather)
    return _vec(w)


def estimate_tick_us(shape: ShapeInfo, plan: Plan, *, ranks: int = 4,
                     drains: float = 1.0, tick_engine: str = "fused",
                     coeffs: Coefficients | None = None) -> float:
    """Model estimate (μs per ingest tick) for the flow-table server."""
    c = coeffs or default_coefficients(plan.backend)
    return float(tick_work_terms(shape, plan, ranks=ranks, drains=drains,
                                 tick_engine=tick_engine) @ c.vector())


def choose_tick_engine(shape: ShapeInfo, *, ranks: int = 4,
                       drains: float = 1.0, backend: str = "fused",
                       block_b: int = BLOCK_B,
                       coeffs: Coefficients | None = None) -> str:
    """Pick fused-tick vs legacy per-rank serving for a table shape.

    Used by ``FlowTableServer(tick_engine="auto")`` once the walk
    backend/block size are resolved (``impl="auto"``/``"tuned"``).
    Pure arithmetic, ties go to fused (fewer dispatches can only help
    the tail).
    """
    plan = Plan(backend=backend, block_b=block_b)
    kw = dict(ranks=ranks, drains=drains, coeffs=coeffs)
    fused = estimate_tick_us(shape, plan, tick_engine="fused", **kw)
    legacy = estimate_tick_us(shape, plan, tick_engine="legacy", **kw)
    return "fused" if fused <= legacy else "legacy"


def choose_tick_plan(
    shape: ShapeInfo, *, ranks: int = 4, drains: float = 1.0,
    backends: Sequence[str] = ("fused", "pallas"),
    coeffs: dict[str, Coefficients] | None = None,
) -> tuple[str, Plan]:
    """Argmin (tick_engine, walk plan) for one serving tick shape.

    The serving analogue of :func:`choose_plan`: sweeps the walk
    backends × ``BLOCK_B_CANDIDATES`` × both tick engines and returns
    the cheapest combination — how the tick-shape estimate picks
    ``block_b`` for the table shape alongside the engine.
    """
    best = None
    best_us = float("inf")
    for te in TICK_ENGINES:
        for plan in candidate_plans(shape, backends=backends,
                                    compact=False):
            c = (coeffs or {}).get(plan.backend) if coeffs else None
            us = estimate_tick_us(shape, plan, ranks=ranks, drains=drains,
                                  tick_engine=te, coeffs=c)
            if us < best_us:
                best, best_us = (te, plan), us
    te, plan = best
    return te, dataclasses.replace(plan, source="costmodel",
                                   est_us=round(best_us, 1))


# ---------------------------------------------------------------------------
# plan enumeration + selection
# ---------------------------------------------------------------------------
def candidate_plans(
    shape: ShapeInfo,
    *,
    backends: Sequence[str] = BACKENDS,
    compact: bool | str | None = "auto",
    block_bs: Sequence[int] = BLOCK_B_CANDIDATES,
    compact_floors: Sequence[int] = COMPACT_FLOOR_CANDIDATES,
) -> list[Plan]:
    """Enumerate the configurations the router/tuner chooses between.

    ``compact`` — True/False pins compaction; "auto"/None explores both
    (the compact=True variants only when a survivor profile suggests
    early exits, or unconditionally for the tuner to measure).
    Compacted plans additionally sweep the capacity-ladder floor
    (``compact_floors``); the looped backend compacts by exact host
    indexing, so it gets a single compacted variant.  ``backends``
    restricts the search (streaming excludes "looped").
    """
    compacts: tuple[bool, ...]
    if compact in ("auto", None):
        compacts = (False, True)
    else:
        compacts = (bool(compact),)
    plans = []
    for backend in backends:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        bbs = block_bs if backend == "pallas" else (BLOCK_B,)
        for bb in bbs:
            for cp in compacts:
                floors = (compact_floors if cp and backend != "looped"
                          else (COMPACT_FLOOR,))
                for fl in floors:
                    plans.append(Plan(backend=backend, block_b=bb,
                                      compact=cp, compact_floor=fl))
    return plans


def choose_plan(
    shape: ShapeInfo,
    *,
    backends: Sequence[str] = BACKENDS,
    compact: bool | str | None = False,
    coeffs: dict[str, Coefficients] | None = None,
) -> Plan:
    """Pick the argmin-cost plan for ``shape`` (``impl="auto"``).

    Pure arithmetic — never times anything, so it is safe on the hot
    path.  ``compact`` defaults to False here (the caller's explicit
    ``compact=`` wins); pass "auto" to let the model weigh compaction
    against the shape's survivor profile.
    """
    best, best_us = None, float("inf")
    for plan in candidate_plans(shape, backends=backends, compact=compact):
        c = (coeffs or {}).get(plan.backend) if coeffs else None
        us = estimate_us(shape, plan, c)
        if us < best_us:
            best, best_us = plan, us
    return dataclasses.replace(best, source="costmodel",
                               est_us=round(best_us, 1))


# ---------------------------------------------------------------------------
# fitting
# ---------------------------------------------------------------------------
def fit_coefficients(
    samples: Iterable[tuple[ShapeInfo, Plan, float]],
    *,
    base: Coefficients | None = None,
) -> Coefficients:
    """Non-negative least-squares fit of :data:`TERMS` weights.

    ``samples`` are (shape, plan, measured_us) triples.  Terms with no
    support in the design matrix (e.g. no compacted samples → no sort
    column) keep the ``base`` coefficient (platform default) instead of
    collapsing to 0, so a partial calibration never breaks routing for
    unmeasured configurations.  Non-negativity via projected iteration:
    solve lstsq over the supported columns, pin negative solutions to
    zero, re-solve the rest (a small NNLS).
    """
    samples = list(samples)
    if not samples:
        raise ValueError("need at least one calibration sample")
    A = np.stack([work_terms(s, p) for s, p, _ in samples])
    y = np.array([us for _, _, us in samples], dtype=np.float64)
    base_v = (base or default_coefficients("fused")).vector()
    x = np.where(A.any(axis=0), 0.0, base_v)     # unsupported -> base
    free = A.any(axis=0)                         # columns with support
    for _ in range(len(TERMS)):
        idx = np.nonzero(free)[0]
        if idx.size == 0:
            break
        sol, *_ = np.linalg.lstsq(A[:, idx], y, rcond=None)
        neg = sol < 0
        x[idx] = np.where(neg, 0.0, sol)
        if not neg.any():
            break
        free[idx[neg]] = False                   # pin to 0, re-solve rest
    return Coefficients.from_vector(x)


def calibrate(
    engine: "Engine",
    win_pkts,
    *,
    probe_sizes: Sequence[int] = (256, 1024),
    repeat: int = 2,
    include_pallas: bool = True,
) -> dict[str, Coefficients]:
    """Fit per-backend coefficients from micro-benchmarks of ``engine``.

    Times the fused walk at each probe size, the looped walk at the
    smallest, and (optionally) the pallas walk at the smallest — then
    fits one :class:`Coefficients` per backend family.  Returns a dict
    usable as ``choose_plan(..., coeffs=...)``.  Cheap by construction:
    a handful of sub-second probes, intended for the autotuner's first
    run on a new host, not the request path.
    """
    from repro.tuning.autotune import time_plan

    B = win_pkts.shape[0]
    sizes = sorted({min(s, B) for s in probe_sizes if s > 0})
    samples: dict[str, list] = {b: [] for b in BACKENDS}
    for n in sizes:
        shape = ShapeInfo.from_engine(engine, win_pkts, B=n)
        plan = Plan(backend="fused")
        samples["fused"].append(
            (shape, plan, time_plan(engine, win_pkts[:n], plan,
                                    repeat=repeat)))
    n0 = sizes[0]
    shape0 = ShapeInfo.from_engine(engine, win_pkts, B=n0)
    lp = Plan(backend="looped")
    samples["looped"].append(
        (shape0, lp, time_plan(engine, win_pkts[:n0], lp, repeat=repeat)))
    if include_pallas:
        pp = Plan(backend="pallas")
        samples["pallas"].append(
            (shape0, pp, time_plan(engine, win_pkts[:n0], pp,
                                   repeat=repeat)))
    return {b: fit_coefficients(ss, base=default_coefficients(b))
            for b, ss in samples.items() if ss}

"""Backend routing for the engine: cost model + cached autotuner.

``impl="auto"``  → :func:`repro.tuning.costmodel.choose_plan` — pure
arithmetic over an analytical per-hop cost model; safe on the hot path.
``impl="tuned"`` → :func:`repro.tuning.autotune.autotune` — times a
cost-model shortlist on the actual model/batch shape, caches the winner
per (shape, device fingerprint).

Every plan is a pure *execution* choice: all backends are bit-identical
(``docs/PARITY.md``), so routing can only change speed, never verdicts.
"""
from repro.tuning.autotune import (  # noqa: F401
    autotune,
    cache_path,
    device_fingerprint,
    get_plan,
    load_cache,
    save_cache,
    time_plan,
)
from repro.tuning.costmodel import (  # noqa: F401
    BACKENDS,
    BLOCK_B_CANDIDATES,
    TICK_ENGINES,
    Coefficients,
    Plan,
    ShapeInfo,
    calibrate,
    candidate_plans,
    choose_plan,
    choose_tick_engine,
    choose_tick_plan,
    estimate_tick_us,
    estimate_us,
    fit_coefficients,
    tick_work_terms,
    work_terms,
)

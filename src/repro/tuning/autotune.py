"""Cached empirical autotuner for the engine (``impl="tuned"``).

The cost model (``repro.tuning.costmodel``) routes analytically; this
module *measures*.  Given the user's actual model and batch shape it

  1. shortlists candidate plans by cost-model estimate (so off-TPU it
     never wastes minutes timing interpret-mode pallas at huge B),
  2. times each shortlisted plan on a bounded probe slice of the real
     windows (compile excluded: one warm-up call, then ``repeat`` timed
     calls, median),
  3. persists the winner to a JSON cache keyed by (shape key, device
     fingerprint), so every later ``impl="tuned"`` call with the same
     shape on the same host is a dict lookup,
  4. falls back to the pure cost model when timing is disallowed
     (``allow_timing=False`` or ``SPLIDT_AUTOTUNE_NO_TIME=1``) — e.g.
     latency-sensitive callers that must never run probes inline.

Cache location: ``SPLIDT_AUTOTUNE_CACHE`` env var, else
``~/.cache/splidt/autotune.json``.  The cache stores *decisions*, not
timings-for-dashboards — `benchmarks/bench_engine.py` owns trend
tracking.

Correctness is never at stake: every backend is bit-identical (see
``docs/PARITY.md``), so a stale or even corrupt cache entry can only
cost speed.  Unknown backends in a cache entry (e.g. written by a newer
version) are ignored and retuned.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import TYPE_CHECKING, Sequence

import numpy as np

from repro import obs
from repro.kernels.compaction import COMPACT_FLOOR
from repro.kernels.dt_traverse import BLOCK_B
from repro.tuning.costmodel import (
    BACKENDS,
    Plan,
    ShapeInfo,
    candidate_plans,
    choose_plan,
    estimate_us,
)

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.inference import Engine

CACHE_ENV = "SPLIDT_AUTOTUNE_CACHE"
NO_TIME_ENV = "SPLIDT_AUTOTUNE_NO_TIME"
CACHE_VERSION = 1

#: Probe slice bound: candidates are timed on at most this many flows
#: (per-flow throughput is what the plan optimises; beyond a few
#: thousand flows the ranking is stable and probing the full batch
#: would defeat the point of tuning).
PROBE_FLOWS = 2048

#: How many cost-model-shortlisted candidates get timed.
SHORTLIST = 4


def cache_path() -> str:
    """Resolve the cache file path (env override, else ~/.cache)."""
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "splidt",
                        "autotune.json")


@functools.lru_cache(maxsize=1)
def device_fingerprint() -> str:
    """Host identity the cache is keyed on.

    Captures what changes plan rankings: the jax platform, the device
    kind, how many devices are visible, and (for CPU) the core count
    that bounds intra-op parallelism.  Cached — the device set is fixed
    for the life of the process.
    """
    import jax
    dev = jax.devices()[0]
    kind = getattr(dev, "device_kind", dev.platform)
    return (f"{jax.default_backend()}:{kind}:{len(jax.devices())}"
            f":cpu{os.cpu_count()}").replace(" ", "_")


def _compact_tag(compact) -> str:
    """Cache-key fragment for the caller's compaction request.

    A plan tuned under ``compact="auto"`` may legitimately be
    compacted; serving it to a caller who PINNED ``compact=False``
    (the dense reference path) would silently override the pin — so
    pinned and auto requests tune and cache separately.
    """
    if compact in ("auto", None):
        return "cA"
    return "c1" if compact else "c0"


def cache_key(shape: ShapeInfo, *, streaming: bool = False,
              compact="auto", backends: Sequence[str] = BACKENDS) -> str:
    """Cache identity: device × shape × every search restriction.

    ``compact`` and ``backends`` are part of the key because a winner
    found under a narrowed search (pinned compaction, walk-only
    backends) must not be served to a later full search — it may have
    never competed against the true best candidate.
    """
    return (f"{device_fingerprint()}/{shape.key()}"
            f"/{_compact_tag(compact)}/b={'+'.join(sorted(backends))}"
            + ("/stream" if streaming else ""))


# ---------------------------------------------------------------------------
# cache I/O — tolerant of missing/corrupt files (tuning must never
# break inference)
# ---------------------------------------------------------------------------
# (path, mtime_ns, size) -> entries; keeps the warm impl="tuned" path
# off the disk (stream_batches resolves a plan per incoming batch)
_load_memo: dict[str, tuple[tuple, dict]] = {}

# (cache path, cache key) -> winning Plan from THIS process's timed
# searches.  The backstop for unwritable cache files (read-only HOME,
# sandboxes): persistence may fail, but "every later impl='tuned' call
# is a dict lookup" must still hold within the process — without this,
# a failed save silently re-runs the multi-second probe search on
# every batch.
_winner_memo: dict[tuple[str, str], Plan] = {}


def _file_stamp(path: str):
    st = os.stat(path)
    return (st.st_mtime_ns, st.st_size)


def load_cache(path: str | None = None) -> dict:
    path = path or cache_path()
    try:
        stamp = _file_stamp(path)
        hit = _load_memo.get(path)
        if hit is not None and hit[0] == stamp:
            return dict(hit[1])
        with open(path) as f:
            data = json.load(f)
        if data.get("version") != CACHE_VERSION:
            return {}
        entries = data.get("entries")
        entries = entries if isinstance(entries, dict) else {}
        _load_memo[path] = (stamp, entries)
        # a COPY: callers (autotune) mutate the result before saving,
        # and a failed save must not leave phantom entries in the memo
        return dict(entries)
    except (OSError, ValueError):
        return {}


def save_cache(entries: dict, path: str | None = None) -> str:
    path = path or cache_path()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"version": CACHE_VERSION, "entries": entries}, f,
                  indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    try:
        _load_memo[path] = (_file_stamp(path), dict(entries))
    except OSError:
        pass
    return path


def _plan_to_entry(plan: Plan, us: float) -> dict:
    return {"backend": plan.backend, "block_b": plan.block_b,
            "compact": plan.compact, "compact_floor": plan.compact_floor,
            "us": round(us, 1)}


def _entry_to_plan(entry: dict) -> Plan | None:
    try:
        if entry["backend"] not in BACKENDS:
            return None
        return Plan(backend=entry["backend"],
                    block_b=int(entry.get("block_b", BLOCK_B)),
                    compact=bool(entry.get("compact", False)),
                    compact_floor=int(entry.get("compact_floor",
                                                COMPACT_FLOOR)),
                    source="cache", est_us=float(entry.get("us", 0)) or None)
    except (KeyError, TypeError, ValueError):
        return None


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------
def time_plan(engine: "Engine", win_pkts: np.ndarray, plan: Plan, *,
              repeat: int = 3) -> float:
    """Median μs/call for running ``win_pkts`` under ``plan``.

    One un-timed warm-up call absorbs compilation; verdict arrays are
    fetched inside the timed region (the engine's real cost includes the
    device→host transfer).
    """
    from repro.core.inference import backend_for_plan

    backend = backend_for_plan(plan)

    def call():
        # splint: allow[R005]: ExecutionBackend protocol run() — compact/
        # compact_floor are real parameters here, not the Engine shim
        return backend.run(engine, win_pkts, with_trace=False,
                           compact=plan.compact,
                           compact_floor=plan.compact_floor)

    call()                                       # compile / warm caches
    ts = []
    for _ in range(max(repeat, 1)):
        t0 = time.perf_counter()
        call()
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


@functools.lru_cache(maxsize=4096)
def _choose_cached(shape: ShapeInfo, backends: tuple, compact) -> Plan:
    """Memoised :func:`choose_plan` for the ``impl="auto"`` hot path.

    ShapeInfo is frozen/hashable and the default coefficients are
    per-process constants, so the argmin for a given (shape, backends,
    compact) never changes within a process — re-enumerating candidates
    on every micro-batch would be pure overhead.
    """
    return choose_plan(shape, backends=backends, compact=compact)


def _timing_allowed(allow_timing: bool | None) -> bool:
    if allow_timing is not None:
        return allow_timing
    return os.environ.get(NO_TIME_ENV, "") not in ("1", "true", "yes")


# ---------------------------------------------------------------------------
# the tuner
# ---------------------------------------------------------------------------
def autotune(
    engine: "Engine",
    win_pkts: np.ndarray,
    *,
    shape: ShapeInfo | None = None,
    backends: Sequence[str] = BACKENDS,
    compact: bool | str | None = "auto",
    allow_timing: bool | None = None,
    cache: bool = True,
    path: str | None = None,
    force: bool = False,
    repeat: int = 3,
    probe_flows: int = PROBE_FLOWS,
    shortlist: int = SHORTLIST,
    streaming: bool = False,
) -> Plan:
    """Resolve the best plan for (engine, batch shape) on this host.

    Resolution order: cache hit → timed search → cost model.  ``shape``
    defaults to the batch's own shape; pass it explicitly when tuning
    for a different deployment batch size than the probe windows.
    ``backends`` restricts candidates (streaming passes the walk
    backends only); ``compact="auto"`` lets the tuner measure
    compaction both ways, True/False pins it.  ``force=True`` ignores
    (and overwrites) the cache entry.

    The probe never runs more than ``probe_flows`` flows, and the
    cost-model ranking is what keeps a CPU-only host from stalling:
    candidates are sorted by estimate first and only the top
    ``shortlist`` get timed, so interpret-mode pallas at large B (whose
    estimate is enormous off-TPU) never reaches the stopwatch.  At
    small B its estimate is competitive and it IS timed — that is the
    point of measuring.
    """
    if shape is None:
        shape = ShapeInfo.from_engine(engine, win_pkts)
    key = cache_key(shape, streaming=streaming, compact=compact,
                    backends=backends)

    reg_obs = obs.get_registry()
    mkey = (path or cache_path(), key)
    entries = load_cache(path) if cache else {}
    if cache and not force:
        hit = _entry_to_plan(entries.get(key, {}))
        if hit is None:
            hit = _winner_memo.get(mkey)
        if hit is not None and hit.backend in backends:
            reg_obs.counter("tune_cache_hits_total",
                            "autotune calls served from cache").inc()
            return hit
    reg_obs.counter("tune_cache_misses_total",
                    "autotune calls not served from cache").inc()

    if not _timing_allowed(allow_timing):
        return choose_plan(shape, backends=backends,
                           compact=False if compact == "auto" else compact)

    # ---- timed search over the cost-model shortlist -------------------
    n = min(shape.B, probe_flows, win_pkts.shape[0])
    probe = win_pkts[:n]
    ranked = sorted(
        candidate_plans(shape, backends=backends, compact=compact),
        key=lambda p: estimate_us(shape, p))
    best_plan, best_us = None, float("inf")
    for plan in ranked[:max(shortlist, 1)]:
        with obs.span("tune/probe"):
            us = time_plan(engine, probe, plan, repeat=repeat)
        reg_obs.counter("tune_probes_total", "timed probe runs",
                        labels={"backend": plan.backend}).inc()
        if obs.enabled():
            reg_obs.histogram(
                "tune_probe_us", "probe outcome (median us/call)",
                edges=obs.exp_edges(10.0, 1e7, 13),
                labels={"backend": plan.backend}).record(us)
        if us < best_us:
            best_plan, best_us = plan, us
    winner = dataclasses.replace(best_plan, source="timed",
                                 est_us=round(best_us, 1))
    if cache:
        _winner_memo[mkey] = dataclasses.replace(winner, source="cache")
        entries[key] = _plan_to_entry(winner, best_us)
        try:
            save_cache(entries, path)
        except OSError:
            pass    # unwritable cache (read-only HOME, sandbox): the
                    # in-process memo above still routes this process;
                    # never raise out of inference over persistence
    return winner


def get_plan(
    engine: "Engine",
    win_pkts: np.ndarray | None = None,
    *,
    impl: str = "auto",
    shape: ShapeInfo | None = None,
    backends: Sequence[str] = BACKENDS,
    compact: bool | str | None = False,
    streaming: bool = False,
) -> Plan:
    """The engine's entry point: resolve ``impl`` → :class:`Plan`.

    * ``impl="auto"``  — pure cost model (no timing ever, no cache).
    * ``impl="tuned"`` — :func:`autotune` (cache → timed → cost model).
    * a fixed backend name — a forced plan for that backend, with
      ``compact="auto"`` still resolved by the cost model.

    ``compact`` may be True/False (pinned), or "auto" (the plan
    decides).
    """
    if shape is None:
        if win_pkts is None:
            raise ValueError("need win_pkts or an explicit shape")
        shape = ShapeInfo.from_engine(engine, win_pkts)
    if impl == "tuned":
        if win_pkts is None:
            # nothing to probe: degrade gracefully to the cost model
            return choose_plan(shape, backends=backends,
                               compact=False if compact == "auto" else compact)
        return autotune(engine, win_pkts, shape=shape, backends=backends,
                        compact=compact, streaming=streaming)
    if impl == "auto":
        return _choose_cached(shape, tuple(backends), compact)
    if impl == "ref":
        impl = "fused"
    if impl not in BACKENDS:
        raise ValueError(f"unknown impl {impl!r}; options: auto, tuned, "
                         "ref, " + ", ".join(sorted(BACKENDS)))
    if impl not in backends:
        raise ValueError(f"impl {impl!r} not allowed here "
                         f"(allowed: {tuple(backends)})")
    if compact == "auto":
        plan = choose_plan(shape, backends=(impl,), compact="auto")
        return dataclasses.replace(plan, source="forced")
    plan = Plan(backend=impl, compact=bool(compact), source="forced")
    return dataclasses.replace(
        plan, est_us=round(estimate_us(shape, plan), 1))

"""Markdown table generation for EXPERIMENTS.md from dry-run artifacts.

    PYTHONPATH=src python -m repro.analysis.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load(dirpath: str) -> dict[tuple, dict]:
    out = {}
    for p in glob.glob(os.path.join(dirpath, "*.json")):
        rec = json.load(open(p))
        out[(rec["arch"], rec["shape"], rec["mesh"])] = rec
    return out


ARCH_ORDER = ["tinyllama-1.1b", "minitron-8b", "granite-3-2b", "stablelm-3b",
              "rwkv6-1.6b", "whisper-medium", "qwen2-moe-a2.7b",
              "deepseek-v2-236b", "paligemma-3b", "zamba2-2.7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def dryrun_table(recs: dict) -> str:
    lines = ["| arch | shape | 16x16 | 2x16x16 | compile s | analytic GB/chip"
             " (fits) | collectives (single-pod) |",
             "|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r1 = recs.get((a, s, "16x16"))
            r2 = recs.get((a, s, "2x16x16"))
            if r1 is None:
                continue
            if r1.get("status") == "skipped":
                reason = r1.get("reason", "")[:58]
                lines.append(f"| {a} | {s} | skip | skip | — | — | {reason} |")
                continue
            mem = r1["analytic_memory"]
            cc = r1["collectives"]["counts"]
            coll = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                            for k, v in cc.items() if v)
            ok2 = "ok" if (r2 or {}).get("status") == "ok" else (
                "skip" if (r2 or {}).get("status") == "skipped" else "?")
            lines.append(
                f"| {a} | {s} | ok | {ok2} | {r1['t_compile_s']:.0f} | "
                f"{mem['total_gb']:.1f} ({'y' if mem['fits'] else 'n'}) | "
                f"{coll} |")
    return "\n".join(lines)


def roofline_table(recs: dict, mesh: str = "16x16") -> str:
    lines = ["| arch | shape | t_comp s | t_mem s | t_mem(hlo) s | t_coll s |"
             " bound | 6ND/HLO | roofline frac | fix |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            rec = recs.get((a, s, mesh))
            if rec is None or rec.get("status") != "ok":
                continue
            r = rec.get("roofline")
            if not r:
                continue
            fix = _fix_hint(r["bottleneck"], s)
            lines.append(
                f"| {a} | {s} | {r['t_compute_s']:.4f} | {r['t_memory_s']:.4f}"
                f" | {r['t_memory_hlo_s']:.3f} | {r['t_collective_s']:.4f} | "
                f"{r['bottleneck']} | {r['useful_flops_fraction']:.3f} | "
                f"{r['roofline_fraction']:.4f} | {fix} |")
    return "\n".join(lines)


def _fix_hint(bound: str, shape: str) -> str:
    if bound == "collective":
        if shape == "train_4k":
            return "FSDP-2D layout (kills TP activation ARs)"
        return "resident weights / einsum MoE dispatch"
    if bound == "memory":
        if "decode" in shape or "long" in shape:
            return "cache sweep is the wall: quantise KV / widen batch"
        return "blockwise attention + fusion"
    return "at compute bound: raise useful-FLOP frac (remat policy)"


def opt_compare_table(recs: dict) -> str:
    lines = ["| cell | metric | baseline | optimized | gain |",
             "|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            b = recs.get((a, s, "16x16"))
            o = recs.get((a, s, "16x16_opt"))
            if not b or not o or "roofline" not in (b or {}) \
                    or "roofline" not in (o or {}):
                continue
            rb, ro = b["roofline"], o["roofline"]
            tb = max(rb["t_compute_s"], rb["t_memory_s"], rb["t_collective_s"])
            to = max(ro["t_compute_s"], ro["t_memory_s"], ro["t_collective_s"])
            lines.append(
                f"| {a} x {s} | step-time bound | {tb:.4f}s | {to:.4f}s | "
                f"{tb / to:.1f}x |")
            lines.append(
                f"| | roofline fraction | {rb['roofline_fraction']:.4f} | "
                f"{ro['roofline_fraction']:.4f} | "
                f"{ro['roofline_fraction'] / max(rb['roofline_fraction'], 1e-9):.1f}x |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"))
    ap.add_argument("--table", choices=("dryrun", "roofline", "opt", "all"),
                    default="all")
    args = ap.parse_args()
    recs = load(args.dir)
    if args.table in ("dryrun", "all"):
        print("## Dry-run matrix\n")
        print(dryrun_table(recs))
    if args.table in ("roofline", "all"):
        print("\n## Roofline (single-pod 16x16, baseline layout)\n")
        print(roofline_table(recs))
    if args.table in ("opt", "all"):
        print("\n## Baseline vs optimized cells\n")
        print(opt_compare_table(recs))


if __name__ == "__main__":
    main()

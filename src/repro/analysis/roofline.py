"""Roofline analysis from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.

Terms per (arch x shape x mesh):
    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * hbm_bw)
    collective = collective_bytes / (chips * link_bw)

Method note (documented in EXPERIMENTS.md): XLA's HLO cost analysis
counts while-loop bodies ONCE, so scanned layer stacks would undercount
by ~L x.  Layer stacks are homogeneous, so every cost is exactly affine
in depth: we compile two small UNROLLED depth variants of the same cell
(same shapes, same mesh, same shardings), fit ``cost = a + b * depth``,
and evaluate at the full depth.  The fit is exact (observed residual
<1e-5 relative); the dry-run records both sample points and the
extrapolation.  Collective bytes are parsed from the optimised post-SPMD
HLO text of the same compiled executables (operand bytes of all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

# --- TPU v5e constants ------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of an HLO shape string like 'f32[128,256]' or a tuple."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    bytes_by_kind: dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in optimised HLO.

    Uses the op's RESULT shape (per-device payload after SPMD
    partitioning) — for all-gather that's the gathered (larger) side,
    for reduce-scatter the pre-scatter side is the operand; result-shape
    accounting is the conservative per-device wire estimate.
    """
    counts: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    nbytes: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # e.g.:  %ag = bf16[4,128]{1,0} all-gather(%x), replica_groups=...
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=]+?)\s+"
                     r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", s)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        if "-done" in s.split(kind)[1][:6]:
            continue
        counts[kind] += 1
        nbytes[kind] += _shape_bytes(shape_str)
    return CollectiveStats(counts=counts, bytes_by_kind=nbytes)


@dataclasses.dataclass
class RooflineTerms:
    flops_per_chip: float
    hbm_bytes_per_chip: float        # HLO "bytes accessed" (unfused bound)
    collective_bytes_per_chip: float
    chips: int
    model_flops: float               # 6*N*D (active N for MoE), global
    hbm_bytes_model: float = 0.0     # fusion-aware analytic estimate

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory_hlo(self) -> float:
        """Upper bound: XLA:CPU HLO bytes count every elementwise
        intermediate as HBM traffic (no TPU-grade fusion)."""
        return self.hbm_bytes_per_chip / HBM_BW

    @property
    def t_memory(self) -> float:
        """Fusion-aware analytic HBM traffic (see analytic_hbm_bytes);
        falls back to the HLO bound when no model was supplied."""
        b = self.hbm_bytes_model or self.hbm_bytes_per_chip
        return b / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        hlo_global = self.flops_per_chip * self.chips
        return self.model_flops / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-FLOP throughput fraction at the bound set by the
        dominant term: (model_flops/chips/peak) / max(all terms)."""
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        t_useful = self.model_flops / self.chips / PEAK_FLOPS
        return t_useful / t_bound if t_bound else 0.0

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops_per_chip": self.flops_per_chip,
            "hbm_bytes_per_chip": self.hbm_bytes_per_chip,
            "hbm_bytes_model": self.hbm_bytes_model,
            "collective_bytes_per_chip": self.collective_bytes_per_chip,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_hlo_s": self.t_memory_hlo,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "useful_flops_fraction": self.useful_flops_fraction,
            "roofline_fraction": self.roofline_fraction,
        }


def affine_extrapolate(v1: float, v2: float, n1: int, n2: int,
                       n_full: int) -> float:
    """cost(n) = a + b*n through (n1, v1), (n2, v2), evaluated at n_full."""
    b = (v2 - v1) / (n2 - n1)
    a = v1 - b * n1
    return a + b * n_full


def analytic_hbm_bytes(cfg, shape, mesh_sizes: dict[str, int],
                       cache_bytes_per_chip: int = 0,
                       resident_param_bytes: int = 0) -> float:
    """Fusion-aware per-chip HBM traffic model (bytes per step).

    XLA:CPU HLO byte counts include every unfused elementwise
    intermediate (measured ~5-15x TPU reality), so the memory roofline
    term uses this transparent first-principles model instead; the HLO
    number is kept in the table as the unfused upper bound.

    Terms (bf16 activations/weights-in-compute, f32 master+optimizer):
      weights: 3 fwd-equivalent passes read the TP shard (FSDP gather
               writes + compute reads), + optimizer read/write of the
               fully-sharded f32 state (train only);
      activations: remat policy saves ~3 residual-sized tensors/layer
               (write fwd, read bwd) + one live layer working set;
      attention: flash-style — q/k/v/out traffic only, NO T^2 term
               (the T^2 probs stay in VMEM in the fused kernel);
      moe: dispatch/combine buffer traffic (~6 residual-sized passes of
               the top-k routed copies);
      logits/loss: one f32 vocab-sharded read+write;
      decode: the whole per-chip KV/state cache is read once per token
               (+ params), which is the classic decode memory wall.
    """
    from repro.models import model_zoo
    tp = mesh_sizes.get("model", 1)
    dp = mesh_sizes.get("data", 1) * mesh_sizes.get("pod", 1)
    chips = tp * dp
    P = model_zoo.param_count(cfg)
    B = shape.global_batch
    T = 1 if shape.kind == "decode" else shape.seq_len
    tokens_loc = max(B // dp, 1) * T
    D = cfg.d_model
    L = cfg.n_layers
    act_elem = 2  # bf16

    if shape.kind == "decode":
        # one sweep of the chip-resident weights + the whole cache shard
        w = resident_param_bytes or 2 * P / tp
        cache = cache_bytes_per_chip
        act = 10 * L * tokens_loc * D * act_elem
        return float(w + cache + act)

    train = shape.kind == "train"
    passes = 3 if train else 1              # fwd + bwd + remat-fwd
    w = passes * 2 * (P / tp) * 2
    if train:
        w += 6 * (P / chips) * 4            # adam m/v/p read+write (f32)
    saved = 3 * L * tokens_loc * D * act_elem
    act = (2 if train else 1) * saved
    # flash attention q/k/v/out traffic (heads TP-sharded)
    h_frac = max(cfg.n_heads // tp, 1) / cfg.n_heads
    attn = passes * 4 * L * tokens_loc * cfg.n_heads * cfg.head_dim \
        * h_frac * act_elem
    moe = 0.0
    if cfg.moe is not None:
        moe = passes * 6 * L * tokens_loc * cfg.moe.top_k * D * act_elem / tp
    logits = 2 * tokens_loc * (cfg.vocab / tp) * 4
    return float(w + act + attn + moe + logits)


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D forward-only (prefill/decode)."""
    from repro.models import model_zoo
    n = model_zoo.param_count(cfg, active_only=cfg.moe is not None)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        if cfg.family.value == "audio":
            tokens = shape.global_batch * (shape.seq_len // cfg.dec_ratio
                                           + shape.seq_len)  # dec + enc share
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch

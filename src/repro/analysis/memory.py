"""Analytic per-device HBM accounting (exact for state, modelled for
activations).

``compiled.memory_analysis()`` on the CPU backend reports buffer totals
WITHOUT liveness-based reuse (verified: temp scales linearly in layer
count even under remat), so it wildly overstates the TPU high-water
mark.  We therefore report BOTH: the raw artifact and this analytic
model, which is exact for all persistent state (params / optimizer /
cache bytes are computed from the resolved shardings leaf by leaf) and
uses the remat policy's saved-residual formula for activations.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np
import jax

from repro.configs.base import ArchConfig, ShapeCfg
from repro.distributed import pspec as pspec_lib

HBM_PER_CHIP = 16e9   # TPU v5e


def _sharded_bytes(sds_tree, spec_tree, mesh_sizes: dict[str, int]) -> int:
    """Exact per-device bytes of a sharded ShapeDtypeStruct tree."""
    total = 0

    def one(sds, spec):
        nonlocal total
        shards = 1
        if spec is not None:
            for entry in spec:
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                for a in axes:
                    shards *= mesh_sizes.get(a, 1)
        total += int(np.prod(sds.shape)) * sds.dtype.itemsize // max(shards, 1)

    jax.tree.map(one, sds_tree, spec_tree,
                 is_leaf=lambda x: x is None)
    return total


@dataclasses.dataclass
class MemoryBudget:
    params_bytes: int
    optimizer_bytes: int
    grads_bytes: int
    cache_bytes: int
    activation_bytes: int
    total_bytes: int
    fits: bool

    def as_dict(self) -> dict[str, Any]:
        d = dataclasses.asdict(self)
        d["total_gb"] = self.total_bytes / 1e9
        return d


def activation_estimate(cfg: ArchConfig, shape: ShapeCfg,
                        dp_shards: int, opt_layout: bool = False) -> int:
    """Saved residuals under the per-layer remat policy: ~3 bf16 tensors
    of (B_local, T, D) per layer (block input + attn_out + mlp_out),
    plus one live layer's working set.  Baseline: naive attention
    materialises f32 probs for the live layer.  Opt layout: batch is
    sharded over ALL mesh axes (FSDP-2D), remat is off (~10 saved
    tensors/layer) and blockwise attention bounds the live set to one
    512-wide KV block."""
    if shape.kind == "decode":
        return 0
    B_local = max(shape.global_batch // dp_shards, 1)
    T = shape.seq_len
    per_layer = 10 if opt_layout else 3   # no-remat saves everything
    saved = per_layer * cfg.n_layers * B_local * T * cfg.d_model * 2
    if opt_layout:
        probs = 4 * B_local * cfg.n_heads * T * 512   # one KV block
    else:
        probs = 4 * B_local * cfg.n_heads * min(T, 4096) * T // 16
    return int(saved + probs)


def budget(cfg: ArchConfig, shape: ShapeCfg, mesh_sizes: dict[str, int],
           param_defs, cache_sds=None, cache_specs=None,
           train: bool = True, rules=None, param_dtype=None) -> MemoryBudget:
    opt_layout = rules is not None
    specs = pspec_lib.resolve_specs(param_defs, mesh_sizes, rules)
    params_sds = pspec_lib.abstract_params(param_defs, dtype=param_dtype)
    pbytes = _sharded_bytes(params_sds, specs, mesh_sizes)
    opt = 2 * pbytes if train else 0
    grads = pbytes if train else 0
    cache = 0
    if cache_sds is not None:
        cache = _sharded_bytes(cache_sds, cache_specs, mesh_sizes)
    dp = mesh_sizes.get("pod", 1) * mesh_sizes.get("data", 1)
    if opt_layout and train:
        dp *= mesh_sizes.get("model", 1)   # FSDP-2D: batch on all axes
    act = activation_estimate(cfg, shape, dp, opt_layout) if train else 0
    total = pbytes + opt + grads + cache + act
    return MemoryBudget(
        params_bytes=pbytes, optimizer_bytes=opt, grads_bytes=grads,
        cache_bytes=cache, activation_bytes=act, total_bytes=total,
        fits=total <= HBM_PER_CHIP)

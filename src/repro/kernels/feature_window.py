"""Pallas TPU kernel: windowed stateful feature accumulation.

The data-plane hot loop of SpliDT's Feature Collection & Engineering
phase (paper §3.1.1), adapted to TPU (DESIGN.md §2): instead of
per-packet register scatter, the pipeline delivers flow-major windows
``(B, W, fields)`` and the kernel performs the per-SID operator-selected
register update for a block of flows entirely in VMEM.

Grid: one step per flow block.  Per-flow op/field/pred rows are gathered
from the SID-indexed operator-selection tables *outside* the kernel
(tiny XLA gathers); the kernel does the O(B * W * k) reduction work.

Layout: flow blocks of ``BLOCK_B`` rows; the packet window (W, up to a
few hundred) and the k slots live fully in VMEM
(BLOCK_B * W * 6 * 4B ~= 0.2 MB at BLOCK_B=128, W=64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import features as F
from repro.kernels.dispatch import pad_axis0, round_up
from repro.kernels.ref import ordered_wsum

BLOCK_B = 128


def _packet_mask_val(pkt, pred, field, k):
    """One packet per row: (mask (n, k) bool, val (n, k) f32).

    The per-packet slice of the window kernel's predicate/field logic —
    the same branchless ops, minus the W axis."""
    n = pkt.shape[0]
    valid = pkt[:, F.PKT_VALID] > 0                        # (n,)
    direc = pkt[:, F.PKT_DIR]
    flags = pkt[:, F.PKT_FLAGS].astype(jnp.int32)
    v = valid[:, None]
    mask = v & (pred == F.PRED_TRUE)
    mask |= v & (pred == F.PRED_FWD) & (direc[:, None] == 0)
    mask |= v & (pred == F.PRED_BWD) & (direc[:, None] == 1)
    for code, bit in ((F.PRED_SYN, F.FLAG_SYN), (F.PRED_ACK, F.FLAG_ACK),
                      (F.PRED_FIN, F.FLAG_FIN), (F.PRED_RST, F.FLAG_RST),
                      (F.PRED_PSH, F.FLAG_PSH), (F.PRED_URG, F.FLAG_URG)):
        mask |= v & (pred == code) & ((flags[:, None] & bit) > 0)
    val = jnp.zeros((n, k), jnp.float32)
    for c in range(F.PKT_NFIELDS):
        val = jnp.where(field == c, pkt[:, c][:, None], val)
    return mask, val


def _kernel(pkts_ref, op_ref, field_ref, pred_ref, init_ref, out_ref):
    pkts = pkts_ref[...]                                   # (Bb, W, F)
    op = op_ref[...]                                       # (Bb, k)
    field = field_ref[...]
    pred = pred_ref[...]
    init = init_ref[...]
    Bb, W, _ = pkts.shape
    k = op.shape[1]

    valid = pkts[..., F.PKT_VALID] > 0                     # (Bb, W)
    direc = pkts[..., F.PKT_DIR]
    flags = pkts[..., F.PKT_FLAGS].astype(jnp.int32)

    p = pred[:, None, :]                                   # (Bb, 1, k)
    v = valid[:, :, None]
    mask = v & (p == F.PRED_TRUE)
    mask |= v & (p == F.PRED_FWD) & (direc[:, :, None] == 0)
    mask |= v & (p == F.PRED_BWD) & (direc[:, :, None] == 1)
    for code, bit in ((F.PRED_SYN, F.FLAG_SYN), (F.PRED_ACK, F.FLAG_ACK),
                      (F.PRED_FIN, F.FLAG_FIN), (F.PRED_RST, F.FLAG_RST),
                      (F.PRED_PSH, F.FLAG_PSH), (F.PRED_URG, F.FLAG_URG)):
        mask |= v & (p == code) & ((flags[:, :, None] & bit) > 0)

    fsel = field[:, None, :]
    val = jnp.zeros((Bb, W, k), jnp.float32)
    for c in range(F.PKT_NFIELDS):
        val = jnp.where(fsel == c, pkts[..., c][:, :, None], val)

    mf = mask.astype(jnp.float32)
    # same canonical left-to-right order as the jnp reference, so the
    # kernel's registers are bit-identical to training-time features
    count = ordered_wsum(mf)
    total = ordered_wsum(val * mf)
    sumsq = ordered_wsum(val * val * mf)
    neg_big = jnp.float32(-3.4e38)
    pos_big = jnp.float32(3.4e38)
    mx = jnp.max(jnp.where(mask, val, neg_big), axis=1)
    mx = jnp.where(mx <= neg_big, 0.0, mx)
    mn = jnp.min(jnp.where(mask, val, pos_big), axis=1)
    mn = jnp.where(mn >= pos_big, init, mn)

    pos = jax.lax.broadcasted_iota(jnp.int32, (Bb, W, k), 1)
    first_i = jnp.min(jnp.where(mask, pos, W), axis=1)     # (Bb, k)
    last_i = jnp.max(jnp.where(mask, pos, -1), axis=1)
    # branchless select-at-index: one-hot dot over the window axis
    first = (val * ((pos == first_i[:, None, :]) & mask)).sum(axis=1)
    last = (val * ((pos == last_i[:, None, :]) & mask)).sum(axis=1)

    out = jnp.zeros((Bb, k), jnp.float32)
    out = jnp.where(op == F.OP_COUNT, count, out)
    out = jnp.where(op == F.OP_SUM, total, out)
    out = jnp.where(op == F.OP_MAX, mx, out)
    out = jnp.where(op == F.OP_MIN, mn, out)
    out = jnp.where(op == F.OP_LAST, last, out)
    out = jnp.where(op == F.OP_FIRST, first, out)
    out = jnp.where(op == F.OP_SUMSQ, sumsq, out)
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def feature_window_pallas(
    pkts: jnp.ndarray,        # (B, W, PKT_NFIELDS) f32
    slot_op: jnp.ndarray,     # (B, k) int32 (pre-gathered by SID)
    slot_field: jnp.ndarray,  # (B, k)
    slot_pred: jnp.ndarray,   # (B, k)
    slot_init: jnp.ndarray,   # (B, k) f32
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
) -> jnp.ndarray:
    B, W, nf = pkts.shape
    k = slot_op.shape[1]
    bb = min(block_b, B)
    Bp = round_up(B, bb)
    if Bp != B:
        pkts, slot_op, slot_field, slot_pred, slot_init = (
            pad_axis0(x, Bp)
            for x in (pkts, slot_op, slot_field, slot_pred, slot_init))
    grid = (Bp // bb,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, W, nf), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
            pl.BlockSpec((bb, k), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, k), jnp.float32),
        interpret=interpret,
    )(pkts, slot_op, slot_field, slot_pred, slot_init)
    return out[:B]


# ---------------------------------------------------------------------------
# incremental per-packet update step (flow-table serving)
# ---------------------------------------------------------------------------
#
# The live flow table folds ONE packet at a time into resident per-slot
# window state ``(acc, seen)`` instead of rebuilding the window — see
# ``kernels.ref.feature_update_ref`` (the dense oracle, whose docstring
# carries the bit-identity argument) and docs/PARITY.md.  This kernel
# is the blocked Pallas form of the same fold: the gathered state rows
# and the packet batch live in VMEM; the table-wide scatter
# (gather rows → update → ``.at[slots].set``) happens outside in jnp
# (``feature_update_at``), mirroring how ``dispatch_dt_traverse`` keeps
# the routing in XLA and the arithmetic in the kernel.


def _update_kernel(pkt_ref, op_ref, field_ref, pred_ref, acc_ref, seen_ref,
                   acc_out, seen_out):
    pkt = pkt_ref[...]                                     # (Bb, F)
    op = op_ref[...]                                       # (Bb, k)
    field = field_ref[...]
    pred = pred_ref[...]
    acc = acc_ref[...]
    seen = seen_ref[...]
    k = op.shape[1]

    mask, val = _packet_mask_val(pkt, pred, field, k)
    mf = mask.astype(jnp.float32)
    # identical op-by-op folds to feature_update_ref, so the Pallas and
    # dense paths stay bit-identical packet by packet
    additive = ((op == F.OP_COUNT) | (op == F.OP_SUM) | (op == F.OP_SUMSQ))
    contrib = jnp.where(op == F.OP_COUNT, mf,
                        jnp.where(op == F.OP_SUM, val * mf, val * val * mf))
    out = jnp.where(additive, acc + contrib, acc)
    out = jnp.where((op == F.OP_MAX) & mask, jnp.maximum(acc, val), out)
    out = jnp.where((op == F.OP_MIN) & mask, jnp.minimum(acc, val), out)
    out = jnp.where((op == F.OP_FIRST) & mask & (seen == 0), val, out)
    out = jnp.where((op == F.OP_LAST) & mask, val, out)
    acc_out[...] = out.astype(jnp.float32)
    seen_out[...] = seen | mask.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def feature_update_pallas(
    pkt: jnp.ndarray,         # (B, PKT_NFIELDS) f32, ONE packet per row
    slot_op: jnp.ndarray,     # (B, k) int32 (pre-gathered by SID)
    slot_field: jnp.ndarray,  # (B, k)
    slot_pred: jnp.ndarray,   # (B, k)
    acc: jnp.ndarray,         # (B, k) f32 running window state
    seen: jnp.ndarray,        # (B, k) int32
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fold one packet per row into ``(acc, seen)``; returns new state.

    Padding rows (all-zero packets, valid = 0) pass their state through
    untouched up to signed zero — the same invariant the window kernel
    gives padded packets."""
    B, nf = pkt.shape
    k = slot_op.shape[1]
    bb = min(block_b, B)
    Bp = round_up(B, bb)
    if Bp != B:
        pkt, slot_op, slot_field, slot_pred, acc, seen = (
            pad_axis0(x, Bp)
            for x in (pkt, slot_op, slot_field, slot_pred, acc, seen))
    grid = (Bp // bb,)
    row = pl.BlockSpec((bb, k), lambda i: (i, 0))
    acc2, seen2 = pl.pallas_call(
        _update_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, nf), lambda i: (i, 0)),
                  row, row, row, row, row],
        out_specs=[row, row],
        out_shape=[jax.ShapeDtypeStruct((Bp, k), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, k), jnp.int32)],
        interpret=interpret,
    )(pkt, slot_op, slot_field, slot_pred, acc, seen)
    return acc2[:B], seen2[:B]


def _update_finalize_kernel(pkt_ref, op_ref, field_ref, pred_ref,
                            init_ref, acc_ref, seen_ref,
                            acc_out, seen_out, regs_out):
    """Fused fold + finalize: one VMEM pass per packet-rank.

    The tick engine (``kernels.tick_step``) hops a slot in the same
    dispatch that folded its window-completing packet, so the kernel
    emits the finalized registers alongside the new ``(acc, seen)`` —
    op-by-op identical to ``feature_update_ref`` followed by
    ``feature_finalize_ref``, so the fused path stays bit-identical to
    the two-step fold."""
    pkt = pkt_ref[...]                                     # (Bb, F)
    op = op_ref[...]                                       # (Bb, k)
    field = field_ref[...]
    pred = pred_ref[...]
    init = init_ref[...]
    acc = acc_ref[...]
    seen = seen_ref[...]
    k = op.shape[1]

    mask, val = _packet_mask_val(pkt, pred, field, k)
    mf = mask.astype(jnp.float32)
    additive = ((op == F.OP_COUNT) | (op == F.OP_SUM) | (op == F.OP_SUMSQ))
    contrib = jnp.where(op == F.OP_COUNT, mf,
                        jnp.where(op == F.OP_SUM, val * mf, val * val * mf))
    out = jnp.where(additive, acc + contrib, acc)
    out = jnp.where((op == F.OP_MAX) & mask, jnp.maximum(acc, val), out)
    out = jnp.where((op == F.OP_MIN) & mask, jnp.minimum(acc, val), out)
    out = jnp.where((op == F.OP_FIRST) & mask & (seen == 0), val, out)
    out = jnp.where((op == F.OP_LAST) & mask, val, out)
    out = out.astype(jnp.float32)
    seen2 = seen | mask.astype(jnp.int32)
    # finalize: the empty-window fallbacks of feature_finalize_ref
    empty = seen2 == 0
    regs = jnp.where((op == F.OP_MAX) & empty, 0.0, out)
    regs = jnp.where((op == F.OP_MIN) & empty, init, regs)
    regs = jnp.where(((op == F.OP_FIRST) | (op == F.OP_LAST)) & empty,
                     0.0, regs)
    acc_out[...] = out
    seen_out[...] = seen2
    regs_out[...] = regs.astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def feature_update_finalize_pallas(
    pkt: jnp.ndarray,         # (B, PKT_NFIELDS) f32, ONE packet per row
    slot_op: jnp.ndarray,     # (B, k) int32 (pre-gathered by SID)
    slot_field: jnp.ndarray,  # (B, k)
    slot_pred: jnp.ndarray,   # (B, k)
    slot_init: jnp.ndarray,   # (B, k) f32 (MIN's empty-window fallback)
    acc: jnp.ndarray,         # (B, k) f32 running window state
    seen: jnp.ndarray,        # (B, k) int32
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fold one packet per row AND finalize: ``(acc2, seen2, regs)``.

    ``regs`` equals ``feature_finalize_ref(acc2, seen2, ...)`` bit for
    bit; rows whose window did not complete simply ignore it.  Padding
    rows pass state through untouched up to signed zero, as in
    :func:`feature_update_pallas`."""
    B, nf = pkt.shape
    k = slot_op.shape[1]
    bb = min(block_b, B)
    Bp = round_up(B, bb)
    if Bp != B:
        pkt, slot_op, slot_field, slot_pred, slot_init, acc, seen = (
            pad_axis0(x, Bp)
            for x in (pkt, slot_op, slot_field, slot_pred, slot_init,
                      acc, seen))
    grid = (Bp // bb,)
    row = pl.BlockSpec((bb, k), lambda i: (i, 0))
    acc2, seen2, regs = pl.pallas_call(
        _update_finalize_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bb, nf), lambda i: (i, 0)),
                  row, row, row, row, row, row],
        out_specs=[row, row, row],
        out_shape=[jax.ShapeDtypeStruct((Bp, k), jnp.float32),
                   jax.ShapeDtypeStruct((Bp, k), jnp.int32),
                   jax.ShapeDtypeStruct((Bp, k), jnp.float32)],
        interpret=interpret,
    )(pkt, slot_op, slot_field, slot_pred, slot_init, acc, seen)
    return acc2[:B], seen2[:B], regs[:B]


def feature_update_at(
    acc_tab: jnp.ndarray,     # (N, k) f32 resident state table
    seen_tab: jnp.ndarray,    # (N, k) int32
    slots: jnp.ndarray,       # (n,) int32 UNIQUE row indices into the table
    pkt: jnp.ndarray,         # (n, PKT_NFIELDS)
    slot_op: jnp.ndarray,     # (n, k) — pre-gathered for each slot's SID
    slot_field: jnp.ndarray,
    slot_pred: jnp.ndarray,
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Scatter-update: fold one packet into each addressed table row.

    Gather the state rows, run the Pallas update step, scatter the new
    state back.  ``slots`` must address each real row at most once per
    call (the flow table's rank batches guarantee it); duplicate
    *padding* indices are safe — padded rows compute identical values,
    so the scatter is order-independent."""
    a2, s2 = feature_update_pallas(
        pkt, slot_op, slot_field, slot_pred, acc_tab[slots], seen_tab[slots],
        interpret=interpret, block_b=block_b)
    return acc_tab.at[slots].set(a2), seen_tab.at[slots].set(s2)

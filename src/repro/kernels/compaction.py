"""Early-exit compaction for the recirculation walk.

SpliDT's recirculation overhead is tiny because classification
confidence is front-loaded: most flows exit in the first partitions
(paper §4.4; pForest makes the same observation for multi-phase random
forests).  The dense partition walk ignores that and pays the full
feature-window rebuild + traversal for all B flows at every hop, even
when 95% are already ``done``.

This module compacts the walk between hops while keeping every shape
static (jit-safe), using the same MoE expert-capacity style as
``kernels.dispatch``:

  * ``compact_perm`` — argsort-on-``done`` (stable, so surviving flows
    keep their original relative order) + a prefix count of survivors;
  * ``bucket_caps`` — a fixed ladder of power-of-two capacities
    ``(0, floor, 2*floor, ..., B)`` chosen at trace time;
  * ``compacted_step`` — ``lax.switch`` over the ladder: the branch for
    the smallest capacity that fits the survivor count gathers that
    prefix of flows, runs the backend's per-partition step on the small
    buffer, and scatters actions (and optionally registers) back to the
    original flow slots.

Why a ladder and not the exact survivor count: jit needs static shapes,
so the per-hop buffer size must come from a finite set chosen at trace
time.  The power-of-two ladder bounds the wasted capacity at <2x the
survivor count (bucket ``2^i*floor`` serves counts in
``(2^(i-1)*floor, 2^i*floor]``) while keeping the ``lax.switch`` branch
count at ``log2(B/floor) + 2`` — every branch is compiled once, and the
data-dependent part is just the branch index.  The ``floor`` (default
:data:`COMPACT_FLOOR`, tunable via ``compact_floor=`` /
``repro.tuning``) sets the smallest non-empty bucket: below it the
gather/scatter overhead dominates the step, so finer rungs cannot pay
for themselves.

Correctness does not depend on the bucket choice: a too-large bucket
merely drags some already-``done`` flows through the step, and their
actions are masked out by the walk's ``active`` bookkeeping.  The step
functions are per-flow (no cross-flow reductions), so gathering a
subset produces bit-identical per-flow results — the compacted walk is
bit-identical to the dense walk and to ``PartitionedDT.predict``
(``docs/PARITY.md`` states the full contract).

The capacity-0 branch skips the step entirely, so a batch whose flows
have all exited pays nothing for the remaining hops.

Shape/dtype conventions: ``pkts`` f32 ``(B, W, PKT_NFIELDS)``, ``sid``
int32 ``(B,)``, ``done`` bool ``(B,)``, registers f32 ``(B, k)``,
actions int32 ``(B,)`` with ``-1`` in unvisited slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.ops import StepFn

# Default smallest non-empty bucket.  Matches the Pallas dispatch block
# (kernels.dt_traverse.BLOCK_B): shrinking below one flow block cannot
# reduce the Pallas grid further, and on the dense path the gather /
# scatter overhead dominates the step below ~this size.
COMPACT_FLOOR = 128


def bucket_caps(n_flows: int, floor: int = COMPACT_FLOOR) -> tuple[int, ...]:
    """Static capacity ladder ``(0, floor, 2*floor, ..., n_flows)``.

    Strictly increasing, ends exactly at ``n_flows`` (the full batch is
    always representable, so no survivor count can overflow the ladder);
    the leading 0 is the "everyone exited" fast path.  An empty batch
    gets the degenerate ladder ``(0,)``.
    """
    if n_flows < 0:
        raise ValueError(f"n_flows must be non-negative, got {n_flows}")
    if floor <= 0:
        raise ValueError(f"floor must be positive, got {floor}")
    if n_flows == 0:
        return (0,)
    caps = [0]
    c = floor
    while c < n_flows:
        caps.append(c)
        c *= 2
    caps.append(n_flows)
    return tuple(caps)


def compact_perm(done: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Survivor-first permutation + survivor count.

    ``argsort`` on the ``done`` flags (stable: False < True) moves every
    surviving flow into the prefix while preserving original order; the
    prefix length is ``B - sum(done)``.  Both are device values — no
    host sync, so compaction composes with the fully-jitted walk,
    ``shard_map`` (each shard counts its own survivors) and donation.
    """
    B = done.shape[0]
    perm = jnp.argsort(done, stable=True)
    # splint: allow[R001]: int32 survivor count — exact, order-invariant
    n_active = (B - jnp.sum(done.astype(jnp.int32))).astype(jnp.int32)
    return perm, n_active


def compacted_step(
    pkts: jnp.ndarray,        # (B, W, PKT_NFIELDS) one partition's windows
    sid: jnp.ndarray,         # (B,) int32 active subtree per flow
    done: jnp.ndarray,        # (B,) bool
    dev: ops.DeviceTables,
    *,
    step: StepFn,
    caps: tuple[int, ...],
    with_regs: bool = False,
) -> tuple[jnp.ndarray | None, jnp.ndarray]:
    """Run ``step`` on the compacted survivor prefix only.

    Returns ``(regs, action)`` with full-batch shapes: ``action`` (B,)
    int32 carries ``-1`` in slots the step did not visit (all masked by
    ``done`` downstream), and ``regs`` (B, k) f32 — survivors' registers
    scattered back, zeros elsewhere — or ``None`` when ``with_regs`` is
    False.  Branch selection is data-dependent (`lax.switch`); every
    branch has static shapes, so the whole thing traces into one XLA
    computation.
    """
    B = sid.shape[0]
    k = int(dev.slot_op.shape[1])
    perm, n_active = compact_perm(done)
    idx = jnp.searchsorted(jnp.asarray(caps, jnp.int32), n_active,
                           side="left")

    def make_branch(cap: int):
        def branch(pkts, sid, done, perm):
            if cap == B and B:
                # full rung: nothing (or too little) has exited — run the
                # step dense and skip the gather/scatter round trip (the
                # step is per-flow, so this is bit-identical)
                regs_c, action = step(pkts, sid, dev)
                regs = (jnp.where(done[:, None], 0.0, regs_c)
                        if with_regs else None)
                return (regs, action) if with_regs else (action,)
            action = jnp.full((B,), -1, jnp.int32)
            regs = jnp.zeros((B, k), jnp.float32) if with_regs else None
            if cap > 0:
                take = perm[:cap]
                regs_c, act_c = step(pkts[take], sid[take], dev)
                action = action.at[take].set(act_c)
                if with_regs:
                    # capacity overhang rows (already-done flows dragged
                    # into the bucket) keep zero registers, so the trace
                    # depends only on the survivor set, not the bucket
                    live = (~done[take])[:, None]
                    regs = regs.at[take].set(jnp.where(live, regs_c, 0.0))
            return (regs, action) if with_regs else (action,)
        return branch

    out = jax.lax.switch(idx, [make_branch(c) for c in caps],
                         pkts, sid, done, perm)
    if with_regs:
        return out[0], out[1]
    return None, out[0]

"""Device-resident SID dispatch for the Pallas range-match kernel.

The switch matches each packet against its flow's ACTIVE subtree; the
TPU analogue streams one subtree's tables into VMEM per grid step,
which requires flows grouped into SID-homogeneous blocks.  PR 1 did
that grouping on the host (numpy sort + per-segment copy) — a
device→host round trip per recirculation hop that forced the fused
engine onto dense jnp math.  Here the grouping is pure jnp (argsort +
bincount + searchsorted + scatter), so it jits INTO the fused partition
walk and the whole multi-partition walk stays on device.

Capacity bound (the MoE "expert capacity" trick applied to subtrees):
with B flows and S subtrees, block-aligning every SID segment needs at
most ceil(B / block_b) + S blocks.  Proof sketch: lay the SID-sorted
flows out contiguously and round each SID's segment start up to a block
boundary; segment s then occupies ceil(n_s / block_b) blocks, and
sum_s ceil(n_s / block_b) <= sum_s (n_s / block_b + 1) =
B / block_b + S <= ceil(B / block_b) + S — each SID wastes strictly
less than one block of padding.  The bound depends only on static
shapes (B, S, block_b), so the dispatch has fixed shapes at trace time
and the data-dependent routing lives entirely in device-side
gathers/scatters.  ``block_b`` is a tuning knob (``repro.tuning``):
smaller blocks waste less padding when S is large relative to B,
larger blocks amortise per-block launch cost when B dominates.

This module also owns the padding helpers shared by the streaming
scheduler (`repro.serve.streaming`) and the Pallas block padding
(`repro.kernels.feature_window`): one definition of "pad the leading
axis with zero rows" instead of three.

Shape/dtype conventions: flow registers are f32 ``(B, k)``; SIDs are
int32 ``(B,)`` in ``[0, S)``; actions are int32 ``(B,)`` (``-1`` where
no leaf matched, which the walk treats as "keep the sentinel" — see
``docs/PARITY.md``).  Padded capacity rows carry zero registers and are
never gathered back.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


def round_up(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n`` (ints, m > 0)."""
    return -(-n // m) * m


def pad_axis0(x, target: int):
    """Pad the leading axis with zero rows up to ``target`` (no-op if
    already there).  Zero rows are the pipeline's "invalid" encoding:
    packets with valid=0 contribute to nothing downstream.  Works on
    jnp and numpy arrays alike."""
    n = x.shape[0]
    if n == target:
        return x
    if n > target:
        raise ValueError(f"cannot pad {n} rows down to {target}")
    xp = jnp if isinstance(x, jnp.ndarray) else np
    return xp.pad(x, ((0, target - n),) + ((0, 0),) * (x.ndim - 1))


def capacity_blocks(n_flows: int, n_subtrees: int, block_b: int) -> int:
    """Static worst-case block count for SID-grouping ``n_flows`` flows:
    ceil(B/bb) full blocks of payload plus at most one partial block of
    padding per subtree (see the module docstring for the proof).  Pure
    ints — usable at trace time and by the cost model
    (``repro.tuning.costmodel``), which charges pallas plans for
    exactly this padding."""
    return -(-n_flows // block_b) + n_subtrees


class SidDispatch(NamedTuple):
    """In-jit flow→block routing plan (all device arrays).

    order     (B,)  flow indices sorted by SID (segment-major)
    dest      (B,)  padded-buffer slot of sorted flow i
    block_sid (nb,) SID each capacity block serves (tail blocks past the
                    last used one are clamped to a valid SID; their rows
                    are never gathered back)
    """
    order: jnp.ndarray
    dest: jnp.ndarray
    block_sid: jnp.ndarray


def sid_dispatch(sid: jnp.ndarray, *, n_subtrees: int,
                 block_b: int) -> SidDispatch:
    """Plan the SID grouping entirely in jnp (jit-safe, static shapes).

    ``sid`` (B,) int32 in ``[0, n_subtrees)`` → :class:`SidDispatch`
    (all int32 device arrays; see the class docstring for per-field
    shapes).  Each SID's flows land contiguously at a block-aligned
    offset; the per-block SID map is recovered by binary search over
    the running block count.  Equivalent to the host-side sort+segment
    of PR 1, but traceable — it fuses into the partition-walk scan.
    """
    B = sid.shape[0]
    counts = jnp.bincount(sid, length=n_subtrees)            # (S,)
    bps = -(-counts // block_b)                              # blocks per SID
    # splint: allow[R001]: int32 block offsets — exact, order-invariant
    block_end = jnp.cumsum(bps)
    block_start = block_end - bps
    # splint: allow[R001]: int32 segment offsets — exact, order-invariant
    seg_start = jnp.cumsum(counts) - counts                  # sorted offsets
    order = jnp.argsort(sid, stable=True)
    ssid = sid[order]
    rank = jnp.arange(B, dtype=counts.dtype) - seg_start[ssid]
    dest = block_start[ssid] * block_b + rank
    nb = capacity_blocks(B, n_subtrees, block_b)
    block_sid = jnp.searchsorted(block_end, jnp.arange(nb, dtype=jnp.int32),
                                 side="right")
    block_sid = jnp.minimum(block_sid, n_subtrees - 1).astype(jnp.int32)
    return SidDispatch(order=order, dest=dest, block_sid=block_sid)


def dispatch_dt_traverse(
    regs: jnp.ndarray,         # (B, k) f32 feature registers
    sid: jnp.ndarray,          # (B,) int32 active subtree per flow
    thresholds: jnp.ndarray,   # (S, k, T) f32
    leaf_lo: jnp.ndarray,      # (S, L, k) int32
    leaf_hi: jnp.ndarray,      # (S, L, k) int32
    leaf_action: jnp.ndarray,  # (S, L) int32
    leaf_valid: jnp.ndarray,   # (S, L) int32 (0/1)
    *,
    interpret: bool,
    block_b: int,
) -> jnp.ndarray:
    """SID-grouped Pallas range-match, fully inside jit -> action (B,).

    Scatter flows to capacity-padded SID blocks, run the kernel (one
    subtree's tables per grid step), gather actions back to flow order.
    Padded rows carry zero registers; their actions are computed but
    never read."""
    from repro.kernels.dt_traverse import dt_traverse_pallas

    B, k = regs.shape
    S = int(thresholds.shape[0])
    d = sid_dispatch(sid, n_subtrees=S, block_b=block_b)
    nb = capacity_blocks(B, S, block_b)
    regs_g = jnp.zeros((nb * block_b, k), regs.dtype)
    regs_g = regs_g.at[d.dest].set(regs[d.order])
    out = dt_traverse_pallas(
        d.block_sid, regs_g, thresholds, leaf_lo, leaf_hi, leaf_action,
        leaf_valid, interpret=interpret, block_b=block_b)[:, 0]
    return jnp.zeros((B,), jnp.int32).at[d.order].set(out[d.dest])

"""Pallas TPU kernel: partitioned-subtree range-mark matching.

The Subtree Model Prediction phase (paper §3.1.2) as dense TPU compute.
Rather than pointer-chasing the tree (hostile to the VPU), we execute
the *range-marking* semantics the switch itself uses:

    marks  = #{threshold < register}   per slot     (compare + reduce)
    hit(l) = marks within leaf l's per-slot interval (dense match)
    action = first hit (TCAM priority encode)

Flows are grouped by SID outside the kernel but INSIDE jit
(``repro.kernels.dispatch``: argsort by SID, scatter each segment to a
capacity-padded block offset — MoE-dispatch style) and the grid
prefetches a ``block_sid`` map so each grid step streams ONE subtree's
threshold and leaf tables into VMEM alongside its flow block — the TPU
analogue of the switch activating one subtree's MAT entries per
pipeline pass.

VMEM per step: regs (Bb, k) + thresholds (k, T) + leaf tables (L, k) x2
+ actions (L,) — a few tens of KB at Bb=128, k<=8, T,L<=64.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_B = 128


def _kernel(block_sid_ref, regs_ref, thr_ref, lo_ref, hi_ref, act_ref,
            valid_ref, out_ref):
    del block_sid_ref  # consumed by the index maps
    regs = regs_ref[...]                       # (Bb, k)
    thr = thr_ref[0]                           # (k, T)
    lo = lo_ref[0]                             # (L, k)
    hi = hi_ref[0]                             # (L, k)
    act = act_ref[0]                           # (L,)
    lvalid = valid_ref[0]                      # (L,)

    marks = (regs[:, :, None] > thr[None]).sum(axis=2).astype(jnp.int32)
    m = marks[:, None, :]                      # (Bb, 1, k)
    hit = (m >= lo[None]) & (m <= hi[None])    # (Bb, L, k)
    hit = hit.all(axis=2) & (lvalid[None] > 0)  # (Bb, L)
    Bb, L = hit.shape
    lidx = jax.lax.broadcasted_iota(jnp.int32, (Bb, L), 1)
    first = jnp.min(jnp.where(hit, lidx, L), axis=1)
    sel = (lidx == first[:, None]) & hit
    action = (act[None] * sel).sum(axis=1)
    found = hit.any(axis=1)
    out_ref[...] = jnp.where(found, action, -1)[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret", "block_b"))
def dt_traverse_pallas(
    block_sid: jnp.ndarray,    # (n_blocks,) int32: SID of each flow block
    regs: jnp.ndarray,         # (n_blocks*Bb, k) f32, grouped by SID
    thresholds: jnp.ndarray,   # (S, k, T) f32 (+inf padded)
    leaf_lo: jnp.ndarray,      # (S, L, k) int32
    leaf_hi: jnp.ndarray,      # (S, L, k) int32
    leaf_action: jnp.ndarray,  # (S, L) int32
    leaf_valid: jnp.ndarray,   # (S, L) int32 (0/1)
    *,
    interpret: bool = True,
    block_b: int = BLOCK_B,
) -> jnp.ndarray:
    """Returns action (n_blocks*Bb, 1) int32; -1 where no leaf matched."""
    nb = block_sid.shape[0]
    S, k, T = thresholds.shape
    L = leaf_lo.shape[1]
    bb = block_b
    assert regs.shape[0] == nb * bb, (regs.shape, nb, bb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((bb, k), lambda i, bs: (i, 0)),
            pl.BlockSpec((1, k, T), lambda i, bs: (bs[i], 0, 0)),
            pl.BlockSpec((1, L, k), lambda i, bs: (bs[i], 0, 0)),
            pl.BlockSpec((1, L, k), lambda i, bs: (bs[i], 0, 0)),
            pl.BlockSpec((1, L), lambda i, bs: (bs[i], 0)),
            pl.BlockSpec((1, L), lambda i, bs: (bs[i], 0)),
        ],
        out_specs=pl.BlockSpec((bb, 1), lambda i, bs: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((nb * bb, 1), jnp.int32),
        interpret=interpret,
    )(block_sid, regs, thresholds, leaf_lo, leaf_hi, leaf_action, leaf_valid)

"""Pallas TPU kernel: chunked gated linear recurrence (GLA / SSD family).

Serves RWKV6 (per-channel data-dependent decay + bonus ``u``) and
Mamba2-SSD (scalar decay broadcast over channels), and powers the
``long_500k`` decode path.  This is the LM-side incarnation of SpliDT's
insight (DESIGN.md §2): sequences are processed in *windows* (chunks)
with a bounded carried state that is re-used across windows — intra-chunk
work is dense MXU compute, the inter-chunk state handoff is the
"recirculation".

Recurrence (per head):   S_t = diag(w_t) S_{t-1} + k_t^T v_t
    GLA form:            o_t = q_t S_t
    bonus (RWKV6) form:  o_t = q_t (S_{t-1} + diag(u) k_t^T v_t)

Grid: (batch*heads, T // C).  TPU iterates the chunk axis sequentially,
so the running state lives in a VMEM scratch accumulator across grid
steps (initialised at chunk 0, final state emitted every step — last
write wins).  VMEM per step: 4 chunk blocks (C, d) + state (dk, dv)
(~0.2 MB at C=128, d=128, f32).

Numerics: the intra-chunk ratio trick ``k / exp(cum)`` is clipped at
exp(30); with C=128 this is safe for per-step decay >= exp(-30/128) —
far below any decay RWKV6/Mamba2 parameterisations produce.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_CHUNK = 128


def _kernel(q_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sout_ref,
            state, *, use_bonus: bool):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state[...] = s0_ref[0]

    q = q_ref[0].astype(jnp.float32)            # (C, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)            # (C, dv)
    w = w_ref[0].astype(jnp.float32)            # (C, dk)
    S = state[...]                              # (dk, dv)
    C = q.shape[0]

    logw = jnp.log(jnp.maximum(w, 1e-38))
    # splint: allow[R001]: LM chunk-scan log-decay prefix, not a SpliDT
    # parity surface (no numpy oracle pins its reduction order)
    cum = jnp.cumsum(logw, axis=0)              # (C, dk) inclusive
    total = cum[-1, :]                          # (dk,)

    # centre the log-decay reference at mid-chunk: pairwise products only
    # need DIFFERENCES of cum, so subtracting m halves the exponent range
    # (safe for per-step decay >= exp(-90/C); see module docstring)
    m = cum[C // 2, :]                          # (dk,)
    cum_q = cum - logw if use_bonus else cum
    q_in = q * jnp.exp(jnp.clip(cum_q - m[None, :], -45.0, 45.0))
    k_in = k * jnp.exp(jnp.clip(m[None, :] - cum, -45.0, 45.0))
    att = jax.lax.dot_general(
        q_in, k_in, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    mask = (si < ti) if use_bonus else (si <= ti)
    att = jnp.where(mask, att, 0.0)
    o = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if use_bonus:
        u = u_ref[0].astype(jnp.float32)        # (dk,)
        diag = (q * u[None, :] * k).sum(axis=1)  # (C,)
        o = o + diag[:, None] * v
    # inter-chunk: TRUE decay from chunk start (uncentred; underflow ok)
    q_state = q * jnp.exp(cum_q)
    o = o + jax.lax.dot_general(q_state, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    d_out = jnp.exp(total[None, :] - cum)       # (C, dk)
    new_S = jnp.exp(total)[:, None] * S + jax.lax.dot_general(
        k * d_out, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    state[...] = new_S
    o_ref[0] = o.astype(o_ref.dtype)
    sout_ref[0] = new_S                          # last chunk's write wins


@functools.partial(jax.jit, static_argnames=("chunk", "use_bonus", "interpret"))
def chunk_scan_pallas(
    q: jnp.ndarray,        # (B, T, dk)
    k: jnp.ndarray,        # (B, T, dk)
    v: jnp.ndarray,        # (B, T, dv)
    decay: jnp.ndarray,    # (B, T, dk) in (0, 1]
    bonus: jnp.ndarray,    # (B, dk)  (ignored unless use_bonus)
    state: jnp.ndarray,    # (B, dk, dv) initial state, f32
    *,
    chunk: int = DEFAULT_CHUNK,
    use_bonus: bool = False,
    interpret: bool = True,
):
    """Returns (o (B, T, dv), final_state (B, dk, dv))."""
    B, T, dk = q.shape
    dv = v.shape[-1]
    C = min(chunk, T)
    assert T % C == 0, f"T={T} must be a multiple of chunk={C}"
    nC = T // C
    grid = (B, nC)
    kernel = functools.partial(_kernel, use_bonus=use_bonus)
    o, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, C, dk), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk), lambda b, c: (b, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, C, dv), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, dv), v.dtype),
            jax.ShapeDtypeStruct((B, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pl.tpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
        compiler_params=pl.tpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(q, k, v, decay, bonus, state)
    return o, s_out

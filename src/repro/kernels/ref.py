"""Pure-jnp oracles for every Pallas kernel.

These are the correctness references: simple, obviously-right
implementations with no tiling, used by tests (`assert_allclose` against
the kernels in interpret mode) and as the CPU fallback path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import features as F

# ---------------------------------------------------------------------------
# feature_window: windowed stateful feature accumulation
# ---------------------------------------------------------------------------

_UNROLL_W = 256


def ordered_wsum(x: jnp.ndarray) -> jnp.ndarray:
    """Strict left-to-right f32 sum over the window axis (axis 1).

    The canonical reduction order shared by the offline feature pipeline
    (``window_features``, 41-slot tensor), both engines' k-slot
    reduction, and the Pallas kernel.  A plain ``.sum(axis=1)`` lets XLA
    pick a shape-dependent summation tree, and a last-ulp difference can
    flip a flow sitting exactly on a learned threshold; chaining the
    adds pins the order for every (B, W, k) shape, so training-time
    features and runtime registers agree bit-exactly.
    """
    W = x.shape[1]
    if W <= _UNROLL_W:          # trace-time unroll: W-1 chained adds
        acc = x[:, 0]
        for w in range(1, W):
            acc = acc + x[:, w]
        return acc
    return jax.lax.fori_loop(    # same left-to-right order, rolled
        1, W, lambda w, acc: acc + x[:, w], x[:, 0])


def _pred_mask(pkts: jnp.ndarray, pred: jnp.ndarray) -> jnp.ndarray:
    """pkts (B, W, F), pred (B, k) codes -> (B, W, k) bool."""
    valid = pkts[..., F.PKT_VALID] > 0                      # (B, W)
    direc = pkts[..., F.PKT_DIR]
    flags = pkts[..., F.PKT_FLAGS].astype(jnp.int32)
    p = pred[:, None, :]                                    # (B, 1, k)
    v = valid[:, :, None]
    out = v & (p == F.PRED_TRUE)
    out |= v & (p == F.PRED_FWD) & (direc[:, :, None] == 0)
    out |= v & (p == F.PRED_BWD) & (direc[:, :, None] == 1)
    for code, bit in ((F.PRED_SYN, F.FLAG_SYN), (F.PRED_ACK, F.FLAG_ACK),
                      (F.PRED_FIN, F.FLAG_FIN), (F.PRED_RST, F.FLAG_RST),
                      (F.PRED_PSH, F.FLAG_PSH), (F.PRED_URG, F.FLAG_URG)):
        out |= v & (p == code) & ((flags[:, :, None] & bit) > 0)
    return out


def _field_vals(pkts: jnp.ndarray, field: jnp.ndarray) -> jnp.ndarray:
    """pkts (B, W, F), field (B, k) codes -> (B, W, k) selected field."""
    f = field[:, None, :]
    out = jnp.zeros(pkts.shape[:2] + (field.shape[1],), pkts.dtype)
    for c in range(F.PKT_NFIELDS):
        out = jnp.where(f == c, pkts[..., c][:, :, None], out)
    return out


def feature_window_ref(
    pkts: jnp.ndarray,       # (B, W, PKT_NFIELDS)
    slot_op: jnp.ndarray,    # (B, k) per-flow op codes (pre-gathered by SID)
    slot_field: jnp.ndarray, # (B, k)
    slot_pred: jnp.ndarray,  # (B, k)
    slot_init: jnp.ndarray,  # (B, k)
) -> jnp.ndarray:
    """Branchless windowed register update; returns regs (B, k) f32."""
    mask = _pred_mask(pkts, slot_pred)                       # (B, W, k)
    val = _field_vals(pkts, slot_field)                      # (B, W, k)
    mf = mask.astype(jnp.float32)

    count = ordered_wsum(mf)
    total = ordered_wsum(val * mf)
    sumsq = ordered_wsum(val * val * mf)
    mx = jnp.where(mask, val, -jnp.inf).max(axis=1)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
    mn = jnp.where(mask, val, jnp.inf).min(axis=1)
    mn = jnp.where(jnp.isfinite(mn), mn, slot_init)
    W = pkts.shape[1]
    pos = jnp.arange(W, dtype=jnp.int32)[None, :, None]
    first_i = jnp.where(mask, pos, W).min(axis=1)
    last_i = jnp.where(mask, pos, -1).max(axis=1)
    any_ = mask.any(axis=1)
    first = jnp.where(any_, jnp.take_along_axis(
        val, jnp.minimum(first_i, W - 1)[:, None, :], axis=1)[:, 0, :], 0.0)
    last = jnp.where(any_, jnp.take_along_axis(
        val, jnp.maximum(last_i, 0)[:, None, :], axis=1)[:, 0, :], 0.0)

    op = slot_op
    out = jnp.zeros_like(total)
    out = jnp.where(op == F.OP_COUNT, count, out)
    out = jnp.where(op == F.OP_SUM, total, out)
    out = jnp.where(op == F.OP_MAX, mx, out)
    out = jnp.where(op == F.OP_MIN, mn, out)
    out = jnp.where(op == F.OP_LAST, last, out)
    out = jnp.where(op == F.OP_FIRST, first, out)
    out = jnp.where(op == F.OP_SUMSQ, sumsq, out)
    return out.astype(jnp.float32)


# ---------------------------------------------------------------------------
# feature_update: incremental per-packet window state (flow-table serving)
# ---------------------------------------------------------------------------
#
# The live flow table (repro.serve.flowtable) cannot rebuild a window
# from scratch on every packet, so the window reduction is re-expressed
# as a left fold over arrival order with per-slot state ``(acc, seen)``.
# Bit-identity with :func:`feature_window_ref` (docs/PARITY.md) follows
# from the reduction orders being the SAME chain:
#
#   * COUNT/SUM/SUMSQ: ``ordered_wsum`` is the left-to-right f32 chain
#     ``x0 + x1 + ...``; the fold computes ``0.0 + x0 + x1 + ...`` and
#     skips the trailing padding terms — both differences only map
#     ``-0.0`` to ``+0.0`` (``0.0 + x == x`` for every other f32), and
#     signed zeros compare equal everywhere downstream (thresholds,
#     ``assert_array_equal``);
#   * MAX/MIN are order-independent; the fold carries the same
#     ±inf "empty" sentinel the reference builds via where(mask);
#   * FIRST latches on the first masked packet, LAST overwrites on
#     every masked packet — exactly the reference's index selects;
#   * finalisation reproduces the reference's empty-window fallbacks
#     (MAX→0, MIN→slot_init, FIRST/LAST→0) from the ``seen`` bit.


def feature_state_init(slot_op: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Blank per-slot window state for the incremental fold.

    ``slot_op`` (n, k) op codes -> ``(acc (n, k) f32, seen (n, k)
    int32)``.  MAX/MIN start at the identity of their reduction (∓inf);
    every additive op starts at 0.0 (the same +0.0 the reference
    chain's padding terms produce).
    """
    acc = jnp.where(slot_op == F.OP_MAX, -jnp.inf,
                    jnp.where(slot_op == F.OP_MIN, jnp.inf, 0.0))
    return acc.astype(jnp.float32), jnp.zeros(slot_op.shape, jnp.int32)


def feature_update_ref(
    pkt: jnp.ndarray,        # (n, PKT_NFIELDS) ONE packet per flow/slot
    slot_op: jnp.ndarray,    # (n, k) per-slot op codes (gathered by SID)
    slot_field: jnp.ndarray, # (n, k)
    slot_pred: jnp.ndarray,  # (n, k)
    acc: jnp.ndarray,        # (n, k) f32 running state
    seen: jnp.ndarray,       # (n, k) int32 "any masked packet yet" bit
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fold one packet per row into the running window state.

    Invalid packets (valid = 0 — e.g. padding rows in a batched scatter
    update) leave the state unchanged up to signed zero, exactly like
    the reference chain's masked terms.  Returns the new ``(acc,
    seen)``.
    """
    mask = _pred_mask(pkt[:, None, :], slot_pred)[:, 0]      # (n, k)
    val = _field_vals(pkt[:, None, :], slot_field)[:, 0]     # (n, k)
    mf = mask.astype(jnp.float32)
    op = slot_op
    additive = ((op == F.OP_COUNT) | (op == F.OP_SUM) | (op == F.OP_SUMSQ))
    contrib = jnp.where(op == F.OP_COUNT, mf,
                        jnp.where(op == F.OP_SUM, val * mf, val * val * mf))
    out = jnp.where(additive, acc + contrib, acc)
    out = jnp.where((op == F.OP_MAX) & mask, jnp.maximum(acc, val), out)
    out = jnp.where((op == F.OP_MIN) & mask, jnp.minimum(acc, val), out)
    out = jnp.where((op == F.OP_FIRST) & mask & (seen == 0), val, out)
    out = jnp.where((op == F.OP_LAST) & mask, val, out)
    return out.astype(jnp.float32), seen | mask.astype(jnp.int32)


def feature_finalize_ref(
    acc: jnp.ndarray,        # (n, k) f32 folded state
    seen: jnp.ndarray,       # (n, k) int32
    slot_op: jnp.ndarray,    # (n, k)
    slot_init: jnp.ndarray,  # (n, k) f32 (MIN's empty-window fallback)
) -> jnp.ndarray:
    """Folded state -> registers, bit-identical to the rebuilt window."""
    op = slot_op
    empty = seen == 0
    out = jnp.where((op == F.OP_MAX) & empty, 0.0, acc)
    out = jnp.where((op == F.OP_MIN) & empty, slot_init, out)
    out = jnp.where(((op == F.OP_FIRST) | (op == F.OP_LAST)) & empty,
                    0.0, out)
    return out.astype(jnp.float32)


def feature_update_finalize_ref(pkt, slot_op, slot_field, slot_pred,
                                slot_init, acc, seen):
    """Fold one packet per row AND finalize: ``(acc2, seen2, regs)``.

    The composed oracle for the fused tick-step kernel
    (``kernels.feature_window.feature_update_finalize_pallas``): exactly
    :func:`feature_update_ref` followed by :func:`feature_finalize_ref`
    on the updated state.
    """
    acc2, seen2 = feature_update_ref(pkt, slot_op, slot_field, slot_pred,
                                     acc, seen)
    return acc2, seen2, feature_finalize_ref(acc2, seen2, slot_op, slot_init)


# ---------------------------------------------------------------------------
# dt_traverse: range-mark matching (grouped by SID outside the kernel)
# ---------------------------------------------------------------------------


def dt_traverse_ref(
    regs: jnp.ndarray,        # (B, k) feature registers
    thresholds: jnp.ndarray,  # (B, k, T) per-flow subtree thresholds (+inf pad)
    leaf_lo: jnp.ndarray,     # (B, L, k)
    leaf_hi: jnp.ndarray,     # (B, L, k)
    leaf_action: jnp.ndarray, # (B, L) int32, -1 padding
    leaf_valid: jnp.ndarray,  # (B, L) bool
) -> jnp.ndarray:
    """Range-marking execution; returns action (B,) int32."""
    marks = (regs[:, :, None] > thresholds).sum(axis=2).astype(jnp.int32)  # (B,k)
    m = marks[:, None, :]                                    # (B, 1, k)
    hit = (m >= leaf_lo) & (m <= leaf_hi)                    # (B, L, k)
    hit = hit.all(axis=2) & leaf_valid                       # (B, L)
    L = hit.shape[1]
    first = jnp.where(hit, jnp.arange(L, dtype=jnp.int32)[None, :],
                      L).min(axis=1)
    safe = jnp.minimum(first, L - 1)
    action = jnp.take_along_axis(leaf_action, safe[:, None], axis=1)[:, 0]
    return jnp.where(first < L, action, -1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# chunk_scan: gated linear recurrence (RWKV6 / Mamba2-SSD family)
# ---------------------------------------------------------------------------


def chunk_scan_ref(
    q: jnp.ndarray,      # (B, T, dk)
    k: jnp.ndarray,      # (B, T, dk)
    v: jnp.ndarray,      # (B, T, dv)
    decay: jnp.ndarray,  # (B, T, dk) in (0, 1]; per-channel data-dependent
    bonus: jnp.ndarray | None = None,   # (B, dk) RWKV6 "u" or None
    state: jnp.ndarray | None = None,   # (B, dk, dv) initial state
):
    """Naive per-token recurrence (the oracle).

        S_t = diag(decay_t) S_{t-1} + k_t^T v_t
        o_t = q_t (S_{t-1} + diag(bonus) k_t^T v_t)   [RWKV6 bonus form]
    With bonus=None: o_t = q_t S_t (GLA/SSD form).

    Returns (o (B, T, dv), final_state (B, dk, dv)).
    """
    B, T, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, dk, dv), jnp.float32)

    def step(S, xs):
        qt, kt, vt, wt = xs
        kv = kt[:, :, None] * vt[:, None, :]                 # (B, dk, dv)
        if bonus is not None:
            o = jnp.einsum("bk,bkv->bv", qt, S + bonus[:, :, None] * kv)
            S = wt[:, :, None] * S + kv
        else:
            S = wt[:, :, None] * S + kv
            o = jnp.einsum("bk,bkv->bv", qt, S)
        return S, o

    xs = (q.transpose(1, 0, 2), k.transpose(1, 0, 2),
          v.transpose(1, 0, 2), decay.transpose(1, 0, 2))
    final, o = jax.lax.scan(step, state.astype(jnp.float32), xs)
    return o.transpose(1, 0, 2).astype(v.dtype), final


def chunk_scan_chunked_ref(q, k, v, decay, bonus=None, state=None, chunk: int = 64):
    """Chunked (parallel-within-chunk) formulation in plain jnp.

    Mathematically identical to :func:`chunk_scan_ref`; this mirrors the
    Pallas kernel's blocking so tests can separate "chunking math wrong"
    from "kernel plumbing wrong".  SpliDT connection: the chunk is the
    window, the carried state is the reused register set (DESIGN.md §2).
    """
    B, T, dk = q.shape
    dv = v.shape[-1]
    assert T % chunk == 0, "pad T to a chunk multiple"
    nC = T // chunk
    if state is None:
        state = jnp.zeros((B, dk, dv), jnp.float32)
    qc = q.reshape(B, nC, chunk, dk).astype(jnp.float32)
    kc = k.reshape(B, nC, chunk, dk).astype(jnp.float32)
    vc = v.reshape(B, nC, chunk, dv).astype(jnp.float32)
    wc = decay.reshape(B, nC, chunk, dk).astype(jnp.float32)

    logw = jnp.log(jnp.maximum(wc, 1e-38))
    # splint: allow[R001]: LM chunk-scan reference, not a SpliDT parity
    # surface (kernel parity is vs this ref, not a numpy oracle)
    cum = jnp.cumsum(logw, axis=2)                # inclusive cumulative log-decay
    total = cum[:, :, -1, :]                      # (B, nC, dk)

    def chunk_step(S, xs):
        qi, ki, vi, logwi, cumi, totali = xs      # (B, chunk, ...)
        # GLA form: kv_s reaches o_t with decay prod_{r=s+1..t} w_r (incl. w_t)
        # bonus form: o_t reads S_{t-1}, so the product excludes w_t
        cum_q = cumi if bonus is None else cumi - logwi
        # mid-chunk-centred reference halves the exponent dynamic range
        # (pairwise products only need differences of cum)
        mref = cumi[:, chunk // 2, :][:, None, :]
        q_in = qi * jnp.exp(jnp.clip(cum_q - mref, -45.0, 45.0))
        k_in = ki * jnp.exp(jnp.clip(mref - cumi, -45.0, 45.0))
        # keys folded into state need decay from s+1 .. end-of-chunk
        d_out = jnp.exp(totali[:, None, :] - cumi)
        att = jnp.einsum("btk,bsk->bts", q_in, k_in)
        if bonus is None:
            mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        else:
            mask = jnp.tril(jnp.ones((chunk, chunk), bool), -1)  # strictly causal
        att = jnp.where(mask[None], att, 0.0)
        o_intra = jnp.einsum("bts,bsv->btv", att, vi)
        if bonus is not None:
            diag = jnp.einsum("btk,bk,btk->bt", qi, bonus, ki)
            o_intra = o_intra + diag[:, :, None] * vi
        # inter-chunk reads the carried state with the TRUE decay from
        # chunk start (uncentred; underflow to 0 is the correct limit)
        o_inter = jnp.einsum("btk,bkv->btv", qi * jnp.exp(cum_q), S)
        S = jnp.exp(totali)[:, :, None] * S + jnp.einsum(
            "btk,btv->bkv", ki * d_out, vi)
        return S, o_intra + o_inter

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (qc, kc, vc, logw, cum)) + (
        total.transpose(1, 0, 2),)
    final, o = jax.lax.scan(chunk_step, state.astype(jnp.float32), xs)
    o = o.transpose(1, 0, 2, 3).reshape(B, T, dv)
    return o.astype(v.dtype), final

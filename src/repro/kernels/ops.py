"""Jit'd public wrappers around the Pallas kernels.

Each op dispatches between three implementations:
  * ``ref``     — pure-jnp oracle (CPU default; always correct)
  * ``pallas``  — the Pallas kernel, ``interpret=True`` off-TPU
  * ``auto``    — pallas on TPU, ref elsewhere

All marshalling is device-resident: per-SID operator rows are gathered
in-jit (feature_window), and dt_traverse groups flows by SID into
padded blocks via ``repro.kernels.dispatch`` (the MAT "match on SID"
stage) — pure jnp, so both the per-op entry points and the fused
partition-walk steps trace into a single XLA computation.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.range_tables import RangeExecTables
from repro.core.tables import PackedTables
from repro.kernels import ref as _ref
from repro.kernels.chunk_scan import chunk_scan_pallas
from repro.kernels.dispatch import dispatch_dt_traverse
from repro.kernels.dt_traverse import BLOCK_B
from repro.kernels.feature_window import feature_window_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# ---------------------------------------------------------------------------
# device tables — the jit-resident form of the MAT programs
# ---------------------------------------------------------------------------
class DeviceTables(NamedTuple):
    """All MAT contents as device arrays, indexable by SID inside jit.

    This is the fused engine's working set: operator-selection rows
    (``slot_*``) and range-execution tables (``thresholds`` / ``leaf_*``)
    live on device for the whole partition walk, so the only host<->device
    traffic per batch is the packet windows in and the verdicts out.
    NamedTuple => a pytree, so it passes straight through ``jax.jit``.
    """
    slot_op: jnp.ndarray      # (S, k) int32
    slot_field: jnp.ndarray   # (S, k) int32
    slot_pred: jnp.ndarray    # (S, k) int32
    slot_init: jnp.ndarray    # (S, k) f32
    thresholds: jnp.ndarray   # (S, k, T) f32, +inf padded
    leaf_lo: jnp.ndarray      # (S, L, k) int32
    leaf_hi: jnp.ndarray      # (S, L, k) int32
    leaf_action: jnp.ndarray  # (S, L) int32, -1 padding
    leaf_valid: jnp.ndarray   # (S, L) int32 (0/1)


def device_tables(tables: PackedTables, ret: RangeExecTables) -> DeviceTables:
    """Upload the packed host tables once; reuse across every batch."""
    return DeviceTables(
        slot_op=jnp.asarray(tables.slot_op),
        slot_field=jnp.asarray(tables.slot_field),
        slot_pred=jnp.asarray(tables.slot_pred),
        slot_init=jnp.asarray(tables.slot_init),
        thresholds=jnp.asarray(ret.thresholds),
        leaf_lo=jnp.asarray(ret.leaf_lo),
        leaf_hi=jnp.asarray(ret.leaf_hi),
        leaf_action=jnp.asarray(ret.leaf_action),
        leaf_valid=jnp.asarray(ret.leaf_valid.astype(np.int32)),
    )


# one partition stage: (pkts (B, W, F), sid (B,), dev) ->
# (regs (B, k), action (B,)) — the contract shared by the engine's walk
# backends (core.inference) and the compaction gather (kernels.compaction)
StepFn = Callable[[jnp.ndarray, jnp.ndarray, DeviceTables],
                  tuple[jnp.ndarray, jnp.ndarray]]


def fused_step(
    pkts: jnp.ndarray,        # (B, W, PKT_NFIELDS) one partition's windows
    sid: jnp.ndarray,         # (B,) int32 active subtree per flow
    dev: DeviceTables,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One partition stage, fully traceable: registers then action.

    Both phases are the pure-jnp reference math (dense per-flow gathers
    of the SID-keyed tables), so the whole thing jits into one XLA
    computation — no host-side grouping, no numpy round-trip.  Returns
    ``(regs (B, k) f32, action (B,) int32)``.
    """
    regs = _ref.feature_window_ref(
        pkts, dev.slot_op[sid], dev.slot_field[sid], dev.slot_pred[sid],
        dev.slot_init[sid])
    action = _ref.dt_traverse_ref(
        regs, dev.thresholds[sid], dev.leaf_lo[sid], dev.leaf_hi[sid],
        dev.leaf_action[sid], dev.leaf_valid[sid] > 0)
    return regs, action


def fused_step_pallas(
    pkts: jnp.ndarray,        # (B, W, PKT_NFIELDS) one partition's windows
    sid: jnp.ndarray,         # (B,) int32 active subtree per flow
    dev: DeviceTables,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One partition stage through the Pallas kernels, fully traceable.

    Same contract as :func:`fused_step`, but the register fill runs the
    blocked Pallas feature kernel and the range match runs the SID-
    grouped Pallas kernel behind the in-jit dispatch — no host-side
    grouping, so the whole partition walk jits into one computation
    (``interpret=True`` off-TPU keeps it runnable anywhere).

    This is :func:`pallas_step` at the default ``block_b``; the tuner
    resolves other block sizes through the factory.
    """
    return _pallas_step_impl(pkts, sid, dev, BLOCK_B)


def _pallas_step_impl(pkts, sid, dev, block_b):
    interpret = not _on_tpu()
    regs = feature_window_pallas(
        pkts, dev.slot_op[sid], dev.slot_field[sid], dev.slot_pred[sid],
        dev.slot_init[sid], interpret=interpret, block_b=block_b)
    action = dispatch_dt_traverse(
        regs, sid, dev.thresholds, dev.leaf_lo, dev.leaf_hi,
        dev.leaf_action, dev.leaf_valid,
        interpret=interpret, block_b=block_b)
    return regs, action


@functools.lru_cache(maxsize=None)
def pallas_step(block_b: int = BLOCK_B) -> StepFn:
    """Pallas partition stage with ``block_b`` as a tunable parameter.

    ``block_b`` sets both the feature kernel's flow-block rows and the
    SID dispatch's capacity-block size (``ceil(B/block_b) + S`` blocks
    worst case — smaller blocks waste less padding at small B / large
    S, larger ones amortise per-block launch cost).  Cached so each
    ``block_b`` maps to ONE function object: jit and the streaming
    scheduler's ``lru_cache`` both key on step identity, so reusing the
    object reuses every downstream compilation.
    """
    if block_b <= 0:
        raise ValueError(f"block_b must be positive, got {block_b}")
    if block_b == BLOCK_B:
        return fused_step_pallas

    def step(pkts: jnp.ndarray, sid: jnp.ndarray, dev: DeviceTables):
        return _pallas_step_impl(pkts, sid, dev, block_b)

    step.__name__ = step.__qualname__ = f"fused_step_pallas_bb{block_b}"
    step.__doc__ = f"fused_step_pallas with block_b={block_b}."
    return step


# ---------------------------------------------------------------------------
# feature_window
# ---------------------------------------------------------------------------
def feature_window(
    pkts: jnp.ndarray,          # (B, W, PKT_NFIELDS)
    sid: jnp.ndarray,           # (B,) int32
    tables: PackedTables,
    *,
    impl: str = "auto",
) -> jnp.ndarray:
    """Compute the k feature registers for each flow's active subtree."""
    impl = _resolve(impl)
    op = jnp.asarray(tables.slot_op)[sid]        # (B, k) — MAT keyed on SID
    field = jnp.asarray(tables.slot_field)[sid]
    pred = jnp.asarray(tables.slot_pred)[sid]
    init = jnp.asarray(tables.slot_init)[sid]
    if impl == "ref":
        return _ref.feature_window_ref(pkts, op, field, pred, init)
    return feature_window_pallas(pkts, op, field, pred, init,
                                 interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# dt_traverse
# ---------------------------------------------------------------------------
def dt_traverse(
    regs: jnp.ndarray,          # (B, k)
    sid: jnp.ndarray,           # (B,) int32
    ret: RangeExecTables,
    *,
    impl: str = "auto",
    block_b: int = BLOCK_B,
) -> jnp.ndarray:
    """Range-mark match each flow against its active subtree -> action (B,)."""
    impl = _resolve(impl)
    thr = jnp.asarray(ret.thresholds)
    lo = jnp.asarray(ret.leaf_lo)
    hi = jnp.asarray(ret.leaf_hi)
    act = jnp.asarray(ret.leaf_action)
    val = jnp.asarray(ret.leaf_valid.astype(np.int32))
    if impl == "ref":
        return _ref.dt_traverse_ref(regs, thr[sid], lo[sid], hi[sid],
                                    act[sid], val[sid] > 0)
    # SID grouping runs in-jit (MoE-dispatch style) — no host round trip
    return dispatch_dt_traverse(regs, sid, thr, lo, hi, act, val,
                                interpret=not _on_tpu(), block_b=block_b)


# ---------------------------------------------------------------------------
# chunk_scan
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def _chunk_scan_jit(q, k, v, decay, bonus, state, impl, chunk):
    use_bonus = bonus is not None
    if impl == "ref":
        return _ref.chunk_scan_chunked_ref(q, k, v, decay, bonus, state,
                                           chunk=min(chunk, q.shape[1]))
    b = bonus if use_bonus else jnp.zeros((q.shape[0], q.shape[2]), jnp.float32)
    return chunk_scan_pallas(q, k, v, decay, b, state, chunk=chunk,
                             use_bonus=use_bonus, interpret=not _on_tpu())


def chunk_scan(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    decay: jnp.ndarray,
    bonus: jnp.ndarray | None = None,
    state: jnp.ndarray | None = None,
    *,
    chunk: int = 128,
    impl: str = "auto",
):
    """Gated linear recurrence over (B, T, d) inputs; see chunk_scan.py."""
    impl = _resolve(impl)
    if state is None:
        state = jnp.zeros((q.shape[0], q.shape[2], v.shape[2]), jnp.float32)
    if q.shape[1] % min(chunk, q.shape[1]) != 0:
        # pad T to a chunk multiple with zero decay-neutral steps
        T = q.shape[1]
        pad = (-T) % chunk if T > chunk else 0
        if pad:
            zq = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            o, s = _chunk_scan_jit(zq(q), zq(k), zq(v),
                                   jnp.pad(decay, ((0, 0), (0, pad), (0, 0)),
                                           constant_values=1.0),
                                   bonus, state, impl, chunk)
            return o[:, :T], s
    return _chunk_scan_jit(q, k, v, decay, bonus, state, impl, chunk)

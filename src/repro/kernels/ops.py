"""Jit'd public wrappers around the Pallas kernels.

Each op dispatches between three implementations:
  * ``ref``     — pure-jnp oracle (CPU default; always correct)
  * ``pallas``  — the Pallas kernel, ``interpret=True`` off-TPU
  * ``auto``    — pallas on TPU, ref elsewhere

The wrappers also own the host-side data marshalling the switch pipeline
would do in hardware: gathering per-SID operator rows (feature_window)
and grouping flows by SID into padded blocks (dt_traverse — the MAT
"match on SID" stage).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.range_tables import RangeExecTables
from repro.core.tables import PackedTables
from repro.kernels import ref as _ref
from repro.kernels.chunk_scan import chunk_scan_pallas
from repro.kernels.dt_traverse import BLOCK_B, dt_traverse_pallas
from repro.kernels.feature_window import feature_window_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# ---------------------------------------------------------------------------
# device tables — the jit-resident form of the MAT programs
# ---------------------------------------------------------------------------
class DeviceTables(NamedTuple):
    """All MAT contents as device arrays, indexable by SID inside jit.

    This is the fused engine's working set: operator-selection rows
    (``slot_*``) and range-execution tables (``thresholds`` / ``leaf_*``)
    live on device for the whole partition walk, so the only host<->device
    traffic per batch is the packet windows in and the verdicts out.
    NamedTuple => a pytree, so it passes straight through ``jax.jit``.
    """
    slot_op: jnp.ndarray      # (S, k) int32
    slot_field: jnp.ndarray   # (S, k) int32
    slot_pred: jnp.ndarray    # (S, k) int32
    slot_init: jnp.ndarray    # (S, k) f32
    thresholds: jnp.ndarray   # (S, k, T) f32, +inf padded
    leaf_lo: jnp.ndarray      # (S, L, k) int32
    leaf_hi: jnp.ndarray      # (S, L, k) int32
    leaf_action: jnp.ndarray  # (S, L) int32, -1 padding
    leaf_valid: jnp.ndarray   # (S, L) int32 (0/1)


def device_tables(tables: PackedTables, ret: RangeExecTables) -> DeviceTables:
    """Upload the packed host tables once; reuse across every batch."""
    return DeviceTables(
        slot_op=jnp.asarray(tables.slot_op),
        slot_field=jnp.asarray(tables.slot_field),
        slot_pred=jnp.asarray(tables.slot_pred),
        slot_init=jnp.asarray(tables.slot_init),
        thresholds=jnp.asarray(ret.thresholds),
        leaf_lo=jnp.asarray(ret.leaf_lo),
        leaf_hi=jnp.asarray(ret.leaf_hi),
        leaf_action=jnp.asarray(ret.leaf_action),
        leaf_valid=jnp.asarray(ret.leaf_valid.astype(np.int32)),
    )


def fused_step(
    pkts: jnp.ndarray,        # (B, W, PKT_NFIELDS) one partition's windows
    sid: jnp.ndarray,         # (B,) int32 active subtree per flow
    dev: DeviceTables,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One partition stage, fully traceable: registers then action.

    Both phases are the pure-jnp reference math (dense per-flow gathers
    of the SID-keyed tables), so the whole thing jits into one XLA
    computation — no host-side grouping, no numpy round-trip.  Returns
    ``(regs (B, k) f32, action (B,) int32)``.
    """
    regs = _ref.feature_window_ref(
        pkts, dev.slot_op[sid], dev.slot_field[sid], dev.slot_pred[sid],
        dev.slot_init[sid])
    action = _ref.dt_traverse_ref(
        regs, dev.thresholds[sid], dev.leaf_lo[sid], dev.leaf_hi[sid],
        dev.leaf_action[sid], dev.leaf_valid[sid] > 0)
    return regs, action


# ---------------------------------------------------------------------------
# feature_window
# ---------------------------------------------------------------------------
def feature_window(
    pkts: jnp.ndarray,          # (B, W, PKT_NFIELDS)
    sid: jnp.ndarray,           # (B,) int32
    tables: PackedTables,
    *,
    impl: str = "auto",
) -> jnp.ndarray:
    """Compute the k feature registers for each flow's active subtree."""
    impl = _resolve(impl)
    op = jnp.asarray(tables.slot_op)[sid]        # (B, k) — MAT keyed on SID
    field = jnp.asarray(tables.slot_field)[sid]
    pred = jnp.asarray(tables.slot_pred)[sid]
    init = jnp.asarray(tables.slot_init)[sid]
    if impl == "ref":
        return _ref.feature_window_ref(pkts, op, field, pred, init)
    return feature_window_pallas(pkts, op, field, pred, init,
                                 interpret=not _on_tpu())


# ---------------------------------------------------------------------------
# dt_traverse
# ---------------------------------------------------------------------------
def dt_traverse(
    regs: jnp.ndarray,          # (B, k)
    sid: jnp.ndarray,           # (B,) int32
    ret: RangeExecTables,
    *,
    impl: str = "auto",
    block_b: int = BLOCK_B,
) -> jnp.ndarray:
    """Range-mark match each flow against its active subtree -> action (B,)."""
    impl = _resolve(impl)
    thr = jnp.asarray(ret.thresholds)
    lo = jnp.asarray(ret.leaf_lo)
    hi = jnp.asarray(ret.leaf_hi)
    act = jnp.asarray(ret.leaf_action)
    val = jnp.asarray(ret.leaf_valid.astype(np.int32))
    if impl == "ref":
        return _ref.dt_traverse_ref(regs, thr[sid], lo[sid], hi[sid],
                                    act[sid], val[sid] > 0)

    # group flows by SID into padded blocks (MoE-dispatch style)
    sid_np = np.asarray(sid)
    B = sid_np.shape[0]
    order = np.argsort(sid_np, kind="stable")
    sids, counts = np.unique(sid_np, return_counts=True)
    blocks_per_sid = [-(-int(c) // block_b) for c in counts]
    nb = int(sum(blocks_per_sid))
    padded = nb * block_b
    # scatter each SID segment to a block-aligned offset
    perm_dst = np.zeros(B, dtype=np.int64)
    block_sid = np.zeros(nb, dtype=np.int32)
    off = blk = 0
    src = 0
    for s, c, nbl in zip(sids, counts, blocks_per_sid):
        perm_dst[src:src + c] = np.arange(c) + off
        block_sid[blk:blk + nbl] = s
        off += nbl * block_b
        blk += nbl
        src += c
    regs_g = jnp.zeros((padded, regs.shape[1]), regs.dtype)
    regs_g = regs_g.at[jnp.asarray(perm_dst)].set(regs[jnp.asarray(order)])
    out = dt_traverse_pallas(
        jnp.asarray(block_sid), regs_g, thr, lo, hi, act, val,
        interpret=not _on_tpu(), block_b=block_b)[:, 0]
    # un-permute
    result = jnp.zeros((B,), jnp.int32)
    return result.at[jnp.asarray(order)].set(out[jnp.asarray(perm_dst)])


# ---------------------------------------------------------------------------
# chunk_scan
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("impl", "chunk"))
def _chunk_scan_jit(q, k, v, decay, bonus, state, impl, chunk):
    use_bonus = bonus is not None
    if impl == "ref":
        return _ref.chunk_scan_chunked_ref(q, k, v, decay, bonus, state,
                                           chunk=min(chunk, q.shape[1]))
    b = bonus if use_bonus else jnp.zeros((q.shape[0], q.shape[2]), jnp.float32)
    return chunk_scan_pallas(q, k, v, decay, b, state, chunk=chunk,
                             use_bonus=use_bonus, interpret=not _on_tpu())


def chunk_scan(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    decay: jnp.ndarray,
    bonus: jnp.ndarray | None = None,
    state: jnp.ndarray | None = None,
    *,
    chunk: int = 128,
    impl: str = "auto",
):
    """Gated linear recurrence over (B, T, d) inputs; see chunk_scan.py."""
    impl = _resolve(impl)
    if state is None:
        state = jnp.zeros((q.shape[0], q.shape[2], v.shape[2]), jnp.float32)
    if q.shape[1] % min(chunk, q.shape[1]) != 0:
        # pad T to a chunk multiple with zero decay-neutral steps
        T = q.shape[1]
        C = min(chunk, T) if T >= chunk else T
        pad = (-T) % chunk if T > chunk else 0
        if pad:
            zq = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
            o, s = _chunk_scan_jit(zq(q), zq(k), zq(v),
                                   jnp.pad(decay, ((0, 0), (0, pad), (0, 0)),
                                           constant_values=1.0),
                                   bonus, state, impl, chunk)
            return o[:, :T], s
    return _chunk_scan_jit(q, k, v, decay, bonus, state, impl, chunk)

"""Device-resident tick engine: one dispatch per serving tick.

The live flow table (``repro.serve.flowtable``) used to pay one jitted
dispatch per packet *rank* (the r-th packet of each flow in a tick) plus
one per hop-drain round, with host round trips in between — on a CPU
host the ~0.5 ms dispatch overhead dominated end-to-end serving latency
(see ``tuning.costmodel.DEFAULT_COEFFS``).  This module folds the whole
per-tick pipeline into ONE jitted call:

  * **state** (:class:`TickState`) is a device-resident pytree holding
    the per-slot window registers AND the per-flow walk metadata that
    used to live in host numpy arrays (``sid``, ``part``, ``win_lo`` /
    ``win_hi``, ``pkts_seen``, ``recircs``) plus a ``retired`` flag and
    the per-flow window ``bounds`` table.  Row ``N`` (one past the table
    capacity) is the dummy row every padded or masked scatter lands on;
  * **admission** (:func:`admit_rows`) re-initialises newly admitted
    slots in one scatter, computing ``flows.windows.window_bounds`` with
    in-jit int32 math (bit-for-bit the host formula);
  * **the tick step** (:func:`tick_step`) runs the rank loop as a
    ``lax.scan`` over the tick's rank-major ``(R, C)`` slot/packet
    arrays.  Each rank folds one packet per slot (the incremental
    update of ``kernels.ref.feature_update_ref`` or the fused Pallas
    fold+finalize kernel), then hops every slot whose window completed:
    finalize → subtree traverse → the walk's own
    ``core.inference._hop_update`` bookkeeping.  Empty trailing windows
    (flows shorter than P packets) drain inside an in-jit bounded
    ``lax.while_loop`` — the partition index strictly advances every
    round, so ``P`` is a static trip bound;
  * **verdicts** accumulate into per-slot device buffers; the server
    issues one bulk ``device_get`` per tick and frees the finished
    slots host-side.

Parity (docs/PARITY.md §5): every per-row computation here is the same
row-wise kernel math the legacy per-rank path dispatched — gathers and
masks route rows, they never change values — so fused-tick verdicts are
bit-identical to the host-looped path and to ``Engine.run`` on rebuilt
windows.  Masked rows (padding, retired flows, already-hopped slots)
are routed to the dummy row with invalidated packets; every dummy
duplicate computes identical values, so the scatters stay
deterministic.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.features import PKT_IAT
from repro.core.inference import _hop_update
from repro.kernels import ops
from repro.kernels import ref as _ref
from repro.kernels.dispatch import dispatch_dt_traverse
from repro.kernels.feature_window import feature_update_finalize_pallas


class TickState(NamedTuple):
    """Device-resident per-slot serving state (``N + 1`` rows).

    The last row is the dummy row: padded rank entries, retired flows,
    and non-hopping slots are all routed there so every device op keeps
    a static shape.  ``bounds`` caches each flow's per-partition window
    ``[lo, hi)`` so hops never need the host.
    """
    acc: jnp.ndarray        # (N+1, k) f32 running window registers
    seen: jnp.ndarray       # (N+1, k) int32 "matched yet" bits
    sid: jnp.ndarray        # (N+1,) int32 active subtree id
    part: jnp.ndarray       # (N+1,) int32 active partition index
    win_lo: jnp.ndarray     # (N+1,) int32 active window start (packets)
    win_hi: jnp.ndarray     # (N+1,) int32 active window end
    pkts_seen: jnp.ndarray  # (N+1,) int32 packets folded so far
    recircs: jnp.ndarray    # (N+1,) int32 partition transitions
    retired: jnp.ndarray    # (N+1,) int32 1 = verdict emitted this epoch
    bounds: jnp.ndarray     # (N+1, P, 2) int32 per-partition windows


@functools.partial(jax.jit, static_argnames=("n", "n_partitions"))
def init_tick_state(dev: ops.DeviceTables, n: int,
                    n_partitions: int) -> TickState:
    """Blank state for ``n`` rows (capacity + dummy), root SID 0."""
    op = jnp.broadcast_to(dev.slot_op[0][None, :], (n, dev.slot_op.shape[1]))
    acc, seen = _ref.feature_state_init(op)
    z = jnp.zeros(n, jnp.int32)
    return TickState(acc, seen, z, z, z, z, z, z, z,
                     jnp.zeros((n, n_partitions, 2), jnp.int32))


@jax.jit
def admit_rows(state: TickState, slots: jnp.ndarray,
               lengths: jnp.ndarray, dev: ops.DeviceTables) -> TickState:
    """Re-initialise newly admitted slots in one scatter.

    ``slots`` (m,) int32 row indices (dummy-padded; real entries are
    unique), ``lengths`` (m,) int32 flow lengths (padding rows carry 1,
    so every dummy duplicate computes identical values).  The window
    bounds replicate ``flows.windows.window_bounds`` in int32 — same
    floor-div/min formula, so the device plan is bit-identical to the
    host's.
    """
    P = state.bounds.shape[1]
    length = jnp.maximum(lengths.astype(jnp.int32), 1)
    base = jnp.maximum(length // P, 1)
    w = jnp.arange(P, dtype=jnp.int32)[None, :]
    lo = jnp.minimum(w * base[:, None], length[:, None])
    hi = jnp.minimum((w + 1) * base[:, None], length[:, None])
    hi = hi.at[:, P - 1].set(length)
    k = dev.slot_op.shape[1]
    a0, s0 = _ref.feature_state_init(
        jnp.broadcast_to(dev.slot_op[0][None, :], (slots.shape[0], k)))
    z = jnp.zeros(slots.shape[0], jnp.int32)
    return TickState(
        acc=state.acc.at[slots].set(a0),
        seen=state.seen.at[slots].set(s0),
        sid=state.sid.at[slots].set(z),
        part=state.part.at[slots].set(z),
        win_lo=state.win_lo.at[slots].set(lo[:, 0]),
        win_hi=state.win_hi.at[slots].set(hi[:, 0]),
        pkts_seen=state.pkts_seen.at[slots].set(z),
        recircs=state.recircs.at[slots].set(z),
        retired=state.retired.at[slots].set(z),
        bounds=state.bounds.at[slots].set(jnp.stack([lo, hi], axis=-1)),
    )


def _traverse(regs, sid_rows, dev, *, pallas: bool, block_b: int):
    """Subtree traversal for one hop round (dense gather or Pallas)."""
    if pallas:
        return dispatch_dt_traverse(
            regs, sid_rows, dev.thresholds, dev.leaf_lo, dev.leaf_hi,
            dev.leaf_action, dev.leaf_valid,
            interpret=not ops._on_tpu(), block_b=block_b)
    return _ref.dt_traverse_ref(
        regs, dev.thresholds[sid_rows], dev.leaf_lo[sid_rows],
        dev.leaf_hi[sid_rows], dev.leaf_action[sid_rows],
        dev.leaf_valid[sid_rows] > 0)


def _hop_round(st: TickState, vm, vl, vr, ve, h, regs, complete, dev, *,
               n_subtrees: int, pallas: bool, block_b: int):
    """One hop for the slots in ``h`` whose ``complete`` bit is set.

    ``h`` (C,) routes non-completing rows to the dummy row; ``regs``
    (C, k) are the finalized registers for the completing rows (masked
    rows may carry anything — traversal output for them is discarded by
    the ``complete`` masks).  Runs traverse + ``_hop_update``, scatters
    verdicts for exiting / fell-off-the-last-partition flows into the
    per-slot buffers, advances the survivors' partition/window/SID, and
    returns the ``complete`` mask for the next drain round (flows whose
    new window is empty).
    """
    P = st.bounds.shape[1]
    dummy = st.sid.shape[0] - 1
    sid_rows = st.sid[h]
    p_rows = st.part[h]
    rec_rows = st.recircs[h]
    action = _traverse(regs, sid_rows, dev, pallas=pallas, block_b=block_b)
    carry = (sid_rows, ~complete,
             jnp.full(sid_rows.shape, -1, jnp.int32), rec_rows,
             jnp.full(sid_rows.shape, -1, jnp.int32))
    sid2, done2, labels, rec2, exit_p = _hop_update(
        carry, p_rows, action, n_subtrees)
    exited = complete & done2
    fell = complete & ~done2 & (p_rows == P - 1)
    adv = complete & ~done2 & (p_rows < P - 1)
    newdone = exited | fell

    # verdict buffers: one row per slot; a slot can finish at most once
    # per tick (admission precedes folding, so no within-tick reuse)
    vslot = jnp.where(newdone, h, dummy)
    vm = vm.at[vslot].set(1)
    vl = vl.at[vslot].set(labels)        # -1 unless the flow exited
    vr = vr.at[vslot].set(rec2)
    ve = ve.at[vslot].set(exit_p)        # -1 unless the flow exited
    retired = st.retired.at[vslot].set(1)

    # survivors advance to the next partition's window; finished rows
    # keep their metadata (the host frees their slots after the fetch)
    new_part = jnp.where(adv, p_rows + 1, p_rows)
    nb = st.bounds[h, jnp.minimum(new_part, P - 1)]          # (C, 2)
    new_lo = jnp.where(adv, nb[:, 0], st.win_lo[h])
    new_hi = jnp.where(adv, nb[:, 1], st.win_hi[h])
    a0, s0 = _ref.feature_state_init(dev.slot_op[sid2])
    st = TickState(
        acc=st.acc.at[h].set(a0),
        seen=st.seen.at[h].set(s0),
        sid=st.sid.at[h].set(sid2),
        part=st.part.at[h].set(new_part),
        win_lo=st.win_lo.at[h].set(new_lo),
        win_hi=st.win_hi.at[h].set(new_hi),
        pkts_seen=st.pkts_seen,
        recircs=st.recircs.at[h].set(rec2),
        retired=retired,
        bounds=st.bounds,
    )
    return st, vm, vl, vr, ve, adv & (new_lo == new_hi)


@functools.partial(jax.jit,
                   static_argnames=("n_subtrees", "pallas", "block_b"))
def tick_step(state: TickState, slots_rc: jnp.ndarray,
              pkt_rc: jnp.ndarray, dev: ops.DeviceTables, *,
              n_subtrees: int, pallas: bool, block_b: int):
    """One ingest tick: fold every rank, hop every completed window.

    ``slots_rc`` (R, C) int32 rank-major slot indices (dummy-padded;
    within a rank each real slot appears at most once) and ``pkt_rc``
    (R, C, F) the matching packets.  Rank order is per-flow arrival
    order — the reduction order the parity contract pins.  Returns the
    new state plus ``(verdict_mask, labels, recircs, exit_partition,
    recircs_snapshot)``, each ``(N,)``, for ONE bulk ``device_get``:
    rows with ``verdict_mask == 1`` finished this tick (exit or
    fell-off sentinels), ``recircs_snapshot`` mirrors the live
    recirculation counts for host-side flush/timeout sentinels.
    """
    N1 = state.sid.shape[0]
    P = state.bounds.shape[1]
    dummy = N1 - 1
    v0 = (jnp.zeros(N1, jnp.int32), jnp.full(N1, -1, jnp.int32),
          jnp.zeros(N1, jnp.int32), jnp.full(N1, -1, jnp.int32))

    def rank_body(carry, xs):
        st, vm, vl, vr, ve = carry
        slots, pkt = xs
        # a flow that finished earlier this tick must not fold its late
        # packets (malformed flow_len) into the slot's state: the
        # retired bit is the device form of the host's key check
        live = (slots != dummy) & (st.retired[slots] == 0)
        s = jnp.where(live, slots, dummy)
        pkt = jnp.where(live[:, None], pkt, 0.0)
        # window boundary clears the dependency chain (first-packet
        # IAT = 0), matching flows.windows.window_packets
        first = st.pkts_seen[s] == st.win_lo[s]
        pkt = pkt.at[:, PKT_IAT].set(jnp.where(first, 0.0, pkt[:, PKT_IAT]))
        sid_rows = st.sid[s]
        op = dev.slot_op[sid_rows]
        fld = dev.slot_field[sid_rows]
        prd = dev.slot_pred[sid_rows]
        init = dev.slot_init[sid_rows]
        if pallas:
            acc2, seen2, regs = feature_update_finalize_pallas(
                pkt, op, fld, prd, init, st.acc[s], st.seen[s],
                interpret=not ops._on_tpu(), block_b=block_b)
        else:
            acc2, seen2 = _ref.feature_update_ref(
                pkt, op, fld, prd, st.acc[s], st.seen[s])
            regs = _ref.feature_finalize_ref(acc2, seen2, op, init)
        pkts_seen = st.pkts_seen.at[s].add(live.astype(jnp.int32))
        st = st._replace(acc=st.acc.at[s].set(acc2),
                         seen=st.seen.at[s].set(seen2),
                         pkts_seen=pkts_seen)
        complete = live & (pkts_seen[s] == st.win_hi[s])

        # the window-completing hop rides the SAME dispatch as the fold
        # (regs already finalized above); drain rounds only ever see
        # empty windows, whose registers finalize from blank state
        h = jnp.where(complete, s, dummy)
        st, vm, vl, vr, ve, nxt = _hop_round(
            st, vm, vl, vr, ve, h, regs, complete, dev,
            n_subtrees=n_subtrees, pallas=pallas, block_b=block_b)

        def drain_cond(c):
            return jnp.any(c[5]) & (c[6] < P)

        def drain_body(c):
            st, vm, vl, vr, ve, comp, trip = c
            hh = jnp.where(comp, s, dummy)
            sid_h = st.sid[hh]
            regs = _ref.feature_finalize_ref(
                st.acc[hh], st.seen[hh], dev.slot_op[sid_h],
                dev.slot_init[sid_h])
            st, vm, vl, vr, ve, comp = _hop_round(
                st, vm, vl, vr, ve, hh, regs, comp, dev,
                n_subtrees=n_subtrees, pallas=pallas, block_b=block_b)
            return st, vm, vl, vr, ve, comp, trip + 1

        # bounded: each round advances every completing flow's
        # partition, so at most P-1 iterations run (trip is a backstop)
        st, vm, vl, vr, ve, _, _ = jax.lax.while_loop(
            drain_cond, drain_body,
            (st, vm, vl, vr, ve, nxt, jnp.int32(0)))
        return (st, vm, vl, vr, ve), None

    (state, vm, vl, vr, ve), _ = jax.lax.scan(
        rank_body, (state,) + v0, (slots_rc, pkt_rc))
    N = N1 - 1
    return state, (vm[:N], vl[:N], vr[:N], ve[:N], state.recircs[:N])

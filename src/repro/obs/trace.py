"""Nestable wall-clock spans that line up with device traces.

``span("tick/dispatch")`` times a host-side region and, when a jax
profiler session is active, emits a ``jax.profiler.TraceAnnotation``
so the host span shows up alongside device ops in
TensorBoard/perfetto.  Spans nest: entering a span while another is
open records the child under the parent, and ``span_tree()`` renders
the accumulated hierarchy.

The global switch is the ``SPLIDT_OBS`` environment variable (read
once at import; flip at runtime with :func:`set_enabled`).  When
disabled, :func:`span` returns one shared, reusable no-op context
manager — entering it is two trivial method calls with no allocation,
so instrumented hot loops cost nothing measurable.

Host timers (and therefore spans) measure nothing inside jit-traced
code — tracing runs once, execution happens later on device.  splint
rule R009 rejects any span entry or ``time.perf_counter`` call in
jit-reachable functions; keep instrumentation on the host side of
every dispatch.
"""
from __future__ import annotations

import os
import threading
import time
from typing import Dict, List, Optional

__all__ = [
    "SpanNode",
    "enabled",
    "reset_spans",
    "set_enabled",
    "span",
    "span_tree",
]

_ENABLED = os.environ.get("SPLIDT_OBS", "1") not in ("0", "false", "off")


def enabled() -> bool:
    """Is observability timing currently on?"""
    return _ENABLED


def set_enabled(on: bool) -> bool:
    """Flip the global switch; returns the previous value."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


class SpanNode:
    """Aggregated timings for one span name at one nesting position.

    Re-entering the same name under the same parent accumulates into
    one node (``count`` calls, ``total_s`` seconds) rather than
    growing an unbounded list — a server alive for millions of ticks
    keeps a tree the size of its instrumentation, not its history.
    """

    __slots__ = ("name", "count", "total_s", "children")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total_s = 0.0
        self.children: Dict[str, "SpanNode"] = {}

    def child(self, name: str) -> "SpanNode":
        node = self.children.get(name)
        if node is None:
            node = self.children[name] = SpanNode(name)
        return node

    def render(self, indent: int = 0) -> List[str]:
        lines = []
        if self.name:
            lines.append("%s%-28s %8d calls  %10.3f ms" % (
                "  " * indent, self.name, self.count,
                self.total_s * 1e3))
        for key in sorted(self.children):
            lines.extend(self.children[key].render(
                indent + (1 if self.name else 0)))
        return lines


class _SpanState(threading.local):
    def __init__(self):
        self.root = SpanNode("")
        self.stack: List[SpanNode] = []


_STATE = _SpanState()


class _Span:
    """Context manager for one timed region (enabled path)."""

    __slots__ = ("name", "_t0", "_node", "_annot")

    def __init__(self, name: str):
        self.name = name
        self._t0 = 0.0
        self._node: Optional[SpanNode] = None
        self._annot = None

    def __enter__(self):
        parent = _STATE.stack[-1] if _STATE.stack else _STATE.root
        self._node = parent.child(self.name)
        _STATE.stack.append(self._node)
        annot = _trace_annotation()
        if annot is not None:
            self._annot = annot(self.name)
            self._annot.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dt = time.perf_counter() - self._t0
        if self._annot is not None:
            self._annot.__exit__(*exc)
            self._annot = None
        node = self._node
        node.count += 1
        node.total_s += dt
        _STATE.stack.pop()
        return False


class _NullSpan:
    """Shared no-op context — the whole disabled path."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


def _trace_annotation():
    """``jax.profiler.TraceAnnotation`` if jax is importable, else None.

    Resolved lazily so ``repro.obs`` stays importable without jax (the
    metrics half is pure numpy) and so a missing profiler degrades to
    plain wall-clock spans.
    """
    try:
        import jax
        return jax.profiler.TraceAnnotation
    except Exception:
        return None


def span(name: str):
    """Open a timed region.  ``with span("tick/admit"): ...``

    No-op (shared null context) when observability is disabled.
    """
    if not _ENABLED:
        return _NULL
    return _Span(name)


def span_tree() -> str:
    """Render this thread's accumulated span hierarchy."""
    lines = _STATE.root.render()
    if not lines:
        return "(no spans recorded)"
    return "\n".join(lines)


def reset_spans() -> None:
    """Drop this thread's accumulated spans (tests, between runs)."""
    _STATE.root = SpanNode("")
    _STATE.stack = []

"""Make the registry consumable: periodic JSONL dumps + scrape endpoint.

``MetricsReporter`` runs a daemon thread that appends one JSON object
per interval to a file (each line a full ``snapshot()`` plus a
monotonic sequence number), and can optionally serve the Prometheus
text exposition over ``http.server`` for ad-hoc ``curl`` scrapes.
Both consumers only *read* the registry, which is single-writer by
design — no locks, no impact on the serving loop.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from .metrics import MetricRegistry, get_registry

__all__ = ["MetricsReporter"]


class MetricsReporter:
    """Periodic JSONL snapshot writer with an optional HTTP endpoint.

    >>> import tempfile, os
    >>> reg = MetricRegistry()
    >>> reg.counter("demo_total").inc(3)
    >>> path = os.path.join(tempfile.mkdtemp(), "metrics.jsonl")
    >>> rep = MetricsReporter(path, registry=reg, interval_s=3600.0)
    >>> rep.dump_once()
    >>> rep.close()
    >>> json.loads(open(path).read())["counters"]["demo_total"]["value"]
    3
    """

    def __init__(self, path: Optional[str] = None, *,
                 registry: Optional[MetricRegistry] = None,
                 interval_s: float = 10.0,
                 http_port: Optional[int] = None):
        if interval_s <= 0:
            raise ValueError("interval_s must be positive")
        self.registry = registry if registry is not None else get_registry()
        self.path = path
        self.interval_s = float(interval_s)
        self._seq = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._server: Optional[ThreadingHTTPServer] = None
        self._http_thread: Optional[threading.Thread] = None
        if http_port is not None:
            self._start_http(http_port)

    # -- JSONL dumps -------------------------------------------------------
    def dump_once(self) -> None:
        """Append one snapshot line now (also used by the timer loop)."""
        if self.path is None:
            return
        snap = self.registry.snapshot()
        snap["seq"] = self._seq
        self._seq += 1
        with open(self.path, "a") as fh:
            fh.write(json.dumps(snap, sort_keys=True) + "\n")

    def start(self) -> "MetricsReporter":
        """Start the periodic dump thread (daemon; ``close()`` stops it)."""
        if self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._loop, name="splidt-metrics-reporter", daemon=True)
        self._thread.start()
        return self

    def _loop(self) -> None:
        while not self._stop.wait(self.interval_s):
            self.dump_once()

    # -- HTTP text endpoint ------------------------------------------------
    def _start_http(self, port: int) -> None:
        registry = self.registry

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (http.server API)
                body = registry.to_prometheus().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):
                pass  # keep scrapes out of stderr

        self._server = ThreadingHTTPServer(("127.0.0.1", port), _Handler)
        self._http_thread = threading.Thread(
            target=self._server.serve_forever,
            name="splidt-metrics-http", daemon=True)
        self._http_thread.start()

    @property
    def http_port(self) -> Optional[int]:
        """Bound port of the scrape endpoint (None when not serving)."""
        if self._server is None:
            return None
        return self._server.server_address[1]

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        """Stop threads; flush one final snapshot line."""
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=2.0)
            self._thread = None
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        if self._http_thread is not None:
            self._http_thread.join(timeout=2.0)
            self._http_thread = None
        self.dump_once()

    def __enter__(self) -> "MetricsReporter":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

"""Line-rate telemetry for the serving stack.

Three pieces (see ``docs/OBSERVABILITY.md`` for the metric catalogue):

* :mod:`repro.obs.metrics` — process-local :class:`MetricRegistry`
  with counters, gauges, and fixed-bucket histograms; Prometheus-text
  and JSON exposition.
* :mod:`repro.obs.trace` — nestable wall-clock :func:`span` hooks that
  double as ``jax.profiler.TraceAnnotation`` markers; globally
  disabled with ``SPLIDT_OBS=0``.
* :mod:`repro.obs.reporter` — :class:`MetricsReporter`, a periodic
  JSONL dumper with an optional ``http.server`` scrape endpoint.

Counters and gauges always record (they back ``ServerStats`` and the
parity tests); only wall-clock timing — spans and latency-histogram
fills — honours the ``SPLIDT_OBS`` switch.
"""
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricRegistry,
    exp_edges,
    get_registry,
    set_registry,
)
from .reporter import MetricsReporter
from .trace import (
    SpanNode,
    enabled,
    reset_spans,
    set_enabled,
    span,
    span_tree,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "MetricsReporter",
    "SpanNode",
    "enabled",
    "exp_edges",
    "get_registry",
    "reset_spans",
    "set_enabled",
    "set_registry",
    "span",
    "span_tree",
]

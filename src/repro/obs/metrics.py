"""Process-local metrics: counters, gauges, fixed-bucket histograms.

The registry is the single source of runtime truth for the serving
stack — ``ServerStats`` is a thin view over it, the benchmarks dump
snapshots of it, and the reporter exposes it.  Design constraints, in
order:

* **O(1) record path.**  ``Counter.inc`` is two dict-free attribute
  ops; ``Histogram.record_many`` is one ``np.searchsorted`` plus one
  ``np.add.at`` regardless of sample count.  Nothing on the hot path
  allocates per-sample Python objects.
* **Lock-free single-writer.**  One thread (the serving loop) writes;
  readers (``MetricsReporter``, a scrape endpoint) only ever see a
  consistent-enough view because every cell is either a Python int
  (atomic under the GIL) or a numpy buffer that is copied on
  ``snapshot()``.  There are deliberately no locks to contend on.
* **Replayable.**  A snapshot is plain ``dict``/``list``/``float``
  data, so two runs over the same ``PacketStream`` can be compared
  key-by-key (the live-parity tests do exactly that).

>>> reg = MetricRegistry()
>>> c = reg.counter("serve_packets_total", "packets ingested")
>>> c.inc(128)
>>> reg.counter("serve_packets_total").value
128
>>> h = reg.histogram("serve_ttd_seconds", "arrival->verdict latency",
...                   edges=[0.001, 0.01, 0.1, 1.0])
>>> h.record_many([0.0005, 0.05, 0.05, 2.0])
>>> [int(c) for c in h.counts]
[1, 0, 2, 0, 1]
>>> h.quantile(0.5) <= 0.1
True
>>> snap = reg.snapshot()
>>> snap["counters"]["serve_packets_total"]["value"]
128
"""
from __future__ import annotations

import json
import math
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricRegistry",
    "exp_edges",
    "get_registry",
    "set_registry",
]


def _label_key(labels: Optional[Mapping[str, str]]) -> Tuple[Tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_suffix(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{%s}" % inner


def exp_edges(lo: float, hi: float, n: int) -> List[float]:
    """``n`` exponentially spaced bucket edges from ``lo`` to ``hi``."""
    if not (lo > 0 and hi > lo and n >= 2):
        raise ValueError("need 0 < lo < hi and n >= 2")
    ratio = (hi / lo) ** (1.0 / (n - 1))
    return [lo * ratio ** i for i in range(n)]


class Counter:
    """Monotonic int counter.  ``inc`` only; never decreases."""

    __slots__ = ("name", "help", "labels", "value")

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Mapping[str, str]] = None):
        self.name = name
        self.help = help
        self.labels = _label_key(labels)
        self.value = 0

    def inc(self, by: int = 1) -> None:
        if by < 0:
            raise ValueError("counters only go up")
        self.value += by


class Gauge:
    """A settable float — last write wins."""

    __slots__ = ("name", "help", "labels", "value")

    def __init__(self, name: str, help: str = "",
                 labels: Optional[Mapping[str, str]] = None):
        self.name = name
        self.help = help
        self.labels = _label_key(labels)
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = float(value)

    def add(self, by: float) -> None:
        self.value += float(by)


class Histogram:
    """Fixed-bucket histogram over ``edges`` (sorted, ascending).

    ``counts`` has ``len(edges) + 1`` cells: cell ``i`` holds the
    samples ``x`` with ``edges[i-1] <= x < edges[i]`` (numpy
    ``searchsorted(side="right")``), the last cell is the +Inf
    overflow.  Bucketing is vectorised; a million samples cost one
    searchsorted + one scatter-add.
    """

    __slots__ = ("name", "help", "labels", "edges", "counts",
                 "total", "sum")

    def __init__(self, name: str, help: str = "",
                 edges: Sequence[float] = (),
                 labels: Optional[Mapping[str, str]] = None):
        e = np.asarray(list(edges), dtype=np.float64)
        if e.ndim != 1 or e.size < 1 or np.any(np.diff(e) <= 0):
            raise ValueError("edges must be a non-empty ascending 1-d "
                             "sequence")
        self.name = name
        self.help = help
        self.labels = _label_key(labels)
        self.edges = e
        self.counts = np.zeros(e.size + 1, dtype=np.int64)
        self.total = 0
        self.sum = 0.0

    def record(self, value: float) -> None:
        i = int(np.searchsorted(self.edges, value, side="right"))
        self.counts[i] += 1
        self.total += 1
        self.sum += float(value)

    def record_many(self, values) -> None:
        v = np.asarray(values, dtype=np.float64).ravel()
        if v.size == 0:
            return
        idx = np.searchsorted(self.edges, v, side="right")
        np.add.at(self.counts, idx, 1)
        self.total += int(v.size)
        self.sum += float(v.sum())

    def quantile(self, q: float) -> float:
        """Upper bucket edge containing the ``q`` quantile (the usual
        Prometheus-style conservative estimate); NaN when empty."""
        if not (0.0 <= q <= 1.0):
            raise ValueError("q must be in [0, 1]")
        if self.total == 0:
            return float("nan")
        target = q * self.total
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, target, side="left"))
        if i >= self.edges.size:
            return float("inf")
        return float(self.edges[i])

    def bucket_of(self, value: float) -> int:
        """Index of the bucket a sample would land in."""
        return int(np.searchsorted(self.edges, value, side="right"))


class MetricRegistry:
    """Name → metric map with get-or-create accessors.

    Metric identity is ``(name, sorted(labels))``; re-asking for the
    same identity returns the same live object, so call sites never
    cache metric handles unless they are hot.
    """

    def __init__(self):
        self._counters: Dict[Tuple, Counter] = {}
        self._gauges: Dict[Tuple, Gauge] = {}
        self._histograms: Dict[Tuple, Histogram] = {}

    # -- get-or-create -----------------------------------------------------
    def counter(self, name: str, help: str = "",
                labels: Optional[Mapping[str, str]] = None) -> Counter:
        key = (name, _label_key(labels))
        m = self._counters.get(key)
        if m is None:
            m = self._counters[key] = Counter(name, help, labels)
        return m

    def gauge(self, name: str, help: str = "",
              labels: Optional[Mapping[str, str]] = None) -> Gauge:
        key = (name, _label_key(labels))
        m = self._gauges.get(key)
        if m is None:
            m = self._gauges[key] = Gauge(name, help, labels)
        return m

    def histogram(self, name: str, help: str = "",
                  edges: Sequence[float] = (),
                  labels: Optional[Mapping[str, str]] = None) -> Histogram:
        key = (name, _label_key(labels))
        m = self._histograms.get(key)
        if m is None:
            if not edges:
                raise ValueError(
                    f"first use of histogram {name!r} must pass edges")
            m = self._histograms[key] = Histogram(name, help, edges, labels)
        return m

    # -- views -------------------------------------------------------------
    def snapshot(self) -> Dict[str, dict]:
        """Plain-data copy of every metric (safe to mutate / serialise)."""
        counters = {}
        for (name, lk), c in sorted(self._counters.items()):
            counters[name + _label_suffix(lk)] = {
                "value": c.value, "help": c.help}
        gauges = {}
        for (name, lk), g in sorted(self._gauges.items()):
            gauges[name + _label_suffix(lk)] = {
                "value": g.value, "help": g.help}
        histograms = {}
        for (name, lk), h in sorted(self._histograms.items()):
            histograms[name + _label_suffix(lk)] = {
                "edges": [float(e) for e in h.edges],
                "counts": [int(c) for c in h.counts],
                "total": h.total,
                "sum": h.sum,
                "help": h.help,
            }
        return {"counters": counters, "gauges": gauges,
                "histograms": histograms}

    @staticmethod
    def delta(before: Mapping[str, dict],
              after: Mapping[str, dict]) -> Dict[str, dict]:
        """Snapshot-vs-snapshot difference (counters + histogram counts;
        gauges report the *after* value)."""
        out: Dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for k, v in after.get("counters", {}).items():
            prev = before.get("counters", {}).get(k, {}).get("value", 0)
            out["counters"][k] = {"value": v["value"] - prev}
        for k, v in after.get("gauges", {}).items():
            out["gauges"][k] = {"value": v["value"]}
        for k, v in after.get("histograms", {}).items():
            prev = before.get("histograms", {}).get(k)
            pc = prev["counts"] if prev else [0] * len(v["counts"])
            out["histograms"][k] = {
                "edges": v["edges"],
                "counts": [a - b for a, b in zip(v["counts"], pc)],
                "total": v["total"] - (prev["total"] if prev else 0),
                "sum": v["sum"] - (prev["sum"] if prev else 0.0),
            }
        return out

    # -- exposition --------------------------------------------------------
    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def to_prometheus(self) -> str:
        """Prometheus text exposition (v0.0.4) of the whole registry."""
        lines: List[str] = []
        for (name, lk), c in sorted(self._counters.items()):
            if c.help and not lk:
                lines.append(f"# HELP {name} {c.help}")
            if not lk:
                lines.append(f"# TYPE {name} counter")
            lines.append(f"{name}{_label_suffix(lk)} {c.value}")
        for (name, lk), g in sorted(self._gauges.items()):
            if g.help and not lk:
                lines.append(f"# HELP {name} {g.help}")
            if not lk:
                lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name}{_label_suffix(lk)} {_fmt(g.value)}")
        for (name, lk), h in sorted(self._histograms.items()):
            if h.help and not lk:
                lines.append(f"# HELP {name} {h.help}")
            if not lk:
                lines.append(f"# TYPE {name} histogram")
            cum = 0
            base = dict(lk)
            for edge, cnt in zip(h.edges, h.counts[:-1]):
                cum += int(cnt)
                le = _label_suffix(_label_key({**base, "le": _fmt(edge)}))
                lines.append(f"{name}_bucket{le} {cum}")
            cum += int(h.counts[-1])
            le = _label_suffix(_label_key({**base, "le": "+Inf"}))
            lines.append(f"{name}_bucket{le} {cum}")
            lines.append(f"{name}_sum{_label_suffix(lk)} {_fmt(h.sum)}")
            lines.append(f"{name}_count{_label_suffix(lk)} {h.total}")
        return "\n".join(lines) + "\n"


def _fmt(x: float) -> str:
    if math.isinf(x):
        return "+Inf" if x > 0 else "-Inf"
    if float(x) == int(x) and abs(x) < 1e15:
        return str(int(x))
    return repr(float(x))


# -- process-global default registry ---------------------------------------
# Engine / fit / dse / tuning instrumentation records here; a
# FlowTableServer gets its own registry by default (pass ``registry=``
# to share).  ``set_registry`` swaps the global for tests/benchmarks.
_DEFAULT = MetricRegistry()


def get_registry() -> MetricRegistry:
    return _DEFAULT


def set_registry(reg: MetricRegistry) -> MetricRegistry:
    """Install ``reg`` as the process default; returns the previous one."""
    global _DEFAULT
    prev = _DEFAULT
    _DEFAULT = reg
    return prev

"""Streaming batch scheduler for the partitioned-DT walk backends.

The data-plane story (DESIGN.md §4) is millions of concurrent flows over
a FIXED register pool; the TPU serving analogue is an unbounded flow
stream over a FIXED device batch.  This module chunks arbitrarily large
flow batches into fixed-size micro-batches, pads the ragged tail with
invalid packets (valid = 0 — the same padding the windowing pipeline
emits), and pushes each chunk through a fully-jitted partition walk:

  * every micro-batch has the SAME (mb, P, W, F) shape — including the
    padded tail — so XLA compiles the walk exactly once and replays it
    per chunk;
  * any walk backend works (``impl="fused"`` or ``"pallas"`` — the
    in-jit SID dispatch keeps the Pallas path streamable; ``"looped"``
    is rejected because it syncs per partition).  ``impl="auto"`` /
    ``"tuned"`` route through ``repro.tuning`` with the *chunk* shape
    (B = micro_batch, n_devices from the mesh) — the chunk, not the
    unbounded stream, is what executes;
  * with a ``mesh``, each micro-batch fans out across the mesh's
    data-parallel axes via ``shard_map`` — the walk is per-flow, so no
    collectives are needed and scaling is embarrassingly parallel;
  * off-CPU the packet buffer is donated, so back-to-back chunks reuse
    one device allocation instead of growing the live set;
  * results land in preallocated host arrays — one device→host
    transfer per micro-batch, none per partition.

**Inflight pipelining.**  jax dispatch is asynchronous: ``walk(batch)``
returns device futures immediately.  The scheduler keeps up to
``inflight`` chunks un-collected, so while the device crunches chunk i
the host is already slicing/padding/uploading chunk i+1; memory
high-water is ``inflight`` micro-batches of packets plus their verdict
buffers, NOT the full stream.  ``inflight=1`` collects each chunk
before dispatching the next (the fully synchronous PR 1 behaviour);
raising it past 2–3 only helps when host staging time rivals device
compute time.

``run_streaming`` is the closed-batch entry point (numpy in → verdicts
out); ``stream_batches`` is the open-stream form that consumes an
iterator of flow batches, for callers that never materialise the full
workload.

Shape/dtype conventions (shared with ``core.inference``): packet
windows are f32 ``(B, P, W, PKT_NFIELDS)``; verdict arrays are int32
``(B,)`` with ``-1`` sentinels for flows that never exit (see
``docs/PARITY.md``); padded rows are all-zero packets (valid=0) whose
verdicts are sliced off before they reach the caller.
"""
from __future__ import annotations

import functools
import math
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec

from repro import obs
from repro.core.inference import (
    _UNSET,
    Engine,
    EngineOptions,
    EngineResult,
    ExecutionBackend,
    StepFn,
    _legacy_options,
    _partition_walk,
    _record_walk,
    backend_for_plan,
    get_backend,
    pallas_backend,
    partition_walk,
    partition_walk_donated,
)
from repro.distributed.sharding import flow_batch_devices, flow_batch_spec
from repro.kernels.compaction import COMPACT_FLOOR
from repro.kernels.dispatch import pad_axis0, round_up


def _should_donate(donate: bool | None) -> bool:
    if donate is None:
        return jax.default_backend() != "cpu"
    return donate


def _walk_backend(engine: Engine, impl: str | None) -> ExecutionBackend:
    backend = get_backend(impl or engine.impl)
    if backend.step is None:
        raise ValueError(
            f"streaming requires a jitted walk backend (fused or pallas); "
            f"impl={backend.name!r} syncs the host every partition")
    return backend


def _resolve_backend(engine: Engine, opt: EngineOptions, mb: int, win_pkts):
    """Pick the chunk's walk backend; returns (backend, compact,
    compact_floor, plan).  A pre-resolved ``opt.plan`` wins outright;
    fixed impls go straight to :func:`get_backend` (honouring
    ``opt.block_b`` for pallas); ``auto``/``tuned`` (or
    ``compact="auto"``) resolve a ``repro.tuning.Plan`` for the CHUNK
    shape — B is the micro-batch, ``n_devices`` the mesh's
    data-parallel extent — with candidates restricted to the
    streamable walk backends."""
    if opt.plan is not None:
        plan = opt.plan
        backend = backend_for_plan(plan)
        if backend.step is None:
            raise ValueError(
                f"streaming requires a jitted walk backend (fused or "
                f"pallas); plan backend {plan.backend!r} syncs the host "
                "every partition")
        return backend, plan.compact, plan.compact_floor, plan
    impl = opt.impl or engine.impl
    if impl not in ("auto", "tuned") and opt.compact != "auto":
        if impl == "pallas" and opt.block_b is not None:
            backend = pallas_backend(opt.block_b)
        else:
            backend = _walk_backend(engine, impl)
        return backend, bool(opt.compact), opt.compact_floor, None
    from repro.tuning import ShapeInfo, get_plan
    mesh = opt.mesh
    n_dev = flow_batch_devices(mesh) if mesh is not None else 1
    shape = ShapeInfo.from_engine(engine, win_pkts, B=mb, n_devices=n_dev)
    plan = get_plan(engine, win_pkts, impl=impl, shape=shape,
                    backends=("fused", "pallas"), compact=opt.compact,
                    streaming=True)
    return (backend_for_plan(plan), plan.compact, plan.compact_floor, plan)


def _single_device_walk(n_subtrees: int, donate: bool, step: StepFn,
                        compact: bool = False, floor: int = COMPACT_FLOOR):
    """(batch, dev) -> (labels, recircs, exit_partition).  No caching
    needed: partition_walk is already jitted at module level, and its
    compile cache keys on the same static (n_subtrees, step, compact,
    compact_floor) args."""
    walk = partition_walk_donated if donate else partition_walk
    return lambda batch, dev: walk(batch, dev, n_subtrees=n_subtrees,
                                   with_trace=False, step=step,
                                   compact=compact, compact_floor=floor)[:3]


@functools.lru_cache(maxsize=None)
def _sharded_walk(mesh, n_subtrees: int, donate: bool, step: StepFn,
                  compact: bool = False, floor: int = COMPACT_FLOOR):
    """shard_map'd walk: the flow axis splits over the mesh's
    data-parallel axes; the device tables replicate.  The walk carries
    no cross-flow state, so the body needs no collectives — and with
    ``compact`` each shard counts its own survivors and picks its own
    capacity bucket (the switch index is shard-local data, no sync)."""
    spec = flow_batch_spec(mesh)

    def body(batch, dev):
        labels, recircs, exit_p, _ = _partition_walk(
            batch, dev, n_subtrees=n_subtrees, with_trace=False, step=step,
            compact=compact, compact_floor=floor)
        return labels, recircs, exit_p

    # check_rep=False: the body is collective-free by construction, and
    # pallas_call (the pallas backend's step) has no replication rule
    sharded = shard_map(body, mesh=mesh,
                        in_specs=(spec, PartitionSpec()),
                        out_specs=(spec, spec, spec),
                        check_rep=False)
    return jax.jit(sharded, donate_argnums=(0,) if donate else ())


def microbatches(n: int, micro_batch: int) -> Iterator[tuple[int, int]]:
    """Yield ``[lo, hi)`` bounds covering ``n`` flows in fixed chunks."""
    if micro_batch <= 0:
        raise ValueError("micro_batch must be positive")
    for i in range(math.ceil(n / micro_batch)):
        yield i * micro_batch, min((i + 1) * micro_batch, n)


def run_streaming(
    engine: Engine,
    win_pkts: np.ndarray,        # (B, p, W, PKT_NFIELDS), B unbounded
    *,
    options: EngineOptions | None = None,
    micro_batch=_UNSET,
    donate=_UNSET,
    mesh=_UNSET,
    impl=_UNSET,
    inflight=_UNSET,
    compact=_UNSET,
) -> EngineResult:
    """Streaming inference over a batch larger than one device batch.

    Equivalent to ``engine.run(win_pkts, with_trace=False)`` for any
    ``B``, ``micro_batch``, backend, mesh, and pipelining depth
    (property-tested, including the padded ragged tail); memory
    high-water is ``inflight`` micro-batches, not ``B``.  Knobs arrive
    as ``options=EngineOptions(...)`` (the bare keywords are deprecated
    shims).  With ``options.mesh`` the micro-batch is rounded up to a
    multiple of the mesh's data-parallel device count and each chunk
    executes sharded over the flow axis.  ``compact=True`` runs each
    chunk's walk with early-exit compaction (``kernels.compaction``) —
    identical verdicts, less work per hop once flows start exiting;
    ``compact="auto"`` lets the routing plan decide.

    ``impl="auto"`` / ``"tuned"`` resolve a ``repro.tuning.Plan`` for
    the chunk shape (backend + ``block_b`` + compaction), restricted to
    the streamable walk backends; the plan lands on the returned
    result's ``.plan`` (a pre-resolved ``options.plan`` is used as-is).

    ``inflight`` chunks are dispatched before the first result is
    pulled, so host staging of chunk i+1 overlaps device compute of
    chunk i (jax dispatch is async); ``inflight=1`` restores the fully
    synchronous PR 1 behaviour.
    """
    opt = _legacy_options(options, {
        "micro_batch": micro_batch, "donate": donate, "mesh": mesh,
        "impl": impl, "inflight": inflight, "compact": compact})
    P = engine._check_windows(win_pkts)
    B = win_pkts.shape[0]
    mesh, inflight = opt.mesh, opt.inflight
    mb = opt.micro_batch
    if mesh is not None:
        mb = round_up(mb, flow_batch_devices(mesh))
    backend, cpt, floor, plan = _resolve_backend(engine, opt, mb, win_pkts)
    if mesh is not None:
        walk = _sharded_walk(mesh, engine.ret.n_subtrees,
                             _should_donate(opt.donate), backend.step, cpt,
                             floor)
    else:
        walk = _single_device_walk(engine.ret.n_subtrees,
                                   _should_donate(opt.donate), backend.step,
                                   cpt, floor)

    # int32 throughout with the walk's -1 sentinels as the fill value:
    # per-batch results concatenate (stream_batches) without upcasts,
    # and an unwritten row can never masquerade as a class-0 verdict
    labels = np.full(B, -1, dtype=np.int32)
    recircs = np.zeros(B, dtype=np.int32)
    exit_partition = np.full(B, -1, dtype=np.int32)
    pending: list[tuple[int, int, tuple]] = []

    reg = obs.get_registry()
    chunk_counter = reg.counter(
        "stream_chunks_total", "micro-batches dispatched by run_streaming",
        labels={"backend": backend.name})

    def collect(keep: int) -> None:
        while len(pending) > keep:
            lo, hi, fut = pending.pop(0)
            with obs.span("stream/fetch"):
                lab, rec, exi = jax.device_get(fut)
            labels[lo:hi] = lab[:hi - lo]
            recircs[lo:hi] = rec[:hi - lo]
            exit_partition[lo:hi] = exi[:hi - lo]

    # every chunk has the SAME (mb, P, W, F) shape — even when B < mb —
    # so XLA compiles the walk once for the whole stream, whatever batch
    # sizes the producer emits
    for lo, hi in microbatches(B, mb):
        m = hi - lo
        if m == mb:
            # full chunk: upload straight from the caller's tensor
            batch = jnp.asarray(win_pkts[lo:hi, :P], dtype=jnp.float32)
        else:
            # ragged tail: pad with invalid packets (all-zero rows)
            batch = jnp.asarray(pad_axis0(
                np.ascontiguousarray(win_pkts[lo:hi, :P], dtype=np.float32),
                mb))
        with obs.span("stream/dispatch"):
            pending.append((lo, hi, walk(batch, engine.dev)))
            chunk_counter.inc()
            reg.counter("engine_dispatches_total",
                        "jitted walk calls issued",
                        labels={"backend": backend.name}).inc()
        collect(inflight - 1)
    collect(0)
    _record_walk(exit_partition, P, compact=cpt, compact_floor=floor)
    return EngineResult(labels, recircs, exit_partition, [], plan=plan)


def stream_batches(
    engine: Engine,
    batches: Iterable[np.ndarray],
    *,
    options: EngineOptions | None = None,
    micro_batch=_UNSET,
    donate=_UNSET,
    mesh=_UNSET,
    impl=_UNSET,
    inflight=_UNSET,
    compact=_UNSET,
) -> Iterator[EngineResult]:
    """Open-stream form: one :class:`EngineResult` per incoming batch.

    Each batch is micro-batched independently, so producers can hand
    over whatever flow counts the capture pipeline emits; the compiled
    walk is shared across all of them as long as ``(p, W)`` match.
    """
    opt = _legacy_options(options, {
        "micro_batch": micro_batch, "donate": donate, "mesh": mesh,
        "impl": impl, "inflight": inflight, "compact": compact})
    for batch in batches:
        yield run_streaming(engine, batch, options=opt)

"""Streaming batch scheduler for the fused partitioned-DT engine.

The data-plane story (DESIGN.md §4) is millions of concurrent flows over
a FIXED register pool; the TPU serving analogue is an unbounded flow
stream over a FIXED device batch.  This module chunks arbitrarily large
flow batches into fixed-size micro-batches, pads the ragged tail with
invalid packets (valid = 0 — the same padding the windowing pipeline
emits), and pushes each chunk through the fused, fully-jitted partition
walk:

  * every micro-batch has the SAME shape, so XLA compiles the walk
    exactly once and replays it per chunk;
  * off-CPU the packet buffer is donated, so back-to-back chunks reuse
    one device allocation instead of growing the live set;
  * results land in preallocated host arrays — one device→host
    transfer per micro-batch, none per partition.

``run_streaming`` is the closed-batch entry point (numpy in → verdicts
out); ``stream_batches`` is the open-stream form that consumes an
iterator of flow batches, for callers that never materialise the full
workload.
"""
from __future__ import annotations

import math
from typing import Iterable, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.inference import (
    Engine,
    EngineResult,
    fused_partition_walk,
    fused_partition_walk_donated,
)


def _should_donate(donate: bool | None) -> bool:
    if donate is None:
        return jax.default_backend() != "cpu"
    return donate


def microbatches(n: int, micro_batch: int) -> Iterator[tuple[int, int]]:
    """Yield ``[lo, hi)`` bounds covering ``n`` flows in fixed chunks."""
    if micro_batch <= 0:
        raise ValueError("micro_batch must be positive")
    for i in range(math.ceil(n / micro_batch)):
        yield i * micro_batch, min((i + 1) * micro_batch, n)


def run_streaming(
    engine: Engine,
    win_pkts: np.ndarray,        # (B, p, W, PKT_NFIELDS), B unbounded
    *,
    micro_batch: int = 4096,
    donate: bool | None = None,
) -> EngineResult:
    """Fused inference over a batch larger than one device batch.

    Equivalent to ``engine.run(win_pkts, with_trace=False)`` for any
    ``B`` and ``micro_batch`` (property-tested, including the padded
    ragged tail); memory high-water is one micro-batch, not ``B``.
    """
    if engine.impl == "pallas":
        raise ValueError(
            "run_streaming always executes the fused jnp walk; the Pallas "
            "dt_traverse groups flows by SID on the host and cannot be "
            "jitted into it — use Engine.run_looped for impl='pallas'")
    P = engine._check_windows(win_pkts)
    B = win_pkts.shape[0]
    walk = (fused_partition_walk_donated if _should_donate(donate)
            else fused_partition_walk)

    labels = np.zeros(B, dtype=np.int32)
    recircs = np.zeros(B, dtype=np.int32)
    exit_partition = np.zeros(B, dtype=np.int32)
    # every chunk has the SAME (micro_batch, P, W, F) shape — even when
    # B < micro_batch — so XLA compiles the walk once for the whole
    # stream, whatever batch sizes the producer emits
    mb = micro_batch
    chunk = None                     # staging buffer, tail chunk only
    for lo, hi in microbatches(B, mb):
        m = hi - lo
        if m == mb:
            # full chunk: upload straight from the caller's tensor
            batch = jnp.asarray(win_pkts[lo:hi, :P], dtype=jnp.float32)
        else:
            if chunk is None:
                chunk = np.zeros((mb, P) + win_pkts.shape[2:4], np.float32)
            chunk[:m] = win_pkts[lo:hi, :P]
            chunk[m:] = 0.0          # padded flows: every packet invalid
            batch = jnp.asarray(chunk)
        lab, rec, exi, _ = jax.device_get(walk(
            batch, engine.dev,
            n_subtrees=engine.ret.n_subtrees, with_trace=False))
        labels[lo:hi] = lab[:m]
        recircs[lo:hi] = rec[:m]
        exit_partition[lo:hi] = exi[:m]
    return EngineResult(labels, recircs, exit_partition, [])


def stream_batches(
    engine: Engine,
    batches: Iterable[np.ndarray],
    *,
    micro_batch: int = 4096,
    donate: bool | None = None,
) -> Iterator[EngineResult]:
    """Open-stream form: one :class:`EngineResult` per incoming batch.

    Each batch is micro-batched independently, so producers can hand
    over whatever flow counts the capture pipeline emits; the compiled
    walk is shared across all of them as long as ``(p, W)`` match.
    """
    for batch in batches:
        yield run_streaming(engine, batch, micro_batch=micro_batch,
                            donate=donate)

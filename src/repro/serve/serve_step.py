"""Serving steps: prefill + decode with greedy/temperature sampling.

``make_prefill_step`` / ``make_decode_step`` return jit-able pure
functions used both by the dry-run (AOT lowering on the production
mesh) and the continuous-batching engine (CPU, reduced configs).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import model_zoo


def make_prefill_step(cfg: ArchConfig) -> Callable:
    zoo = model_zoo.get_model(cfg)

    def prefill(params, batch, cache):
        lg, cache, _ = zoo.forward(cfg, params, batch, mode="prefill",
                                   cache=cache)
        return lg[:, -1:], cache         # next-token logits only

    return prefill


def make_decode_step(cfg: ArchConfig, temperature: float = 0.0) -> Callable:
    zoo = model_zoo.get_model(cfg)

    def decode(params, tokens, cache, rng):
        """tokens: (B, 1) last sampled tokens -> (next (B, 1), cache)."""
        lg, cache, _ = zoo.forward(cfg, params, {"tokens": tokens},
                                   mode="decode", cache=cache)
        lg = lg[:, -1, :].astype(jnp.float32)
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt[:, None].astype(jnp.int32), cache

    return decode

"""Device-resident flow table: per-packet streaming inference.

The paper's data plane keeps per-flow feature registers in a fixed
register pool, updates them on EVERY packet, and runs the active
subtree when a window boundary passes (paper §3.1, Fig. 4).  The batch
engine (``core.inference``) scores complete flow windows after the
fact; this module is the live analogue — the ROADMAP's "millions of
users, heavy traffic" direction:

  * a **hash-indexed slot table** (``FlowTable``) admits flows into a
    fixed pool of ``n_buckets * bucket_size`` slots (bucketed hashing
    with linear bucket probing — the register-pool analogue of
    ``kernels.dispatch``'s capacity blocks: a static capacity bound
    with data-dependent routing).  When every probe fails the flow
    falls back to a host-side spill store instead of being dropped.
    Admission is vectorized: one NumPy group-by over the tick's flow
    ids, one ``lookup_batch``/``insert_batch`` over the tick's unique
    flows — no per-packet python loop;
  * the **fused tick engine** (``kernels.tick_step``, the default via
    ``tick_engine="auto"``) holds ALL per-flow serving state on device
    — window registers and the walk metadata (``sid``, partition,
    window bounds, packets seen, recircs, retired bit) — and processes
    one whole tick in ONE jitted dispatch: a ``lax.scan`` over packet
    ranks, each rank a fused fold→finalize→traverse (window-complete
    slots hop through ``core.inference._hop_update`` in the same
    dispatch that folded them), with empty trailing windows drained by
    an in-jit bounded ``while_loop``.  Verdicts come back in one bulk
    ``device_get`` per tick;
  * the **legacy tick engine** (``tick_engine="legacy"``) keeps the
    PR-6 shape — one fold dispatch per rank, one hop dispatch + host
    sync per drain round — as the measured baseline
    (``tuning.estimate_tick_us`` models both; ``BENCH_serve.json``
    records the speedup).  Both engines are bit-identical;
  * **timeout eviction** emits mid-stream verdicts for idle flows with
    the ``-1`` sentinel convention (labels / exit_partition), keeping
    the accumulated recirculation count.

``FlowTableServer.ingest(packets) -> StreamVerdicts`` is the entry
point; packets arrive as arrival-ordered ticks (see
``flows.synthetic.make_packet_stream``).  Within a tick, packets are
processed in per-slot "ranks" (the r-th packet of each flow), so every
device scatter addresses each slot at most once and per-flow arrival
order — the reduction order the parity contract pins — is preserved.
Rank batches are padded to a power-of-two capacity ladder (a dummy
table row absorbs the padding) so jit compiles a handful of shapes,
not one per tick.  ``ServerStats.dispatches`` counts jitted device
calls: the fused tick engine issues at most 2 per tick (admission
scatter + tick step) regardless of rank count or drain rounds — the
deterministic perf bar ``tests/test_tick_engine.py`` pins.

Execution knobs come from :class:`repro.core.inference.EngineOptions`:
``impl`` picks the fold/traverse kernels (``fused`` = dense jnp,
``pallas`` = the Pallas scatter-update + SID-dispatched traverse;
``auto``/``tuned`` resolve a ``repro.tuning.Plan`` for the table
shape), ``block_b`` the Pallas block size; ``tick_engine="auto"`` then
routes fused-tick vs legacy through the tick-shape cost estimate
(``repro.tuning.choose_tick_engine``).  All routes are bit-identical
to ``Engine.run`` on the offline windows — the flow table can only
change *when* a verdict is computed, never its value.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import PKT_IAT, PKT_NFIELDS
from repro.core.inference import Engine, EngineOptions, _hop_update
from repro.flows.windows import window_bounds
from repro.kernels import ops
from repro.kernels import ref as _ref
from repro.kernels import tick_step as _tick
from repro.kernels.dispatch import dispatch_dt_traverse
from repro.kernels.dt_traverse import BLOCK_B
from repro.kernels.feature_window import feature_update_at
from repro.obs import MetricRegistry, exp_edges, span

#: Tick-engine modes ``FlowTableServer`` accepts ("auto" resolves via
#: the tick-shape cost estimate in ``repro.tuning``).
TICK_ENGINES = ("auto", "fused", "legacy")

#: Histogram bucket edges (docs/OBSERVABILITY.md catalogues the
#: metrics).  TTD is measured in STREAM time — the packet arrival
#: clock of the replayed ``PacketStream`` — so two replays of the same
#: stream land every verdict in the same bucket, deterministically.
TTD_EDGES = tuple(exp_edges(1e-3, 1e4, 15))
RECIRC_EDGES = (0.5, 1.5, 2.5, 4.5, 8.5, 16.5, 32.5)
WINDOW_EDGES = (1.5, 2.5, 3.5, 4.5, 6.5, 8.5, 12.5, 16.5)


# ---------------------------------------------------------------------------
# results — same field contract as core.inference.EngineResult
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class StreamVerdicts:
    """Verdicts emitted by one ``ingest``/``flush`` call.

    Field contract matches :class:`repro.core.inference.EngineResult`
    (``labels`` / ``recircs`` / ``exit_partition`` int32 with ``-1``
    sentinels, ``plan``, ``n_unterminated``) plus ``flow_id`` — stream
    verdicts arrive in completion order, not batch order, so each row
    names its flow.
    """
    flow_id: np.ndarray          # (n,) int64 flow key per verdict
    labels: np.ndarray           # (n,) int32; -1 = never took an exit action
    recircs: np.ndarray          # (n,) int32 partition transitions
    exit_partition: np.ndarray   # (n,) int32; -1 sentinel as above
    plan: "object | None" = None  # repro.tuning.Plan when routing resolved one

    @property
    def n_flows(self) -> int:
        return int(self.flow_id.shape[0])

    @property
    def n_unterminated(self) -> int:
        """Flows evicted or flushed without an exit action (-1 rows)."""
        return int(np.count_nonzero(np.asarray(self.exit_partition) < 0))

    @classmethod
    def empty(cls, plan=None) -> "StreamVerdicts":
        return cls(np.empty(0, np.int64), np.empty(0, np.int32),
                   np.empty(0, np.int32), np.empty(0, np.int32), plan=plan)

    @classmethod
    def concat(cls, parts) -> "StreamVerdicts":
        """Concatenate per-tick verdicts (keeps the first non-None plan)."""
        parts = list(parts)
        if not parts:
            return cls.empty()
        plan = next((p.plan for p in parts if p.plan is not None), None)
        return cls(
            np.concatenate([p.flow_id for p in parts]),
            np.concatenate([p.labels for p in parts]),
            np.concatenate([p.recircs for p in parts]),
            np.concatenate([p.exit_partition for p in parts]),
            plan=plan)


#: Singular alias — the per-flow row type and the batch share one shape.
StreamVerdict = StreamVerdicts


class _VerdictAccum:
    """Batched verdict builder: array chunks in, one pre-sized copy out.

    Callers append whole arrays per event batch (tick completions,
    evictions, spill runs) rather than per flow; ``build`` allocates the
    final arrays once from the accumulated count.
    """

    def __init__(self):
        self._chunks: list[tuple] = []
        self.n = 0

    def add(self, fid, label, rec, exitp, first_ts: float = np.inf) -> None:
        self.add_batch(np.asarray([fid], np.int64),
                       np.asarray([label], np.int32),
                       np.asarray([rec], np.int32),
                       np.asarray([exitp], np.int32),
                       np.asarray([first_ts], np.float64))

    def add_batch(self, fids, labels, recs, exitps, first_ts=None) -> None:
        fids = np.asarray(fids, np.int64)
        if not fids.size:
            return
        if first_ts is None:
            first_ts = np.full(fids.size, np.inf, np.float64)
        self._chunks.append((fids, np.asarray(labels, np.int32),
                             np.asarray(recs, np.int32),
                             np.asarray(exitps, np.int32),
                             np.asarray(first_ts, np.float64)))
        self.n += int(fids.size)

    def first_ts(self) -> np.ndarray:
        """First-packet arrival per accumulated verdict (TTD input)."""
        if not self._chunks:
            return np.empty(0, np.float64)
        return np.concatenate([c[4] for c in self._chunks])

    def build(self, plan) -> StreamVerdicts:
        fid = np.empty(self.n, np.int64)
        lab = np.empty(self.n, np.int32)
        rec = np.empty(self.n, np.int32)
        exp = np.empty(self.n, np.int32)
        at = 0
        for f, l, r, e, _ in self._chunks:
            fid[at:at + f.size] = f
            lab[at:at + f.size] = l
            rec[at:at + f.size] = r
            exp[at:at + f.size] = e
            at += f.size
        return StreamVerdicts(fid, lab, rec, exp, plan=plan)


# ---------------------------------------------------------------------------
# host hash index (bucketed, linear bucket probing, never drops)
# ---------------------------------------------------------------------------
def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finaliser — cheap, well-mixed bucket hashing."""
    x = np.asarray(x).astype(np.uint64)
    with np.errstate(over="ignore"):
        x = x + np.uint64(0x9E3779B97F4A7C15)
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


class FlowTable:
    """Fixed-capacity hash index over the device slot array.

    ``capacity = n_buckets * bucket_size`` slots; a flow key hashes to
    a home bucket and takes the first free slot there, probing
    subsequent buckets (wrapping) on overflow — the data-plane analogue
    is a multi-way register hash table.  ``insert`` returns ``None``
    only when the WHOLE table is full; the server then spills to the
    host instead of dropping the flow.  The batch forms
    (:meth:`lookup_batch` / :meth:`insert_batch`) serve one tick's
    UNIQUE flows in a single call — home buckets are hashed vectorized;
    probing stays sequential because each insert's placement depends on
    the previous one's occupancy.
    """

    def __init__(self, n_buckets: int, bucket_size: int):
        if n_buckets <= 0 or bucket_size <= 0:
            raise ValueError("n_buckets and bucket_size must be positive")
        self.n_buckets = n_buckets
        self.bucket_size = bucket_size
        self.capacity = n_buckets * bucket_size
        self.key = np.full(self.capacity, -1, np.int64)   # -1 = free slot
        self._slot_of: dict[int, int] = {}
        self.probe_overflows = 0    # inserts that left their home bucket

    @property
    def resident(self) -> int:
        return len(self._slot_of)

    def lookup(self, key: int) -> int | None:
        return self._slot_of.get(key)

    def lookup_batch(self, keys: np.ndarray) -> np.ndarray:
        """Slot per key, ``-1`` where absent (one probe per key)."""
        keys = np.asarray(keys, np.int64)
        get = self._slot_of.get
        return np.fromiter((get(int(k), -1) for k in keys), np.int64,
                           count=keys.size)

    def _insert_at(self, key: int, b0: int) -> int:
        for probe in range(self.n_buckets):
            b = (b0 + probe) % self.n_buckets
            base = b * self.bucket_size
            free = np.nonzero(
                self.key[base:base + self.bucket_size] == -1)[0]
            if free.size:
                if probe:
                    self.probe_overflows += 1
                slot = base + int(free[0])
                self.key[slot] = key
                self._slot_of[key] = slot
                return slot
        return -1

    def insert(self, key: int) -> int | None:
        b0 = int(_mix64(np.int64(key)) % np.uint64(self.n_buckets))
        slot = self._insert_at(int(key), b0)
        return None if slot < 0 else slot

    def insert_batch(self, keys: np.ndarray) -> np.ndarray:
        """Insert keys in order; slot per key, ``-1`` where full."""
        keys = np.asarray(keys, np.int64)
        homes = _mix64(keys) % np.uint64(self.n_buckets)
        out = np.empty(keys.size, np.int64)
        for i in range(keys.size):
            out[i] = self._insert_at(int(keys[i]), int(homes[i]))
        return out

    def free(self, slot: int) -> None:
        key = int(self.key[slot])
        del self._slot_of[key]
        self.key[slot] = -1


@dataclasses.dataclass
class _SpillFlow:
    """Host fallback for flows the hash table could not place.

    Packets are buffered and the completed flow runs through the batch
    engine's full-window walk — bit-identical verdicts (the parity
    contract makes incremental vs rebuilt windows indistinguishable),
    just computed late.  A spilled flow evicted before completion never
    ran a hop, so it reports zero recirculations with its sentinels.
    """
    length: int
    rows: list = dataclasses.field(default_factory=list)
    last_ts: float = -np.inf
    first_ts: float = np.inf


def _counter_stat(metric: str, doc: str) -> property:
    """A ServerStats field backed by a registry counter.

    The setter only accepts the ``stats.field += n`` idiom (counters
    are monotonic), which is the only way the server writes them.
    """
    def _get(self):
        return self.registry.counter(metric, doc).value

    def _set(self, value):
        c = self.registry.counter(metric, doc)
        c.inc(int(value) - c.value)

    return property(_get, _set, doc=doc)


class ServerStats:
    """Live integer counters for one server — a thin view.

    Since the obs PR the numbers live in the server's
    :class:`repro.obs.MetricRegistry` (``serve_*`` metrics); this
    class keeps the historical eight-field attribute API
    (``srv.stats.dispatches`` etc.) as properties over the registry,
    so stats appear in Prometheus/JSONL exposition for free.
    ``ServerStats()`` with no argument gets a private registry —
    the pre-PR standalone behaviour.
    """

    FIELDS = ("packets", "flows_seen", "verdicts", "spilled", "evicted",
              "peak_resident", "ticks", "dispatches")

    def __init__(self, registry: MetricRegistry | None = None):
        self.registry = registry if registry is not None else MetricRegistry()

    packets = _counter_stat(
        "serve_packets_total", "packets ingested (resident + spilled)")
    flows_seen = _counter_stat(
        "serve_flows_total", "distinct flows admitted or spilled")
    verdicts = _counter_stat(
        "serve_verdicts_total", "verdicts emitted (incl. sentinels)")
    spilled = _counter_stat(
        "serve_spilled_total", "flows that fell back to the host store")
    evicted = _counter_stat(
        "serve_evicted_total", "timeout evictions (mid-stream sentinels)")
    ticks = _counter_stat(
        "serve_ticks_total", "ingest calls served")
    dispatches = _counter_stat(
        "serve_dispatches_total", "jitted device calls issued (not syncs)")

    @property
    def peak_resident(self):
        """Max concurrent flows (slots + spill)."""
        return int(self.registry.gauge("serve_peak_resident").value)

    @peak_resident.setter
    def peak_resident(self, value):
        self.registry.gauge(
            "serve_peak_resident",
            "max concurrent flows (slots + spill)").set(value)

    def as_dict(self) -> dict:
        return {f: getattr(self, f) for f in self.FIELDS}

    def __repr__(self) -> str:
        inner = ", ".join(f"{k}={v}" for k, v in self.as_dict().items())
        return f"ServerStats({inner})"


# ---------------------------------------------------------------------------
# jitted device steps (module level: compile cache shared across servers)
# ---------------------------------------------------------------------------
def _pow2_cap(n: int, floor: int) -> int:
    """Smallest power-of-two >= n (>= floor) — the rank/hop batch
    capacity ladder, so jit sees a handful of shapes per table."""
    cap = max(int(floor), 1)
    while cap < n:
        cap *= 2
    return cap


@functools.partial(jax.jit, static_argnames=("n",))
def _blank_state(dev: ops.DeviceTables, n: int):
    """(acc, seen) for ``n`` rows, initialised for the root SID 0."""
    op = jnp.broadcast_to(dev.slot_op[0][None, :],
                          (n, dev.slot_op.shape[1]))
    return _ref.feature_state_init(op)


@jax.jit
def _reset_rows(acc, seen, slots, sid_rows, dev):
    """Re-initialise the addressed rows for their (new) SID's ops."""
    a0, s0 = _ref.feature_state_init(dev.slot_op[sid_rows])
    return acc.at[slots].set(a0), seen.at[slots].set(s0)


@functools.partial(jax.jit, static_argnames=("pallas", "block_b"))
def _fold_rank(acc, seen, pkt, sid_rows, slots, dev, *,
               pallas: bool, block_b: int):
    """Fold one rank (<= 1 packet per slot) into the resident state.

    Padding entries address the dummy row with an invalid packet; all
    compute identical values, so the duplicate scatter is
    deterministic.
    """
    op = dev.slot_op[sid_rows]
    fld = dev.slot_field[sid_rows]
    prd = dev.slot_pred[sid_rows]
    if pallas:
        return feature_update_at(acc, seen, slots, pkt, op, fld, prd,
                                 interpret=not ops._on_tpu(),
                                 block_b=block_b)
    a2, s2 = _ref.feature_update_ref(pkt, op, fld, prd,
                                     acc[slots], seen[slots])
    return acc.at[slots].set(a2), seen.at[slots].set(s2)


@functools.partial(jax.jit,
                   static_argnames=("n_subtrees", "pallas", "block_b"))
def _hop_rank(acc, seen, slots, sid_rows, p_rows, rec_rows, dev, *,
              n_subtrees: int, pallas: bool, block_b: int):
    """One recirculation hop for the slots whose window just completed.

    Finalize the folded registers, traverse the active subtree, and run
    the walk's own ``_hop_update`` bookkeeping with this batch's
    per-flow partition indices; the hopped rows are re-initialised for
    their post-hop SID (exited rows are reset too — harmless, their
    slots are freed host-side).  Returns the updated state tables plus
    ``(labels, done, sid, recircs, exit_partition)`` for the host.
    """
    op = dev.slot_op[sid_rows]
    init = dev.slot_init[sid_rows]
    regs = _ref.feature_finalize_ref(acc[slots], seen[slots], op, init)
    if pallas:
        action = dispatch_dt_traverse(
            regs, sid_rows, dev.thresholds, dev.leaf_lo, dev.leaf_hi,
            dev.leaf_action, dev.leaf_valid,
            interpret=not ops._on_tpu(), block_b=block_b)
    else:
        action = _ref.dt_traverse_ref(
            regs, dev.thresholds[sid_rows], dev.leaf_lo[sid_rows],
            dev.leaf_hi[sid_rows], dev.leaf_action[sid_rows],
            dev.leaf_valid[sid_rows] > 0)
    carry = (sid_rows,
             jnp.zeros(sid_rows.shape, jnp.bool_),
             jnp.full(sid_rows.shape, -1, jnp.int32),
             rec_rows,
             jnp.full(sid_rows.shape, -1, jnp.int32))
    sid2, done, labels, rec2, exit_p = _hop_update(
        carry, p_rows, action, n_subtrees)
    a0, s0 = _ref.feature_state_init(dev.slot_op[sid2])
    return (acc.at[slots].set(a0), seen.at[slots].set(s0),
            labels, done, sid2, rec2, exit_p)


def _resolve_exec(engine: Engine, opt: EngineOptions, capacity: int):
    """EngineOptions -> (pallas?, block_b, plan) for the serving steps.

    ``auto``/``tuned`` resolve a walk-backend ``Plan`` for the table's
    shape through ``repro.tuning`` (no probe windows exist yet, so
    ``tuned`` degrades to the cost model); only the plan's backend and
    ``block_b`` apply — per-hop batches are already survivor-compacted
    by construction, so the compaction knob is inert here.
    """
    plan = opt.plan
    impl = opt.impl or engine.impl
    if plan is None and impl in ("auto", "tuned"):
        from repro.tuning import ShapeInfo, get_plan
        shape = ShapeInfo.from_engine(engine, None, B=capacity, W=1)
        plan = get_plan(engine, None, impl=impl, shape=shape,
                        backends=("fused", "pallas"), compact=False)
    if plan is not None:
        if plan.backend not in ("fused", "pallas"):
            raise ValueError(
                "flow-table serving requires a walk backend (fused or "
                f"pallas); plan backend {plan.backend!r} syncs per hop")
        return plan.backend == "pallas", plan.block_b, plan
    if impl == "ref":
        impl = "fused"
    if impl not in ("fused", "pallas"):
        raise ValueError(
            "flow-table serving requires a walk backend (fused or "
            f"pallas); got impl={impl!r}")
    return impl == "pallas", opt.block_b or BLOCK_B, None


# ---------------------------------------------------------------------------
# the server
# ---------------------------------------------------------------------------
class FlowTableServer:
    """Per-packet streaming inference behind a resident flow table.

    ``ingest`` consumes arrival-ordered packet ticks
    (``flows.synthetic.PacketBatch``) and returns the
    :class:`StreamVerdicts` that completed during the tick; ``flush``
    evicts everything still resident (``-1`` sentinels for flows whose
    stream ended mid-window).  With ``timeout`` set, flows idle longer
    than ``timeout`` seconds of stream time are evicted at tick
    boundaries the same way.

    ``tick_engine`` picks the per-tick execution strategy: ``"fused"``
    runs one jitted tick step for the whole rank loop + hop drain
    (``kernels.tick_step``), ``"legacy"`` dispatches per rank / per
    drain round, ``"auto"`` (default) routes through the tick-shape
    cost estimate — fused everywhere dispatch overhead dominates.
    Both are bit-identical; only dispatch counts and latency differ.

    Each flow key is served exactly once: after its verdict (exit,
    flush, or timeout) the key is retired and late packets for it are
    dropped.  The retired set grows with the number of completed flows;
    callers running unbounded streams should recreate the server
    per epoch.
    """

    def __init__(self, engine: Engine, *, n_buckets: int = 64,
                 bucket_size: int = 8, timeout: float | None = None,
                 options: EngineOptions | None = None,
                 rank_floor: int = 64, tick_engine: str = "auto",
                 registry: MetricRegistry | None = None):
        self.engine = engine
        self.options = options or EngineOptions()
        self.timeout = timeout
        self.table = FlowTable(n_buckets, bucket_size)
        self.P = engine.tables.n_partitions
        self.S = engine.ret.n_subtrees
        self._rank_floor = int(rank_floor)
        self._pallas, self._block_b, self._plan = _resolve_exec(
            engine, self.options, self.table.capacity)
        if tick_engine not in TICK_ENGINES:
            raise ValueError(f"unknown tick_engine {tick_engine!r}; "
                             f"options {TICK_ENGINES}")
        if tick_engine == "auto":
            from repro.tuning import ShapeInfo, choose_tick_engine
            shape = ShapeInfo.from_engine(engine, None,
                                          B=self.table.capacity, W=1)
            tick_engine = choose_tick_engine(
                shape, backend="pallas" if self._pallas else "fused",
                block_b=self._block_b)
        self.tick_engine = tick_engine
        # spilled flows run the batch walk; pin the same backend family
        self._spill_options = EngineOptions(
            impl="pallas" if self._pallas else "fused",
            block_b=self._block_b if self._pallas else None)

        N = self.table.capacity
        self._dummy = N                       # padding scatters land here
        # each server gets a private registry unless the caller shares
        # one; ServerStats is a view over it (serve_* counters/gauge)
        self.registry = registry if registry is not None else MetricRegistry()
        self.stats = ServerStats(self.registry)
        self._m_ttd = self.registry.histogram(
            "serve_ttd_seconds",
            "stream-time packet-arrival -> verdict latency (TTD)",
            edges=TTD_EDGES)
        self._m_recirc_hist = self.registry.histogram(
            "serve_recircs_per_flow",
            "recirculations accumulated per emitted verdict",
            edges=RECIRC_EDGES)
        self._m_windows = self.registry.histogram(
            "serve_windows_per_verdict",
            "partition windows visited per verdict (recircs + 1)",
            edges=WINDOW_EDGES)
        self._m_recircs = self.registry.counter(
            "serve_recircs_total",
            "recirculations summed over emitted verdicts")
        self._m_overhead = self.registry.gauge(
            "serve_recirc_overhead",
            "recirculations per ingested packet (paper bar: < 0.0005)")
        self._m_resident = self.registry.gauge(
            "serve_resident_flows",
            "concurrent flows currently held (slots + host spill)")
        self._now = -np.inf                   # stream clock: max arrival seen
        self._first_ts = np.full(N, np.inf, np.float64)
        self._last_ts = np.full(N, -np.inf, np.float64)
        self._recircs = np.zeros(N, np.int32)
        self._spill: dict[int, _SpillFlow] = {}
        self._retired: set[int] = set()
        if self.tick_engine == "fused":
            # everything else lives on device (kernels.tick_step);
            # _recircs is the host mirror refreshed by each tick's bulk
            # verdict fetch (flush/timeout sentinels read it)
            self._tstate = _tick.init_tick_state(engine.dev, N + 1, self.P)
        else:
            self._acc, self._seen = _blank_state(engine.dev, N + 1)
            self._sid = np.zeros(N, np.int32)
            self._part = np.zeros(N, np.int32)
            self._win_lo = np.zeros(N, np.int32)
            self._win_hi = np.zeros(N, np.int32)
            self._pkts_seen = np.zeros(N, np.int32)
            self._bounds = np.zeros((N, self.P, 2), np.int32)

    # -- admission ------------------------------------------------------
    @property
    def resident_flows(self) -> int:
        """Concurrent flows currently held (slots + host spill)."""
        return self.table.resident + len(self._spill)

    def _evict(self, slot: int) -> None:
        self._retired.add(int(self.table.key[slot]))
        self.table.free(slot)

    def _route_tick(self, fid: np.ndarray, flen: np.ndarray) -> np.ndarray:
        """Vectorized admission: one group-by over the tick's flow ids.

        Returns a per-packet routing code: a slot index (``>= 0``),
        ``-2`` for the host spill store, ``-1`` for retired-flow drops.
        Unique flows are looked up / inserted in one batch call each;
        new flows insert in first-packet order — the exact occupancy
        evolution of the old per-packet loop, since within a tick every
        lookup of an already-inserted flow hits and order cannot matter
        for hits.  Admitted slots are re-initialised in one batch
        (``_admit_batch``); ``flows_seen`` counts once from the masks.
        """
        uniq, first_idx, inv = np.unique(fid, return_index=True,
                                         return_inverse=True)
        code = self.table.lookup_batch(uniq)
        miss = np.nonzero(code < 0)[0]
        if miss.size:
            keys = uniq[miss]
            retired = np.fromiter((int(k) in self._retired for k in keys),
                                  np.bool_, count=keys.size)
            spilled = np.fromiter((int(k) in self._spill for k in keys),
                                  np.bool_, count=keys.size)
            code[miss[retired]] = -1
            code[miss[spilled]] = -2
            new = miss[~retired & ~spilled]
            if new.size:
                new = new[np.argsort(first_idx[new], kind="stable")]
                lens = flen[first_idx[new]]
                slots = self.table.insert_batch(uniq[new])
                ok = slots >= 0
                code[new] = np.where(ok, slots, -2)
                for j in np.nonzero(~ok)[0]:   # table full: host spill
                    self._spill[int(uniq[new[j]])] = _SpillFlow(
                        length=max(int(lens[j]), 1))
                self.stats.spilled += int(np.count_nonzero(~ok))
                self.stats.flows_seen += int(new.size)
                if ok.any():
                    self._admit_batch(slots[ok], lens[ok])
        return code[inv]

    def _admit_batch(self, slots: np.ndarray, lengths: np.ndarray) -> None:
        """Initialise newly admitted slots (recycled slots carry the
        previous tenant's state/SID) — one device call per tick."""
        slots = np.asarray(slots, np.int64)
        lengths = np.maximum(np.asarray(lengths, np.int64), 1)
        self._last_ts[slots] = -np.inf
        self._first_ts[slots] = np.inf        # new tenant: fresh TTD clock
        if self.tick_engine == "fused":
            cap, padded = self._pad_slots(slots)
            plen = np.ones(cap, np.int32)
            plen[:slots.size] = lengths
            self._tstate = _tick.admit_rows(
                self._tstate, jnp.asarray(padded), jnp.asarray(plen),
                self.engine.dev)
            self.stats.dispatches += 1
            return
        # legacy: host metadata writes (vectorized) + one device reset
        P = self.P
        length = lengths.astype(np.int32)
        base = np.maximum(length // P, 1)
        w = np.arange(P, dtype=np.int32)[None, :]
        lo = np.minimum(w * base[:, None], length[:, None])
        hi = np.minimum((w + 1) * base[:, None], length[:, None])
        hi[:, P - 1] = length
        self._bounds[slots] = np.stack([lo, hi], axis=-1)
        self._sid[slots] = 0
        self._part[slots] = 0
        self._win_lo[slots] = lo[:, 0]
        self._win_hi[slots] = hi[:, 0]
        self._pkts_seen[slots] = 0
        self._recircs[slots] = 0
        self._reset_admitted(np.sort(slots))

    # -- ingest ---------------------------------------------------------
    def ingest(self, batch) -> StreamVerdicts:
        """Fold one tick of packet arrivals; return completed verdicts."""
        fid = np.asarray(batch.flow_id, np.int64)
        flen = np.asarray(batch.flow_len, np.int64)
        pk = np.asarray(batch.pkts, np.float32)
        arr = np.asarray(batch.arrival, np.float64)
        n = int(fid.shape[0])
        self.stats.packets += n
        self.stats.ticks += 1
        if n:
            self._now = max(self._now, float(arr.max()))
        out = _VerdictAccum()

        # route every packet: resident slot, spill store, or retired-drop
        with span("tick/admit"):
            slot_pk = (self._route_tick(fid, flen) if n
                       else np.empty(0, np.int64))
        self.stats.peak_resident = max(self.stats.peak_resident,
                                       self.resident_flows)

        spill_rows = np.nonzero(slot_pk == -2)[0]
        for i in spill_rows:
            f = self._spill[int(fid[i])]
            f.rows.append(pk[i])
            ts = float(arr[i])
            f.last_ts = max(f.last_ts, ts)
            f.first_ts = min(f.first_ts, ts)

        res_rows = np.nonzero(slot_pk >= 0)[0]
        if res_rows.size:
            self._process_resident(slot_pk[res_rows], fid[res_rows],
                                   pk[res_rows], arr[res_rows], out)
        self._run_spilled_complete(out)
        if self.timeout is not None and n:
            self._evict_timeouts(float(arr.max()), out)
        self.stats.verdicts += out.n
        return self._finish(out)

    def flush(self) -> StreamVerdicts:
        """End of stream: evict every resident flow with sentinels."""
        out = _VerdictAccum()
        self._run_spilled_complete(out)
        live = np.nonzero(self.table.key >= 0)[0]
        if live.size:
            neg = np.full(live.size, -1, np.int32)
            out.add_batch(self.table.key[live], neg,
                          self._recircs[live], neg, self._first_ts[live])
            for slot in live:
                self._evict(int(slot))
        for key in list(self._spill):
            out.add(key, -1, 0, -1, self._spill[key].first_ts)
            del self._spill[key]
            self._retired.add(key)
        self.stats.verdicts += out.n
        return self._finish(out)

    def _finish(self, out: _VerdictAccum) -> StreamVerdicts:
        """Build the tick's verdicts and fold them into the registry.

        Everything here is derived from the verdicts themselves plus
        the stream clock, so it is deterministic across replays and
        across tick engines — the live-parity tests recompute each
        value offline from the raw :class:`StreamVerdicts`.
        """
        v = out.build(self._plan)
        if v.n_flows:
            rec = np.asarray(v.recircs, np.int64)
            self._m_recircs.inc(int(rec.sum()))
            self._m_recirc_hist.record_many(rec)
            self._m_windows.record_many(rec + 1)
            ttd = np.float64(self._now) - out.first_ts()
            self._m_ttd.record_many(ttd[np.isfinite(ttd)])
        pkts = self.stats.packets
        self._m_overhead.set(
            self._m_recircs.value / pkts if pkts else 0.0)
        self._m_resident.set(self.resident_flows)
        return v

    # -- device plumbing ------------------------------------------------
    def _pad_slots(self, s: np.ndarray) -> tuple[int, np.ndarray]:
        cap = _pow2_cap(s.size, self._rank_floor)
        slots = np.full(cap, self._dummy, np.int32)
        slots[:s.size] = s
        return cap, slots

    def _reset_admitted(self, s: np.ndarray) -> None:
        cap, slots = self._pad_slots(s)
        self._acc, self._seen = _reset_rows(
            self._acc, self._seen, jnp.asarray(slots),
            jnp.zeros(cap, jnp.int32), self.engine.dev)
        self.stats.dispatches += 1

    @staticmethod
    def _rank_decompose(slots: np.ndarray):
        """(order, sorted slots, group id, rank) for one tick.

        Rank r = the r-th packet of a flow within the tick: every rank
        addresses each slot at most once (unique-scatter), and rank
        order preserves per-flow arrival order (stable argsort) — the
        reduction order the parity contract pins.
        """
        order = np.argsort(slots, kind="stable")
        ss = slots[order]
        new_grp = np.r_[True, ss[1:] != ss[:-1]]
        grp_start = np.nonzero(new_grp)[0]
        grp_id = np.cumsum(new_grp) - 1
        rank = np.arange(ss.size) - grp_start[grp_id]
        return order, ss, grp_id, rank

    def _process_resident(self, slots, fids, pkts, arr, out) -> None:
        np.minimum.at(self._first_ts, slots, arr)
        np.maximum.at(self._last_ts, slots, arr)
        if self.tick_engine == "fused":
            self._process_resident_fused(slots, pkts, out)
        else:
            self._process_resident_legacy(slots, fids, pkts, out)

    def _process_resident_fused(self, slots, pkts, out) -> None:
        """One jitted dispatch for the whole tick, one bulk fetch.

        The tick's packets are packed rank-major into ``(R, C)`` arrays
        (column = the flow's group index, constant across ranks; unused
        cells address the dummy row), padded on both axes to the
        power-of-two ladder so jit compiles a handful of shapes.  The
        retired-flow guard, IAT window reset, fold, completion hop, and
        empty-window drain all run inside ``kernels.tick_step``.
        """
        with span("tick/pack"):
            order, ss, grp_id, rank = self._rank_decompose(slots)
            R = _pow2_cap(int(rank.max()) + 1, 1)
            C = _pow2_cap(int(grp_id[-1]) + 1, self._rank_floor)
            slots_rc = np.full((R, C), self._dummy, np.int32)
            pkt_rc = np.zeros((R, C, PKT_NFIELDS), np.float32)
            slots_rc[rank, grp_id] = ss
            pkt_rc[rank, grp_id] = pkts[order]
        with span("tick/dispatch"):
            self._tstate, res = _tick.tick_step(
                self._tstate, jnp.asarray(slots_rc), jnp.asarray(pkt_rc),
                self.engine.dev, n_subtrees=self.S,
                pallas=self._pallas, block_b=self._block_b)
            self.stats.dispatches += 1
        with span("tick/fetch"):
            vm, vl, vr, ve, rec = (
                np.asarray(a) for a in jax.device_get(res))
        self._recircs = rec                   # host mirror (flush/timeout)
        done = np.nonzero(vm)[0]
        if done.size:
            out.add_batch(self.table.key[done], vl[done], vr[done],
                          ve[done], self._first_ts[done])
            for slot in done:
                self._evict(int(slot))

    def _process_resident_legacy(self, slots, fids, pkts, out) -> None:
        order, _, _, rank = self._rank_decompose(slots)
        for r in range(int(rank.max()) + 1):
            sel = order[rank == r]
            s = slots[sel]
            # a flow that exited earlier this tick frees its slot; any
            # later packets of it (malformed flow_len) must not fold
            # into the slot's next tenant
            alive = self.table.key[s] == fids[sel]
            sel, s = sel[alive], s[alive]
            if not s.size:
                continue
            p = pkts[sel].copy()
            # window boundary clears the dependency chain (first-packet
            # IAT = 0), matching flows.windows.window_packets
            p[self._pkts_seen[s] == self._win_lo[s], PKT_IAT] = 0.0
            self._fold(s, p)
            self._pkts_seen[s] += 1
            complete = s[self._pkts_seen[s] == self._win_hi[s]]
            if complete.size:
                self._hop_drain(complete, out)

    def _fold(self, s: np.ndarray, p: np.ndarray) -> None:
        cap, slots = self._pad_slots(s)
        sid = np.zeros(cap, np.int32)
        sid[:s.size] = self._sid[s]
        pkt = np.zeros((cap, PKT_NFIELDS), np.float32)
        pkt[:s.size] = p
        with span("tick/dispatch"):
            self._acc, self._seen = _fold_rank(
                self._acc, self._seen, jnp.asarray(pkt), jnp.asarray(sid),
                jnp.asarray(slots), self.engine.dev,
                pallas=self._pallas, block_b=self._block_b)
            self.stats.dispatches += 1

    def _hop_drain(self, s: np.ndarray, out: _VerdictAccum) -> None:
        """Hop the completed slots; drain any windows that complete
        immediately after (flows shorter than P packets have empty
        trailing windows — the walk still traverses them, so we do
        too).  Terminates: every drain round advances the partition.
        Per-slot bookkeeping is vectorized with numpy masks."""
        while s.size:
            cap, slots = self._pad_slots(s)
            sid = np.zeros(cap, np.int32)
            sid[:s.size] = self._sid[s]
            p_rows = np.zeros(cap, np.int32)
            p_rows[:s.size] = self._part[s]
            rec = np.zeros(cap, np.int32)
            rec[:s.size] = self._recircs[s]
            with span("tick/dispatch"):
                res = _hop_rank(
                    self._acc, self._seen, jnp.asarray(slots),
                    jnp.asarray(sid), jnp.asarray(p_rows),
                    jnp.asarray(rec),
                    self.engine.dev, n_subtrees=self.S,
                    pallas=self._pallas, block_b=self._block_b)
                self.stats.dispatches += 1
            self._acc, self._seen = res[0], res[1]
            with span("tick/fetch"):
                labels, done, sid2, rec2, exit_p = (
                    np.asarray(a)[:s.size]
                    for a in jax.device_get(res[2:]))
            done = done.astype(bool)
            # exits emit verdicts; flows falling off the last partition
            # emit -1 sentinels; the rest advance to the next window
            fin = done | (self._part[s] == self.P - 1)
            if fin.any():
                out.add_batch(self.table.key[s[fin]],
                              np.where(done, labels, -1)[fin], rec2[fin],
                              np.where(done, exit_p, -1)[fin],
                              self._first_ts[s[fin]])
                for slot in s[fin]:
                    self._evict(int(slot))
            sa = s[~fin]
            self._sid[sa] = sid2[~fin]
            self._recircs[sa] = rec2[~fin]
            self._part[sa] += 1
            b = self._bounds[sa, self._part[sa]]
            self._win_lo[sa] = b[:, 0]
            self._win_hi[sa] = b[:, 1]
            s = sa[b[:, 0] == b[:, 1]]        # empty window: hop again

    # -- host fallbacks -------------------------------------------------
    def _run_spilled_complete(self, out: _VerdictAccum) -> None:
        """Run completed spilled flows through the batch walk."""
        done = [key for key, f in self._spill.items()
                if len(f.rows) >= f.length]
        if not done:
            return
        P = self.P
        all_bounds = {key: window_bounds(self._spill[key].length, P)
                      for key in done}
        w_max = max(1, max(hi - lo for b in all_bounds.values()
                           for lo, hi in b))
        # pad the flows axis to the pow2 capacity ladder: batch rows are
        # independent in the walk, so the zero-filled tail is discarded
        # below.  Without this, every distinct spill-batch size is a
        # fresh XLA compile — a spill-heavy stream (tiny table) racks up
        # one executable per tick and can OOM the compiler.
        cap = _pow2_cap(len(done), 1)
        wp = np.zeros((cap, P, w_max, PKT_NFIELDS), np.float32)
        for idx, key in enumerate(done):
            rows = np.stack(self._spill[key].rows)
            for w, (lo, hi) in enumerate(all_bounds[key]):
                if hi <= lo:
                    continue
                win = rows[lo:hi].copy()
                win[0, PKT_IAT] = 0.0
                wp[idx, w, :hi - lo] = win
        with span("tick/spill"):
            res = self.engine.run(wp, with_trace=False,
                                  options=self._spill_options)
            # the batch walk is a jitted device call like any tick step;
            # both tick engines share this path, so counting it keeps
            # fused/legacy dispatch counts comparable (it was silently
            # uncounted before, understating spill-heavy workloads)
            self.stats.dispatches += 1
        n = len(done)
        first = np.asarray([self._spill[k].first_ts for k in done],
                           np.float64)
        out.add_batch(np.asarray(done, np.int64),
                      np.asarray(res.labels)[:n],
                      np.asarray(res.recircs)[:n],
                      np.asarray(res.exit_partition)[:n], first)
        for key in done:
            del self._spill[key]
            self._retired.add(key)

    def _evict_timeouts(self, now: float, out: _VerdictAccum) -> None:
        stale = np.nonzero((self.table.key >= 0)
                           & (now - self._last_ts > self.timeout))[0]
        if stale.size:
            neg = np.full(stale.size, -1, np.int32)
            out.add_batch(self.table.key[stale], neg,
                          self._recircs[stale], neg, self._first_ts[stale])
            for slot in stale:
                self._evict(int(slot))
            self.stats.evicted += int(stale.size)
        for key, f in list(self._spill.items()):
            if now - f.last_ts > self.timeout:
                out.add(key, -1, 0, -1, f.first_ts)
                del self._spill[key]
                self._retired.add(key)
                self.stats.evicted += 1

"""Continuous batching over a fixed slot pool.

The SpliDT analogy is deliberate (DESIGN.md §4): a switch supports
millions of flows with a FIXED register pool, time-sharing state across
flows; this server supports an open request stream with a FIXED pool of
B cache slots, admitting new requests into freed slots every step.
Admission hashes request ids into the slot table exactly like the
paper's CRC-indexed flow store.

Per engine tick:
  1. admit: pop queued requests into free slots (per-slot prefill);
  2. decode: ONE batched decode step over all live slots;
  3. retire: slots whose request hit EOS/max_len free their registers.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model_zoo
from repro.serve.serve_step import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    eos: int = -1
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    admitted: int = 0
    completed: int = 0
    decode_tokens: int = 0
    slot_occupancy: list = dataclasses.field(default_factory=list)


class ContinuousBatcher:
    """CPU-scale reference engine (reduced configs; the sharded path uses
    the same step functions under the production mesh)."""

    def __init__(self, cfg: ArchConfig, params, *, slots: int,
                 max_len: int, temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        zoo = model_zoo.get_model(cfg)
        # one cache per slot (batch=1) -> admission never reshapes others
        self.caches = [zoo.init_cache(cfg, 1, max_len) for _ in range(slots)]
        self.live: list[Request | None] = [None] * slots
        self.last_tok = np.zeros((slots, 1), np.int32)
        self.queue: deque[Request] = deque()
        self.prefill = jax.jit(make_prefill_step(cfg))
        self.decode = jax.jit(make_decode_step(cfg, temperature))
        self.zoo = zoo
        self.rng = jax.random.key(seed)
        self.stats = EngineStats()

    def submit(self, req: Request):
        self.queue.append(req)

    # -- engine tick --------------------------------------------------------
    def tick(self):
        self._admit()
        self._decode_all()
        self._retire()
        self.stats.ticks += 1
        self.stats.slot_occupancy.append(
            sum(r is not None for r in self.live))

    def _admit(self):
        for s in range(self.slots):
            if self.live[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            cache = self.zoo.init_cache(self.cfg, 1, self.max_len)
            toks = jnp.asarray(np.asarray(req.prompt, np.int32)[None])
            lg, cache = self.prefill(self.params, {"tokens": toks}, cache)
            nxt = int(jnp.argmax(lg[0, -1]))
            self.caches[s] = cache
            self.live[s] = req
            req.out.append(nxt)
            self.last_tok[s, 0] = nxt
            self.stats.admitted += 1

    def _decode_all(self):
        for s in range(self.slots):
            req = self.live[s]
            if req is None or req.done:
                continue
            self.rng, sub = jax.random.split(self.rng)
            nxt, cache = self.decode(
                self.params, jnp.asarray(self.last_tok[s:s + 1]),
                self.caches[s], sub)
            self.caches[s] = cache
            tok = int(nxt[0, 0])
            req.out.append(tok)
            self.last_tok[s, 0] = tok
            self.stats.decode_tokens += 1

    def _retire(self):
        for s in range(self.slots):
            req = self.live[s]
            if req is None:
                continue
            if (len(req.out) >= req.max_new
                    or (req.eos >= 0 and req.out and req.out[-1] == req.eos)):
                req.done = True
                self.live[s] = None      # register reuse: slot freed
                self.stats.completed += 1

    def run_until_drained(self, max_ticks: int = 1000) -> EngineStats:
        while (self.queue or any(self.live)) and self.stats.ticks < max_ticks:
            self.tick()
        return self.stats

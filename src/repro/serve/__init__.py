"""Serving surface: batch streaming + live flow-table inference.

One import path for everything a serving deployment touches — the
unified :class:`~repro.core.inference.EngineOptions` knobs, the batch
micro-batching pipeline (``run_streaming`` / ``stream_batches``) and
the per-packet :class:`FlowTableServer`.  The LM-serving prototypes
(``serve.batching`` / ``serve.serve_step``) stay out of this namespace
so importing ``repro.serve`` never pulls their heavier dependencies.
"""
from repro.core.inference import Engine, EngineOptions, EngineResult
from repro.serve.flowtable import (
    FlowTable,
    FlowTableServer,
    ServerStats,
    StreamVerdict,
    StreamVerdicts,
)
from repro.serve.streaming import run_streaming, stream_batches

__all__ = [
    "Engine",
    "EngineOptions",
    "EngineResult",
    "FlowTable",
    "FlowTableServer",
    "ServerStats",
    "StreamVerdict",
    "StreamVerdicts",
    "run_streaming",
    "stream_batches",
]

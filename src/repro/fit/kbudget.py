"""SpliDT's k-distinct-feature register budget, enforced in-jit.

Every SpliDT subtree must fit its features into the ``k`` register
slots the data plane time-shares across partitions (paper §2.2), so the
trainer caps the number of *distinct* features per tree.  The numpy
oracle enforces this greedily in level order: each node sees the set of
features used by every node decided before it (above it, or to its
left on the same level); once that set reaches ``k``, only those
features remain candidates.

Greedy acquisition is inherently sequential -- node ``i``'s candidate
mask depends on node ``i-1``'s choice -- so it cannot ride the
vectorised split scoring in ``repro.fit.hist``.  Instead
:func:`budget_level` replays it as a ``lax.scan`` over the level's
frontier slots carrying a per-feature "used" mask: tiny (``F`` steps of
O(m) work) next to the histogram reduction, and exactly the oracle's
semantics because empty/padded slots decline to split and therefore
never advance the mask.

This is also where every other per-node split gate lives (purity,
``min_samples_leaf``, ``min_gain``), so the scan's accept decision is
the single point that must mirror ``core.tree.train_tree``'s leaf
checks -- see the contract list in ``core/tree.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def budget_level(
    used_mask: jnp.ndarray,     # (m,) bool  features used so far (tree-wide)
    gain: jnp.ndarray,          # (F, m) f32 best gain per (node, feature)
    bins: jnp.ndarray,          # (F, m) i32 best split bin per (node, feature)
    nl: jnp.ndarray,            # (F, m) i32 left-child size at that bin
    total: jnp.ndarray,         # (F, C) i32 per-node class counts
    *,
    allowed_mask: jnp.ndarray,  # (m,) bool
    k_features: int,
    min_samples_leaf: int,
    min_gain32: jnp.ndarray,    # f32 scalar
):
    """Greedy per-node feature selection for one frontier level.

    Scans the level's slots in heap order (== the numpy trainer's BFS
    queue order).  For each node: restrict candidates to the budget
    (``allowed`` while the distinct-feature count is below
    ``k_features``, else ``allowed & used``), take the first-argmax
    feature over masked gains (lowest feature index wins ties), then
    apply the oracle's leaf gates -- purity, ``2*min_samples_leaf``
    node size, strict ``min_gain`` improvement, per-child
    ``min_samples_leaf``.  Accepted splits update the used mask that
    the NEXT slot sees.

    Returns ``(used_mask, feat (F,) i32 [-1 = leaf], bin (F,) i32)``.
    """
    m = used_mask.shape[0]
    msl = jnp.int32(min_samples_leaf)

    def one(used, xs):
        g_row, b_row, nl_row, tot = xs
        n_node = tot.sum()
        pure = (tot > 0).sum() <= 1
        budget_open = used.sum() < k_features
        cand = jnp.where(budget_open, allowed_mask, allowed_mask & used)
        g = jnp.where(cand, g_row, -jnp.inf)
        j = jnp.argmax(g).astype(jnp.int32)          # first max: lowest fid
        gj = g[j]
        nlj = nl_row[j]
        nrj = n_node - nlj
        ok = ((~pure) & (n_node >= 2 * msl) & (gj > min_gain32)
              & (nlj >= msl) & (nrj >= msl))
        feat = jnp.where(ok, j, jnp.int32(-1))
        used = used | (ok & (jnp.arange(m, dtype=jnp.int32) == j))
        return used, (feat, jnp.where(ok, b_row[j], jnp.int32(0)))

    used_mask, (feat, bin_out) = jax.lax.scan(
        one, used_mask, (gain, bins, nl, total))
    return used_mask, feat, bin_out


def distinct_feature_count(feature: jnp.ndarray, n_features: int) -> jnp.ndarray:
    """Number of distinct features a flat ``feature`` array uses (>= 0
    entries) -- the quantity the budget caps; handy for property tests."""
    f = jnp.asarray(feature)
    onehot = (f[:, None] == jnp.arange(n_features, dtype=jnp.int32)[None, :]) \
        & (f[:, None] >= 0)
    return onehot.any(axis=0).sum()

"""repro.fit -- jitted batched tree induction + batched DSE evaluation.

The training half of SpliDT on the accelerator: a level-synchronous
histogram grower (``hist``: binning -> per-node class histograms ->
``lax.scan`` over depth on a fixed node arena), the in-jit k-distinct-
feature register budget (``kbudget``), and the ``vmap`` fleets
(``batched``: whole-partition subtree fleets for
``train_partitioned_dt(trainer="jax")``, and whole-candidate-batch
scoring for ``core.dse.bayes_search``).

Structurally identical to the numpy oracle (``core.tree.train_tree``)
node-for-node -- the shared contract (binning, f32 split scores,
tie-breaks, level-order greedy budget) is stated in ``core/tree.py``
and enforced zero-tolerance by ``tests/test_fit.py``.
"""
from repro.fit.batched import (
    fleet_predict, pack_model_fleet, train_forest, train_tree_jax,
)
from repro.fit.hist import arena_to_tree, grow_arena, grow_forest_arenas
from repro.fit.kbudget import budget_level, distinct_feature_count

__all__ = [
    "arena_to_tree", "budget_level", "distinct_feature_count",
    "fleet_predict", "grow_arena", "grow_forest_arenas",
    "pack_model_fleet", "train_forest", "train_tree_jax",
]

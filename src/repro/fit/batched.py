"""Batched training + batched DSE scoring (the ``vmap`` layer of repro.fit).

Two fleets live here:

* **subtree fleets** -- :func:`train_forest` stacks the subsets a
  partition's subtrees train on (padded to a common capacity, inert
  rows masked) and runs the jitted level-synchronous grower
  (``repro.fit.hist``) once, ``vmap``'d over the subtree axis.
  ``train_partitioned_dt(trainer="jax")`` calls it once per partition,
  so Algorithm 1 becomes P dispatches instead of one Python-loop tree
  at a time.
* **DSE candidate fleets** -- :func:`fleet_predict` packs a *batch* of
  trained :class:`PartitionedDT` models into one stacked
  ``DeviceTables`` (padded to the batch's max S/k/T/L, exit actions
  re-encoded for the shared subtree count) and scores all of them
  against the test flows in ONE jitted, ``vmap``-over-models partition
  walk -- the same ``fused_step`` engine the serving path runs, so the
  labels are bit-identical to ``PartitionedDT.predict`` and the
  per-candidate Python evaluation loop disappears from
  ``core.dse.bayes_search``.

Padding safety: padded subtrees are never reached (SIDs stay
model-local), padded threshold slots are ``+inf`` (mark 0, wildcard
leaf intervals), padded leaves are ``valid=0``, and extra partitions
walk flows that have all exited (trained models exit every flow by
their last partition), so verdicts and recirculation counts match the
serial engine exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import MAX_BINS, Tree
from repro.fit import hist


def _next_pow2(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length() if x > 1 else 1


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# ---------------------------------------------------------------------------
# subtree fleets
# ---------------------------------------------------------------------------
# per-level histogram elements allowed per grower dispatch; fleets whose
# (S, 2**(d-1), m, nbins, C) working set exceeds it run in chunks
_HIST_BUDGET = 16_000_000


def train_forest(
    Xs: list[np.ndarray],
    ys: list[np.ndarray],
    *,
    max_depth: int,
    k_features: int | None = None,
    n_classes: int,
    min_samples_leaf: int = 4,
    min_gain: float = 1e-7,
    max_bins: int = MAX_BINS,
    allowed_features: np.ndarray | None = None,
) -> list[Tree]:
    """Train one tree per ``(Xs[i], ys[i])`` subset in one vmapped dispatch.

    Each subset is quantile-binned on its own rows (the shared contract
    binning -- identical edges to what the numpy trainer would compute),
    padded to a common row capacity and bin count, and grown by
    ``hist.grow_forest_arenas``.  Structural parity with
    ``core.tree.train_tree`` is node-for-node (see docs/PARITY.md).
    """
    S = len(Xs)
    if S == 0:
        return []
    m = int(np.asarray(Xs[0]).shape[1])
    C = int(n_classes)
    allowed_mask = np.zeros(m, dtype=bool)
    if allowed_features is None:
        allowed_mask[:] = True
    else:
        allowed_mask[np.asarray(allowed_features, dtype=np.int64)] = True

    if max_depth < 1:
        return [hist.leaf_tree(y, C) for y in ys]

    edges_list: list[list[np.ndarray]] = []
    binned_list: list[np.ndarray] = []
    for Xf in Xs:
        e, b = hist.bin_for_growth(np.asarray(Xf), max_bins)
        edges_list.append(e)
        binned_list.append(b)

    nbins = max(max((len(e) for e in edges), default=0)
                for edges in edges_list) + 1
    nbins = _round_up(nbins, 8)           # stabilise the jit cache
    n_cap = _next_pow2(max(b.shape[0] for b in binned_list))

    binned = np.zeros((S, n_cap, m), dtype=np.int32)
    yb = np.zeros((S, n_cap), dtype=np.int32)
    valid = np.zeros((S, n_cap), dtype=bool)
    for i, (b, y) in enumerate(zip(binned_list, ys)):
        ni = b.shape[0]
        binned[i, :ni] = b
        yb[i, :ni] = np.asarray(y, dtype=np.int32)
        valid[i, :ni] = True

    kk = int(k_features) if k_features is not None else m + 1
    # chunk the fleet if one level's histogram would blow the memory
    # budget (S * 2**(d-1) * m * nbins * C int32 live at once)
    per_tree = (1 << (max_depth - 1)) * m * nbins * C
    s_chunk = max(1, min(S, _HIST_BUDGET // max(per_tree, 1)))
    s_chunk = _next_pow2(s_chunk + 1) // 2 if s_chunk > 1 else 1  # floor pow2

    trees: list[Tree] = []
    am = jnp.asarray(allowed_mask)
    for lo in range(0, S, s_chunk):
        hi = min(lo + s_chunk, S)
        pad = s_chunk - (hi - lo)         # keep ONE compiled shape per fleet
        sl = slice(lo, hi)
        chunk = (np.concatenate([binned[sl], np.zeros_like(binned[:pad])])
                 if pad else binned[sl])
        ych = (np.concatenate([yb[sl], np.zeros_like(yb[:pad])])
               if pad else yb[sl])
        vch = (np.concatenate([valid[sl], np.zeros_like(valid[:pad])])
               if pad else valid[sl])
        feats, bins, counts, last_counts, _ = jax.device_get(
            hist.grow_forest_arenas(
                jnp.asarray(chunk), jnp.asarray(ych), jnp.asarray(vch), am,
                depth=int(max_depth), n_classes=C, nbins=int(nbins),
                k_features=kk, min_samples_leaf=int(min_samples_leaf),
                min_gain=float(min_gain)))
        for i in range(hi - lo):
            trees.append(hist.arena_to_tree(
                feats[i], bins[i], counts[i], last_counts[i],
                edges_list[lo + i], C))
    return trees


def train_tree_jax(X, y, *, max_depth, k_features=None,
                   allowed_features=None, n_classes=None,
                   min_samples_leaf=4, min_gain=1e-7,
                   max_bins=MAX_BINS) -> Tree:
    """Single-tree convenience wrapper: ``core.tree.train_tree``'s jitted
    twin (same signature, structurally identical output)."""
    y = np.asarray(y, dtype=np.int64)
    C = int(n_classes if n_classes is not None else y.max() + 1)
    return train_forest([np.asarray(X)], [y], max_depth=max_depth,
                        k_features=k_features, n_classes=C,
                        min_samples_leaf=min_samples_leaf,
                        min_gain=min_gain, max_bins=max_bins,
                        allowed_features=allowed_features)[0]


# ---------------------------------------------------------------------------
# DSE candidate fleets
# ---------------------------------------------------------------------------
def pack_model_fleet(pdts: list) -> tuple:
    """Pack a batch of models into ONE stacked ``DeviceTables``.

    Pads every model to the batch's max subtree count ``S``, slot count
    ``k``, threshold count ``T`` and leaf count ``L``, and re-encodes
    exit actions (``action >= S_model`` means exit) for the shared
    ``S``: labels survive as ``action - S`` regardless of which model
    emitted them.  Returns ``(DeviceTables with leading model axis,
    n_subtrees)``.
    """
    from repro.core.range_tables import pack_range_exec
    from repro.core.tables import pack_tables
    from repro.kernels import ops

    packs = [(pack_tables(p), pack_range_exec(p)) for p in pdts]
    S = max(t.n_subtrees for t, _ in packs)
    k = max(t.k for t, _ in packs)
    T = max(r.max_thresholds for _, r in packs)
    L = max(r.max_leaves for _, r in packs)

    def pad_model(t, r):
        s0, k0 = t.slot_op.shape
        l0, t0 = r.leaf_action.shape[1], r.thresholds.shape[2]
        slot_op = np.zeros((S, k), np.int32)
        slot_field = np.zeros((S, k), np.int32)
        slot_pred = np.zeros((S, k), np.int32)
        slot_init = np.zeros((S, k), np.float32)
        thresholds = np.full((S, k, T), np.inf, np.float32)
        leaf_lo = np.zeros((S, L, k), np.int32)
        leaf_hi = np.full((S, L, k), T, np.int32)
        leaf_action = np.full((S, L), -1, np.int32)
        leaf_valid = np.zeros((S, L), np.int32)
        slot_op[:s0, :k0] = t.slot_op
        slot_field[:s0, :k0] = t.slot_field
        slot_pred[:s0, :k0] = t.slot_pred
        slot_init[:s0, :k0] = t.slot_init
        thresholds[:s0, :k0, :t0] = r.thresholds
        leaf_lo[:s0, :l0, :k0] = r.leaf_lo
        leaf_hi[:s0, :l0, :k0] = r.leaf_hi
        # exits were encoded against the model's own subtree count
        act = r.leaf_action.astype(np.int64)
        act = np.where((act >= r.n_subtrees) & (act >= 0),
                       act - r.n_subtrees + S, act)
        leaf_action[:s0, :l0] = act.astype(np.int32)
        leaf_valid[:s0, :l0] = r.leaf_valid.astype(np.int32)
        return (slot_op, slot_field, slot_pred, slot_init, thresholds,
                leaf_lo, leaf_hi, leaf_action, leaf_valid)

    stacked = [np.stack(arrs) for arrs in
               zip(*(pad_model(t, r) for t, r in packs))]
    dev = ops.DeviceTables(*(jnp.asarray(a) for a in stacked))
    return dev, S


@functools.partial(jax.jit, static_argnames=("n_subtrees",))
def _fleet_walk(win_pkts, devs, *, n_subtrees):
    from repro.core.inference import _partition_walk
    from repro.kernels import ops

    def one(dev):
        labels, recircs, exit_p, _ = _partition_walk(
            win_pkts, dev, n_subtrees=n_subtrees, with_trace=False,
            step=ops.fused_step)
        return labels, recircs, exit_p

    return jax.vmap(one)(devs)


def fleet_predict(pdts: list, win_pkts: np.ndarray):
    """Score a batch of models against one flow batch in ONE dispatch.

    ``win_pkts``: (B, P, W, F) from ``flows.windows.window_packets``
    with ``P >= max(model.n_partitions)``.  Every model walks all P
    hops -- flows have exited by the model's own last partition, so the
    extra hops are no-ops and the verdicts are bit-identical to the
    serial engine / ``PartitionedDT.predict``.  Returns
    ``(labels (M, B), recircs (M, B), exit_partition (M, B))`` int32
    numpy arrays.
    """
    dev, S = pack_model_fleet(pdts)
    labels, recircs, exit_p = jax.device_get(
        _fleet_walk(jnp.asarray(win_pkts), dev, n_subtrees=S))
    return np.asarray(labels), np.asarray(recircs), np.asarray(exit_p)

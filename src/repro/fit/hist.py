"""Jitted level-synchronous histogram tree induction.

The XGBoost/LightGBM formulation of CART mapped onto JAX: features are
quantile-binned on the host (``core.tree.quantile_bins`` -- the shared
cross-trainer contract), then the whole tree grows inside one jitted
program as a ``lax.scan`` over depth on a fixed ``2**(d+1)-1`` heap
arena (node ``a``'s children are ``2a+1`` / ``2a+2``):

* every sample carries its current arena position; one scatter-add
  builds the level's ``(node, feature, bin, class)`` histogram;
* per-(node, feature) best splits fall out of a cumulative-sum
  reduction over bins -- the same f32 ``split_scores`` math as the
  numpy oracle, class chain pinned left-to-right
  (:func:`repro.core.tree.class_sq_chain`), so both trainers compare
  identical bits;
* the k-distinct-feature register budget is applied by a sequential
  in-jit pass over the level's frontier (``repro.fit.kbudget``),
  matching the numpy trainer's level-order greedy semantics;
* samples descend (``bin <= split_bin`` == ``x <= edges[split_bin]``,
  exactly) and the next level repeats.

The result is **structurally identical** to
:func:`repro.core.tree.train_tree` -- same feature/threshold/left/
right/value arrays, node for node (tie-break: lowest bin, then lowest
feature; see the contract in ``core/tree.py`` and docs/PARITY.md).
``repro.fit.batched`` vmaps :func:`grow_arena` over whole subtree
fleets.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tree import MAX_BINS, Tree, bin_data, quantile_bins
from repro.fit import kbudget


def class_sq_chain(counts: jnp.ndarray) -> jnp.ndarray:
    """``sum_c counts[...,c]^2`` as a left-to-right f32 chain.

    The jnp twin of :func:`repro.core.tree.class_sq_chain`: the only
    order-sensitive reduction in the split score, pinned so XLA cannot
    re-associate it away from the numpy oracle's bits.
    """
    acc = jnp.zeros(counts.shape[:-1], jnp.float32)
    for c in range(counts.shape[-1]):
        x = counts[..., c].astype(jnp.float32)
        acc = acc + x * x
    return acc


def _level_hist(binned, y, seg, *, frontier, nbins, n_classes):
    """(node, feature, bin, class) counts for one level.

    ``binned`` (n, m) int32, ``y`` (n,) int32, ``seg`` (n,) int32 --
    frontier-local node index, or ``frontier`` for inactive samples
    (their flattened index lands out of range and the scatter drops
    it).  Returns (frontier, m, nbins, n_classes) int32.
    """
    n, m = binned.shape
    j = jnp.arange(m, dtype=jnp.int32)[None, :]
    idx = ((seg[:, None] * m + j) * nbins + binned) * n_classes + y[:, None]
    flat = jnp.zeros(frontier * m * nbins * n_classes, jnp.int32)
    flat = flat.at[idx.ravel()].add(1, mode="drop")
    return flat.reshape(frontier, m, nbins, n_classes)


def _level_scores(hist: jnp.ndarray):
    """Best split per (node, feature) from the level histogram.

    The jnp twin of :func:`repro.core.tree.split_scores` +
    :func:`repro.core.tree.node_impurity`, vectorised over the frontier
    and feature axes.  ``hist`` (F, m, nbins, C) int32.  Returns
    ``(gain (F, m) f32, bin (F, m) i32, nl (F, m) i32,
    total (F, C) i32)`` where ``bin`` is the first (lowest) argmin of
    the child impurity and ``gain`` is ``-inf`` where no valid split
    exists.
    """
    # splint: allow[R001]: int32 count histogram — integer adds are
    # exact in any order; the f32 scoring below goes via class_sq_chain
    cum = jnp.cumsum(hist, axis=2)                       # (F, m, nbins, C)
    total = cum[:, 0, -1, :]                             # (F, C)
    nl = cum.sum(axis=3)                                 # (F, m, nbins)
    n_node = total.sum(axis=1)                           # (F,)
    nr = n_node[:, None, None] - nl
    sl = class_sq_chain(cum)
    sr = class_sq_chain(total[:, None, None, :] - cum)
    one = jnp.float32(1.0)
    nl_f = nl.astype(jnp.float32)
    nr_f = nr.astype(jnp.float32)
    child = ((nl_f - sl / jnp.maximum(nl_f, one))
             + (nr_f - sr / jnp.maximum(nr_f, one)))
    child = jnp.where((nl > 0) & (nr > 0), child, jnp.inf)
    e = jnp.argmin(child, axis=2).astype(jnp.int32)      # first min
    child_best = jnp.take_along_axis(child, e[..., None], axis=2)[..., 0]
    n_f = n_node.astype(jnp.float32)
    parent = n_f - class_sq_chain(total) / jnp.maximum(n_f, one)
    gain = parent[:, None] - child_best                  # -inf when no split
    nl_best = jnp.take_along_axis(nl, e[..., None], axis=2)[..., 0]
    return gain, e, nl_best, total


def grow_arena(
    binned: jnp.ndarray,        # (n, m) int32 bin ids
    y: jnp.ndarray,             # (n,) int32 class labels
    valid: jnp.ndarray,         # (n,) bool  (False rows are padding)
    allowed_mask: jnp.ndarray,  # (m,) bool  candidate features
    *,
    depth: int,
    n_classes: int,
    nbins: int,
    k_features: int,
    min_samples_leaf: int,
    min_gain: float,
):
    """Grow one tree level-synchronously on the heap arena (jit-traceable).

    Returns ``(feat (depth, F), bin (depth, F), counts (depth, F, C),
    last_counts (2**depth, C), used_mask (m,))`` with
    ``F = 2**(depth-1)`` -- level ``l``'s slot ``i`` is arena node
    ``2**l - 1 + i`` (slots beyond ``2**l`` are inert padding).
    ``feat == -1`` marks leaves; ``last_counts`` covers the bottom
    (never-split) level.  Host code assembles a :class:`Tree` via
    :func:`arena_to_tree`.
    """
    n, m = binned.shape
    if depth < 1:
        raise ValueError("grow_arena needs depth >= 1 (depth-0 trees are "
                         "a single leaf; handle on the host)")
    F = 1 << (depth - 1)
    min_gain32 = jnp.float32(min_gain)
    y = y.astype(jnp.int32)
    binned = binned.astype(jnp.int32)

    def level(carry, l):
        pos, at_leaf, used = carry
        base = jnp.left_shift(jnp.int32(1), l) - 1
        local = pos - base
        active = (~at_leaf) & valid
        seg = jnp.where(active, local, F)
        hist = _level_hist(binned, y, seg, frontier=F, nbins=nbins,
                           n_classes=n_classes)
        gain, bins, nl, total = _level_scores(hist)
        used, feat, bin_out = kbudget.budget_level(
            used, gain, bins, nl, total, allowed_mask=allowed_mask,
            k_features=k_features, min_samples_leaf=min_samples_leaf,
            min_gain32=min_gain32)
        # descend: split samples move to a child, leaf samples freeze
        slot = jnp.clip(local, 0, F - 1)
        f = feat[slot]
        is_split = active & (f >= 0)
        bsel = jnp.take_along_axis(binned, jnp.maximum(f, 0)[:, None],
                                   axis=1)[:, 0]
        go_left = bsel <= bin_out[slot]              # == x <= edges[bin]
        child = 2 * pos + 1 + jnp.where(go_left, 0, 1).astype(jnp.int32)
        pos = jnp.where(is_split, child, pos)
        at_leaf = at_leaf | (active & (f < 0))
        return (pos, at_leaf, used), (feat, bin_out, total)

    init = (jnp.zeros(n, jnp.int32), jnp.zeros(n, jnp.bool_),
            jnp.zeros(m, jnp.bool_))
    (pos, at_leaf, used), (feats, bins, counts) = jax.lax.scan(
        level, init, jnp.arange(depth, dtype=jnp.int32))

    # class counts of the bottom level (children of depth-1 splits)
    lastbase = (1 << depth) - 1
    seg = jnp.where((~at_leaf) & valid, pos - lastbase, 1 << depth)
    idx = seg * n_classes + y
    last = jnp.zeros((1 << depth) * n_classes, jnp.int32)
    last = last.at[idx].add(jnp.where(seg < (1 << depth), 1, 0), mode="drop")
    last_counts = last.reshape(1 << depth, n_classes)
    return feats, bins, counts, last_counts, used


@functools.partial(
    jax.jit,
    static_argnames=("depth", "n_classes", "nbins", "k_features",
                     "min_samples_leaf", "min_gain"))
def grow_forest_arenas(binned, y, valid, allowed_mask, *, depth, n_classes,
                       nbins, k_features, min_samples_leaf, min_gain):
    """vmap of :func:`grow_arena` over a stacked subtree fleet.

    ``binned`` (S, n, m), ``y`` (S, n), ``valid`` (S, n);
    ``allowed_mask`` (m,) is shared.  One dispatch trains the whole
    fleet -- this is what ``train_partitioned_dt(trainer="jax")`` calls
    once per partition.
    """
    grow = functools.partial(
        grow_arena, depth=depth, n_classes=n_classes, nbins=nbins,
        k_features=k_features, min_samples_leaf=min_samples_leaf,
        min_gain=min_gain)
    return jax.vmap(grow, in_axes=(0, 0, 0, None))(
        binned, y, valid, allowed_mask)


def arena_to_tree(feats: np.ndarray, bins: np.ndarray, counts: np.ndarray,
                  last_counts: np.ndarray, edges: list[np.ndarray],
                  n_classes: int) -> Tree:
    """Assemble the compact :class:`Tree` from arena outputs (host side).

    Reachable arena nodes are renumbered in ascending heap order, which
    is exactly the numpy trainer's BFS level-order numbering (left
    child before right), so the resulting arrays are comparable
    element-for-element.
    """
    D, F = feats.shape
    A = (1 << (D + 1)) - 1
    feat_h = np.full(A, -1, dtype=np.int64)
    bin_h = np.zeros(A, dtype=np.int64)
    val_h = np.zeros((A, n_classes), dtype=np.float32)
    for lvl in range(D):
        base = (1 << lvl) - 1
        cnt = 1 << lvl
        feat_h[base:base + cnt] = feats[lvl, :cnt]
        bin_h[base:base + cnt] = bins[lvl, :cnt]
        val_h[base:base + cnt] = counts[lvl, :cnt]
    val_h[(1 << D) - 1:] = last_counts

    exists = np.zeros(A, dtype=bool)
    exists[0] = True
    order: list[int] = []
    for a in range(A):                      # ascending == level order
        if not exists[a]:
            continue
        order.append(a)
        if feat_h[a] >= 0:
            exists[2 * a + 1] = True
            exists[2 * a + 2] = True
    new_id = {a: i for i, a in enumerate(order)}

    n_nodes = len(order)
    feature = np.full(n_nodes, -1, dtype=np.int32)
    threshold = np.zeros(n_nodes, dtype=np.float32)
    left = np.full(n_nodes, -1, dtype=np.int32)
    right = np.full(n_nodes, -1, dtype=np.int32)
    value = np.zeros((n_nodes, n_classes), dtype=np.float32)
    for a in order:
        i = new_id[a]
        value[i] = val_h[a]
        f = int(feat_h[a])
        if f >= 0:
            feature[i] = f
            threshold[i] = np.float32(edges[f][int(bin_h[a])])
            left[i] = new_id[2 * a + 1]
            right[i] = new_id[2 * a + 2]
    return Tree(feature=feature, threshold=threshold, left=left, right=right,
                value=value, n_classes=n_classes)


def leaf_tree(y: np.ndarray, n_classes: int) -> Tree:
    """Depth-0 degenerate tree: a single leaf holding the class counts."""
    counts = np.bincount(np.asarray(y, dtype=np.int64),
                         minlength=n_classes).astype(np.float32)
    return Tree(feature=np.asarray([-1], np.int32),
                threshold=np.zeros(1, np.float32),
                left=np.asarray([-1], np.int32),
                right=np.asarray([-1], np.int32),
                value=counts[None, :], n_classes=n_classes)


def bin_for_growth(X: np.ndarray, max_bins: int = MAX_BINS):
    """Host-side contract binning for one subtree's subset.

    Returns ``(edges, binned int32)`` via the shared
    :func:`repro.core.tree.quantile_bins` / :func:`bin_data` -- the
    numpy trainer computes the identical edges from the identical
    subset, which is what makes thresholds bit-equal across trainers.
    """
    X = np.asarray(X, dtype=np.float32)
    edges = quantile_bins(X, max_bins)
    binned = bin_data(X, edges).astype(np.int32)
    return edges, binned

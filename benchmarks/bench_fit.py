"""Training + DSE throughput: numpy oracle vs the jitted repro.fit fleet.

Two grids, written to ``BENCH_fit.json`` (override with the
BENCH_FIT_JSON env var) alongside the CSV rows:

* ``fit/tree/<depth>x<k>x<n>/{numpy,jax}`` -- single-tree trainer
  throughput (trees/s) across a depth x k x n grid, plus a
  ``fit/forest/...`` row for the vmapped fleet (trees/s with the whole
  fleet in one dispatch vs looping the numpy trainer);
* ``fit/dse/{serial,batched}`` -- DSE candidate evaluation (evals/s):
  the per-candidate ``PartitionedDT.predict`` loop vs
  ``evaluate_batch`` scoring the whole candidate batch through the
  jitted engine in one vmapped dispatch.

``--smoke`` (CI) shrinks the grid to one point per family so the paths
stay exercised; jit compile time is excluded by the warm-up call in
``timed``.  Parity is not re-checked here -- ``tests/test_fit.py``
holds the trainers bit-identical, so these rows can only differ in
speed.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import Row, dataset, timed, windowed
from repro.core.dse import Config, make_splidt_evaluator
from repro.core.tree import train_tree
from repro.flows.windows import window_packets

JSON_PATH_ENV = "BENCH_FIT_JSON"
DEFAULT_JSON_PATH = "BENCH_fit.json"


def _write_json(results: list[dict], mode: str) -> str:
    import jax
    path = os.environ.get(JSON_PATH_ENV, DEFAULT_JSON_PATH)
    payload = {
        "bench": "fit",
        "mode": mode,
        "jax_backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "cpu_count": os.cpu_count(),
        "results": results,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def run(quick: bool = True, smoke: bool = False):
    from repro.fit import train_forest, train_tree_jax

    rows: list[Row] = []
    results: list[dict] = []

    def add(name: str, us: float, unit_per_call: float, unit: str, **extra):
        per_s = unit_per_call / (us / 1e6) if us > 0 else 0.0
        derived = f"{unit}_per_s={per_s:.1f}"
        for key, val in extra.items():
            derived += f";{key}={val}"
        rows.append(Row(name, us, derived))
        results.append({"name": name, "us_per_call": round(us, 1),
                        f"{unit}_per_s": round(per_s, 1), **extra})

    rng = np.random.default_rng(0)
    repeat = 1 if smoke else 3

    # ---- single-tree trainer grid: depth x k x n --------------------
    if smoke:
        grid = [(4, 3, 512)]
    elif quick:
        grid = [(3, 2, 512), (5, 4, 2048), (7, 4, 8192)]
    else:
        grid = [(3, 2, 2048), (5, 4, 8192), (7, 4, 32768), (8, 6, 32768)]
    m, C = 16, 4
    for depth, k, n in grid:
        X = rng.normal(size=(n, m)).astype(np.float32)
        y = rng.integers(0, C, n)
        kw = dict(max_depth=depth, k_features=k, n_classes=C)
        _, us_np = timed(train_tree, X, y, repeat=repeat, **kw)
        _, us_jx = timed(train_tree_jax, X, y, repeat=repeat, **kw)
        tag = f"{depth}x{k}x{n}"
        add(f"fit/tree/{tag}/numpy", us_np, 1.0, "trees",
            depth=depth, k=k, n=n)
        add(f"fit/tree/{tag}/jax", us_jx, 1.0, "trees",
            depth=depth, k=k, n=n,
            speedup_vs_numpy=round(us_np / max(us_jx, 1e-9), 2))

    # ---- fleet: S subtrees in one vmapped dispatch ------------------
    S = 4 if smoke else 16
    depth, k, n = (4, 3, 256) if smoke else (5, 4, 1024)
    Xs = [rng.normal(size=(n, m)).astype(np.float32) for _ in range(S)]
    ys = [rng.integers(0, C, n) for _ in range(S)]
    kw = dict(max_depth=depth, k_features=k, n_classes=C)
    _, us_loop = timed(
        lambda: [train_tree(Xf, yf, **kw) for Xf, yf in zip(Xs, ys)],
        repeat=repeat)
    _, us_fleet = timed(train_forest, Xs, ys, repeat=repeat, **kw)
    add(f"fit/forest/S{S}/numpy_loop", us_loop, float(S), "trees",
        S=S, depth=depth, k=k, n=n)
    add(f"fit/forest/S{S}/jax_vmap", us_fleet, float(S), "trees",
        S=S, depth=depth, k=k, n=n,
        speedup_vs_loop=round(us_loop / max(us_fleet, 1e-9), 2))

    # ---- DSE evaluation: serial predict loop vs one batched dispatch
    n_flows = 400 if smoke else 2500
    ds, tr, te = dataset("d2", n_flows=n_flows)
    P = 3
    Xw_tr, Xw_te = windowed("d2", P, n_flows=n_flows)
    wp_te = window_packets(te, P)
    batch = 16                            # paper: 16 parallel evaluations
    cfgs = [Config(int(rng.integers(2, 5)),
                   tuple(int(d) for d in rng.integers(
                       2, 4, int(rng.integers(1, P + 1)))))
            for _ in range(batch)]
    kw = dict(n_classes=ds.n_classes, flows=100_000)
    ev_serial = make_splidt_evaluator(Xw_tr, tr.labels, Xw_te, te.labels,
                                      **kw)
    ev_batched = make_splidt_evaluator(Xw_tr, tr.labels, Xw_te, te.labels,
                                       trainer="jax", win_pkts_te=wp_te,
                                       **kw)
    _, us_serial = timed(lambda: [ev_serial(c) for c in cfgs], repeat=repeat)
    _, us_batched = timed(ev_batched.evaluate_batch, cfgs, repeat=repeat)
    add("fit/dse/serial", us_serial, float(batch), "evals", batch=batch,
        predict_dispatches_per_round=batch)
    # the whole candidate batch is scored by ONE vmapped partition walk
    # (fit.batched.fleet_predict); training remains P fleet dispatches
    # per candidate
    add("fit/dse/batched", us_batched, float(batch), "evals", batch=batch,
        predict_dispatches_per_round=1,
        speedup_vs_serial=round(us_serial / max(us_batched, 1e-9), 2))

    path = _write_json(results, "smoke" if smoke else
                       ("quick" if quick else "full"))
    rows.append(Row("fit/json", 0.0, f"path={path};rows={len(results)}"))
    return rows

"""Shared benchmark plumbing: datasets, timing, CSV rows."""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable



@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str     # free-form "key=value;key=value" payload

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.1f},{self.derived}"


def timed(fn: Callable, *args, repeat: int = 3, **kw) -> tuple[Any, float]:
    out = fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def timed_min(fn: Callable, *args, rounds: int = 3, **kw) -> float:
    """Min-of-rounds μs/call (one warm-up call excluded).

    For a deterministic workload the minimum is the noise-robust
    estimator — a mean lets one GC pause or scheduler hiccup
    manufacture a fake 2x difference.  Used wherever two configs are
    *compared* (the engine/auto routing grid); ``timed``'s mean stays
    for plain throughput rows.
    """
    fn(*args, **kw)
    ts = []
    for _ in range(max(rounds, 1)):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    return min(ts)


@functools.lru_cache(maxsize=None)
def dataset(name: str, n_flows: int = 2500):
    from repro.flows.synthetic import make_dataset
    ds = make_dataset(name, n_flows=n_flows)
    return ds, *ds.split()


@functools.lru_cache(maxsize=None)
def windowed(name: str, p: int, n_flows: int = 2500):
    from repro.flows.windows import window_features
    ds, tr, te = dataset(name, n_flows)
    return window_features(tr, p), window_features(te, p)


@functools.lru_cache(maxsize=None)
def profile_dataset(profile: str, n_flows: int = 2500):
    """Exit-rate profile workload (front / uniform / back-loaded)."""
    from repro.flows.synthetic import make_profile_dataset
    return make_profile_dataset(profile, n_flows=n_flows)


@functools.lru_cache(maxsize=None)
def profile_model(profile: str, n_flows: int = 2500,
                  ps: tuple = (3, 3, 3), k: int = 4):
    from repro.core.partition import train_partitioned_dt
    from repro.flows.windows import window_features
    ds = profile_dataset(profile, n_flows)
    tr, _ = ds.split()
    Xw = window_features(tr, len(ps))
    return train_partitioned_dt(Xw, tr.labels, partition_sizes=list(ps),
                                k=k, n_classes=ds.n_classes)


@functools.lru_cache(maxsize=None)
def splidt_model(name: str, ps: tuple, k: int, n_flows: int = 2500,
                 max_dep: int | None = None):
    from repro.core.partition import train_partitioned_dt
    ds, tr, te = dataset(name, n_flows)
    Xw_tr, _ = windowed(name, len(ps), n_flows)
    return train_partitioned_dt(Xw_tr, tr.labels, partition_sizes=list(ps),
                                k=k, n_classes=ds.n_classes,
                                max_dep_depth=max_dep)

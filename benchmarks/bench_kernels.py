"""Kernel microbenchmarks: wall-time of the jnp reference path on CPU
(the Pallas kernels target TPU; interpret-mode timing is not meaningful).
End-to-end engine throughput lives in ``bench_engine``; the pallas
interpret row stays here as a correctness-path smoke signal."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, dataset, splidt_model, timed
from repro.core.inference import Engine
from repro.flows.windows import window_packets
from repro.kernels import ops


def run(quick: bool = True, smoke: bool = False):
    rows = []
    rng = np.random.default_rng(0)

    # chunk_scan (the LM-side kernel): tokens/sec on CPU ref path
    B, T, d = (2, 128, 32) if smoke else (4, 512, 64)
    q = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.9, 0.999, (B, T, d)), jnp.float32)
    fn = lambda: jax.block_until_ready(
        ops.chunk_scan(q, k, v, w, chunk=128, impl="ref")[0])
    _, us = timed(fn, repeat=1 if smoke else 5)
    rows.append(Row("kernel/chunk_scan_ref", us,
                    f"tokens_per_s={B * T / (us / 1e6):.0f}"))

    # the engine's pallas dispatch path (interpret mode off-TPU);
    # non-smoke uses the default n_flows to share the lru_cache entry
    # with the other bench modules
    name = "d2"
    if smoke:
        _, _, te = dataset(name, n_flows=400)
        pdt = splidt_model(name, (3, 3, 3), 4, n_flows=400)
    else:
        _, _, te = dataset(name)
        pdt = splidt_model(name, (3, 3, 3), 4)
    wp = window_packets(te, 3)
    eng_p = Engine.from_model(pdt, impl="pallas")
    _, us_p = timed(lambda: eng_p.run(wp), repeat=1)
    rows.append(Row("engine/pallas_interpret_inference", us_p,
                    f"flows_per_s={te.n_flows / (us_p / 1e6):.0f}"))
    return rows

"""Kernel microbenchmarks: wall-time of the jnp reference path on CPU
(the Pallas kernels target TPU; interpret-mode timing is not meaningful)
plus the data-plane engine's end-to-end flow throughput."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, dataset, splidt_model, timed, windowed
from repro.core.inference import Engine
from repro.flows.windows import window_packets
from repro.kernels import ops


def run(quick: bool = True):
    rows = []
    rng = np.random.default_rng(0)

    # chunk_scan (the LM-side kernel): tokens/sec on CPU ref path
    B, T, d = 4, 512, 64
    q = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.9, 0.999, (B, T, d)), jnp.float32)
    fn = lambda: jax.block_until_ready(
        ops.chunk_scan(q, k, v, w, chunk=128, impl="ref")[0])
    _, us = timed(fn, repeat=5)
    rows.append(Row("kernel/chunk_scan_ref", us,
                    f"tokens_per_s={B * T / (us / 1e6):.0f}"))

    # feature_window + dt_traverse through the engine
    name = "d2"
    ds, tr, te = dataset(name)
    pdt = splidt_model(name, (3, 3, 3), 4)
    wp = window_packets(te, 3)
    eng = Engine.from_model(pdt, impl="ref")
    _, us = timed(lambda: eng.run(wp), repeat=2)
    rows.append(Row("engine/ref_full_inference", us,
                    f"flows_per_s={te.n_flows / (us / 1e6):.0f};"
                    f"n_flows={te.n_flows}"))
    eng_p = Engine.from_model(pdt, impl="pallas")
    _, us_p = timed(lambda: eng_p.run(wp), repeat=1)
    rows.append(Row("engine/pallas_interpret_inference", us_p,
                    f"flows_per_s={te.n_flows / (us_p / 1e6):.0f}"))
    return rows

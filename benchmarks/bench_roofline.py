"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (produced by `python -m
repro.launch.dryrun --all`) and emits one row per single-pod cell with
the three terms, the bottleneck, and MODEL_FLOPS/HLO_FLOPs."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def run(quick: bool = True):
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*__16x16.json"))):
        rec = json.load(open(path))
        cell = f"{rec['arch']}/{rec['shape']}"
        if rec.get("status") == "skipped":
            rows.append(Row(f"roofline/{cell}", 0.0, "skipped"))
            continue
        r = rec.get("roofline")
        if not r:
            continue
        rows.append(Row(
            f"roofline/{cell}", 0.0,
            f"t_compute={r['t_compute_s']:.4f};t_memory={r['t_memory_s']:.4f};"
            f"t_collective={r['t_collective_s']:.4f};"
            f"bound={r['bottleneck']};"
            f"useful_frac={r['useful_flops_fraction']:.3f};"
            f"roofline_frac={r['roofline_fraction']:.4f}"))
    if not rows:
        rows.append(Row("roofline/none", 0.0,
                        "run `python -m repro.launch.dryrun --all` first"))
    return rows

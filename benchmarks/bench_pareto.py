"""Paper Fig. 6 / Table 3: F1 vs flow-target Pareto — SpliDT vs the
one-shot top-k baselines (NetBeacon-/Leo-style) on d1-d3 analogues."""
from __future__ import annotations


from benchmarks.common import Row, dataset, splidt_model, timed, windowed
from repro.core.baselines import best_oneshot_for_flows
from repro.core.resources import estimate
from repro.core.tree import macro_f1
from repro.flows.windows import full_flow_features

# SpliDT config grid per flow target (DSE-selected shapes: deep subtrees,
# few partitions at low flow counts; shallow low-k, dependency-free
# features at 1M where the register budget binds)
GRID = {
    100_000: [((6, 6), 6, None), ((5, 5, 5), 6, None), ((8, 8), 4, None)],
    500_000: [((6, 6), 3, None), ((4, 4, 4), 3, 0), ((5, 5), 2, 0)],
    1_000_000: [((6, 6), 2, 0), ((13,), 2, 0), ((8, 8), 1, 0)],
}


def run(quick: bool = True):
    rows = []
    names = ["d1", "d2"] if quick else ["d1", "d2", "d3"]
    targets = [100_000, 1_000_000] if quick else sorted(GRID)
    for name in names:
        ds, tr, te = dataset(name)
        Xf_tr, Xf_te = full_flow_features(tr), full_flow_features(te)
        for flows in targets:
            best_f1, best_cfg = -1.0, None
            t_total = 0.0
            for ps, k, max_dep in GRID[flows]:
                (pdt), us = timed(splidt_model, name, ps, k,
                                  max_dep=max_dep, repeat=1)
                t_total += us
                rep = estimate(pdt, flows=flows)
                if not rep.feasible:
                    continue
                _, Xw_te = windowed(name, len(ps))
                f1 = macro_f1(te.labels, pdt.predict(Xw_te), ds.n_classes)
                if f1 > best_f1:
                    best_f1, best_cfg = f1, (ps, k)
            for style in ("nb", "leo"):
                _, f1_b = best_oneshot_for_flows(
                    Xf_tr, tr.labels, Xf_te, te.labels, flows=flows,
                    style=style, n_classes=ds.n_classes,
                    k_grid=(1, 2, 4, 6), depth_grid=(3, 8, 13))
                rows.append(Row(
                    f"pareto/{name}/{flows}/{style}", 0.0,
                    f"f1={max(f1_b, 0):.3f}"))
            rows.append(Row(
                f"pareto/{name}/{flows}/splidt", t_total,
                f"f1={best_f1:.3f};cfg={best_cfg}"))
    return rows

"""Engine throughput across execution backends + streaming schedulers.

Rows (flows/sec):
  * ``engine/looped``   — per-partition host sync (baseline)
  * ``engine/fused``    — single jitted scan, dense jnp step
  * ``engine/pallas``   — same scan, Pallas kernels + in-jit SID
                          dispatch (interpret mode off-TPU, so absolute
                          numbers are only meaningful on TPU; the row is
                          a correctness-path smoke signal elsewhere)
  * ``engine/streaming``          — fused walk over fixed micro-batches
  * ``engine/streaming_sharded``  — same, shard_map'd over all devices
                                    (emitted when >1 device is visible,
                                    e.g. XLA_FLAGS=--xla_force_host_
                                    platform_device_count=8; on a
                                    single-device mesh the speedup
                                    fields are null — a speedup vs
                                    itself is meaningless)
  * ``engine/fused@B=...``        — batch-size sweep of the fused walk
  * ``engine/compact/<profile>/<backend>`` — early-exit compaction
    (``compact=True``) vs the dense walk, on the three exit-rate
    profile workloads (front / uniform / back-loaded; see
    ``flows.synthetic.make_profile_dataset``); ``speedup_vs_dense`` and
    the realised per-partition ``exit_frac`` land in the JSON
  * ``engine/auto/<S>/<B>/<profile>`` — cost-model routing
    (``impl="auto"``, ``repro.tuning``) over the (small-S, large-S) x
    (small-B, large-B) x exit-profile grid: each cell times the forced
    backends AND the auto route, records the chosen plan, the
    measured-best fixed backend, ``auto_vs_best`` (>= ~1.0 within
    noise means the router did its job), and the cost-model estimate
    per backend (``est``) so crossover points are readable straight
    from the JSON.  Off-TPU the pallas column is interpret mode and
    only measured at small B (compile cost unrolls with the grid);
    the cost model knows this and routes around it.  The S axis labels
    the *requested* partition depths (``ps``); realized ``S`` is
    data-dependent and recorded per row — at full dataset sizes the
    largeS config reaches S≈25-33 on uniform/back workloads, while
    FRONT-loaded profiles inherently collapse to S≈1-2 regardless of
    depth (nearly every flow exits in partition 0, so later partitions
    retain no subtrees; read those cells by their recorded ``S``, not
    the label).
  * ``engine/tuned`` — the cached empirical autotuner
    (``impl="tuned"``): cold-call latency (probe + persist), warm
    cached-hit throughput, the winning plan, and a bit-exactness check
    against the backend it routed to

Besides the CSV rows, results are dumped to ``BENCH_engine.json``
(override with the BENCH_ENGINE_JSON env var) so the perf trajectory is
tracked across PRs; CI uploads the smoke run as a workflow artifact.

Note on sharded speedup: the walk is embarrassingly parallel over
flows, so sharded/single tracks the number of physical cores XLA's
single-device intra-op parallelism leaves idle.  On a 2-core container
the single-device walk already saturates the socket and the ratio is
~1.1x; on hosts with >= 8 cores (or real multi-accelerator meshes) it
exceeds 1.5x.  ``cpu_count`` lands in the JSON for exactly this reason.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (
    Row, dataset, profile_dataset, profile_model, splidt_model, timed,
    timed_min,
)
from repro.core.inference import Engine, EngineOptions
from repro.flows.synthetic import EXIT_PROFILES
from repro.flows.windows import window_packets
from repro.serve.streaming import run_streaming

JSON_PATH_ENV = "BENCH_ENGINE_JSON"
DEFAULT_JSON_PATH = "BENCH_engine.json"
METRICS_PATH_ENV = "METRICS_ENGINE_JSON"
DEFAULT_METRICS_PATH = "METRICS_engine.json"


def _tiled_windows(te, p: int, n_flows: int) -> np.ndarray:
    """Tile the test split's window tensor up to ``n_flows`` flows."""
    wp = window_packets(te, p)
    reps = -(-n_flows // wp.shape[0])
    return np.tile(wp, (reps, 1, 1, 1))[:n_flows]


def _write_json(results: list[dict], mode: str) -> str:
    import jax
    path = os.environ.get(JSON_PATH_ENV, DEFAULT_JSON_PATH)
    payload = {
        "bench": "engine",
        "mode": mode,
        "jax_backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "cpu_count": os.cpu_count(),
        "results": results,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def _write_metrics(mode: str) -> str:
    """Snapshot the engine-side observability registry accumulated over
    the whole bench run (per-hop survivors, compaction bucket occupancy,
    dispatch counts — see ``docs/OBSERVABILITY.md``)."""
    from repro import obs
    path = os.environ.get(METRICS_PATH_ENV, DEFAULT_METRICS_PATH)
    with open(path, "w") as f:
        json.dump({"bench": "engine", "mode": mode,
                   "registry": obs.get_registry().snapshot()}, f, indent=2)
        f.write("\n")
    return path


def run(quick: bool = True, smoke: bool = False):
    import jax
    from repro import obs

    # fresh registry: the artifact carries exactly this run's walks
    obs.set_registry(obs.MetricRegistry())

    rows: list[Row] = []
    results: list[dict] = []

    def add(name: str, us: float, B: int, **extra):
        flows_per_s = B / (us / 1e6)
        derived = f"flows_per_s={flows_per_s:.0f};B={B}"
        for key, val in extra.items():
            derived += f";{key}={val}"
        rows.append(Row(name, us, derived))
        results.append({"name": name, "us_per_call": round(us, 1),
                        "flows_per_s": round(flows_per_s), "B": B, **extra})

    name, p, k = "d2", 3, 4
    # smoke: small dataset; otherwise the DEFAULT n_flows so the
    # lru_cache hit is shared with the other bench modules
    if smoke:
        _, _, te = dataset(name, n_flows=400)
        pdt = splidt_model(name, (3,) * p, k, n_flows=400)
    else:
        _, _, te = dataset(name)
        pdt = splidt_model(name, (3,) * p, k)
    eng = Engine.from_model(pdt, impl="ref")

    B = 256 if smoke else (10_000 if quick else 100_000)
    wp = _tiled_windows(te, p, B)
    repeat = 1 if smoke else 3

    _, us_loop = timed(lambda: eng.run_looped(wp, with_trace=False),
                       repeat=repeat)
    add("engine/looped", us_loop, B)

    _, us_fused = timed(lambda: eng.run(wp, with_trace=False), repeat=repeat)
    add("engine/fused", us_fused, B, speedup_vs_looped=round(
        us_loop / us_fused, 2))

    # pallas walk: interpret mode off-TPU unrolls the grid at trace time,
    # so cap the batch to keep compile time sane on CPU
    Bp = min(B, 256 if smoke else 2048)
    wpp = wp[:Bp]
    _, us_pal = timed(lambda: eng.run(wpp, with_trace=False,
                            options=EngineOptions(impl="pallas")),
                      repeat=repeat)
    add("engine/pallas", us_pal, Bp, interpret=int(
        jax.default_backend() != "tpu"))

    mb = 128 if smoke else 4096
    _, us_stream = timed(
        lambda: run_streaming(eng, wp, options=EngineOptions(micro_batch=mb)), repeat=repeat)
    add("engine/streaming", us_stream, B, micro_batch=mb)

    from repro.distributed.sharding import flow_batch_devices
    from repro.launch.mesh import make_flow_mesh
    mesh = make_flow_mesh()
    n_mesh = flow_batch_devices(mesh)
    # the sharded path prefers a larger micro-batch (each chunk
    # splits n_devices ways, so per-device slices stay cache-resident
    # where a single device's working set would spill); measure the
    # single-device baseline at BOTH sizes and report the speedup
    # against the best single-device config, so the tracked metric
    # can't flatter sharding by picking a degraded baseline
    mb_s = mb if smoke else 8192
    us_base = us_stream
    if mb_s != mb:
        _, us_base = timed(
            lambda: run_streaming(eng, wp,
                                  options=EngineOptions(micro_batch=mb_s)),
            repeat=repeat)
        add(f"engine/streaming@mb={mb_s}", us_base, B, micro_batch=mb_s)
    _, us_shard = timed(
        lambda: run_streaming(eng, wp, options=EngineOptions(
            micro_batch=mb_s, mesh=mesh)),
        repeat=repeat)
    # a 1-device mesh shards against itself: the "speedup" would be pure
    # timer noise around 1.0, so record null rather than a number
    # downstream dashboards would read as signal
    add("engine/streaming_sharded", us_shard, B, micro_batch=mb_s,
        n_devices=n_mesh,
        speedup_vs_single=(
            None if n_mesh < 2
            else round(min(us_stream, us_base) / us_shard, 2)),
        speedup_vs_single_same_mb=(
            None if n_mesh < 2 else round(us_base / us_shard, 2)))

    # batch sweep: how the fused walk's flows/sec scales with B
    sweep = [256] if smoke else ([1_000, 10_000] if quick
                                 else [10_000, 100_000])
    for Bs in sweep:
        wps = wp[:Bs] if Bs <= B else _tiled_windows(te, p, Bs)
        _, us = timed(lambda: eng.run(wps, with_trace=False), repeat=repeat)
        add(f"engine/fused@B={Bs}", us, Bs)

    # ------------------------------------------------------------------
    # early-exit compaction: exit-rate profile x walk backend
    # ------------------------------------------------------------------
    # Compaction's payoff is entirely a function of WHEN flows exit, so
    # it is measured on the three profile workloads rather than the d2
    # model above (whose exits cluster in the later partitions).  The
    # dense (compact=False) run of the SAME model/windows is the
    # baseline; `exit_frac` records the realised per-partition exit
    # rates so the speedup can be read against the workload shape.
    # Caveat (see module docstring on pallas): off-TPU the pallas rows
    # run in interpret mode — smoke-signal only.
    n_prof = 400 if smoke else 2500
    Bc = 256 if smoke else (20_000 if quick else 50_000)
    Bcp = 256 if smoke else 1024          # pallas interpret-mode cap
    for profile in EXIT_PROFILES:
        pdt_c = profile_model(profile, n_prof)
        _, te_c = profile_dataset(profile, n_prof).split()
        wp_c = _tiled_windows(te_c, 3, Bc)
        eng_c = Engine.from_model(pdt_c, impl="ref")
        dense, us_dense = timed(lambda: eng_c.run(wp_c, with_trace=False),
                                repeat=repeat)
        exit_frac = [round(float(np.mean(dense.exit_partition == q)), 3)
                     for q in range(pdt_c.n_partitions)]
        add(f"engine/compact/{profile}/dense", us_dense, Bc,
            exit_frac=exit_frac)
        _, us_comp = timed(
            lambda: eng_c.run(wp_c, with_trace=False,
                              options=EngineOptions(compact=True)),
            repeat=repeat)
        add(f"engine/compact/{profile}/fused", us_comp, Bc,
            exit_frac=exit_frac,
            speedup_vs_dense=round(us_dense / us_comp, 2))
        # pallas rows run a smaller slice (interpret-mode compile cost),
        # so their exit_frac is recomputed on that slice; the dense
        # pallas baseline is emitted too, otherwise the tracked speedup
        # ratio could stay flat while both sides regress
        wp_cp = wp_c[:Bcp]
        interp = int(jax.default_backend() != "tpu")
        pd_res, us_pd = timed(
            lambda: eng_c.run(wp_cp, with_trace=False,
                              options=EngineOptions(impl="pallas")),
            repeat=repeat)
        exit_frac_p = [round(float(np.mean(pd_res.exit_partition == q)), 3)
                       for q in range(pdt_c.n_partitions)]
        add(f"engine/compact/{profile}/pallas_dense", us_pd, Bcp,
            exit_frac=exit_frac_p, interpret=interp)
        _, us_pc = timed(
            lambda: eng_c.run(wp_cp, with_trace=False,
                              options=EngineOptions(impl="pallas",
                                                    compact=True)),
            repeat=repeat)
        add(f"engine/compact/{profile}/pallas", us_pc, Bcp,
            exit_frac=exit_frac_p, interpret=interp,
            speedup_vs_dense=round(us_pd / us_pc, 2))

    # ------------------------------------------------------------------
    # cost-model auto-routing: (S, B, profile) grid
    # ------------------------------------------------------------------
    # The acceptance bar for impl="auto": beat or match the best FIXED
    # backend within benchmark noise in every cell.  Forced rows are
    # measured in the same process right before the auto row, so cache
    # warmth is identical; `auto_vs_best` is best_fixed_us / auto_us
    # (>= 1.0 means auto won; ~0.6+ is within this box's noise band).
    from repro.tuning import Plan, ShapeInfo, estimate_us

    on_tpu = jax.default_backend() == "tpu"
    Bs_small = 256 if smoke else 512
    Bs_large = 512 if smoke else (8192 if quick else 32768)
    pallas_cap = Bs_small if not on_tpu else Bs_large
    for S_name, ps in (("smallS", (2, 2, 2)), ("largeS", (4, 4, 4))):
        for profile in EXIT_PROFILES:
            pdt_a = profile_model(profile, n_prof, ps=ps)
            _, te_a = profile_dataset(profile, n_prof).split()
            for B_name, Bv in (("smallB", Bs_small), ("largeB", Bs_large)):
                wp_a = _tiled_windows(te_a, len(ps), Bv)
                eng_a = Engine.from_model(pdt_a)
                # auto_vs_best is the tracked acceptance metric, so
                # every entry in `fixed` uses the SAME estimator
                # (common.timed_min), with the fused/auto pair
                # additionally interleaved (A/B/A/B) so load drift
                # between their timing windows cancels
                rounds = max(repeat, 2)
                fixed: dict[str, float] = {}
                run_fused = lambda: eng_a.run(
                    wp_a, with_trace=False,
                    options=EngineOptions(impl="fused"))
                run_auto = lambda: eng_a.run(
                    wp_a, with_trace=False,
                    options=EngineOptions(impl="auto"))
                res_a = run_auto()                       # warm both paths
                run_fused()
                t_f, t_a = [], []
                for _ in range(rounds):
                    t0 = time.perf_counter(); run_fused()
                    t_f.append((time.perf_counter() - t0) * 1e6)
                    t0 = time.perf_counter(); run_auto()
                    t_a.append((time.perf_counter() - t0) * 1e6)
                fixed["fused"], us_auto = min(t_f), min(t_a)
                if Bv <= pallas_cap:
                    fixed["pallas"] = timed_min(
                        lambda: eng_a.run(
                            wp_a, with_trace=False,
                            options=EngineOptions(impl="pallas")),
                        rounds=rounds)
                if B_name == "smallB":      # host-sync path: too slow to
                    fixed["looped"] = timed_min(   # time at large B
                        lambda: eng_a.run_looped(wp_a, with_trace=False),
                        rounds=rounds)
                shape = ShapeInfo.from_engine(eng_a, wp_a)
                est = {b: round(estimate_us(shape, Plan(backend=b)))
                       for b in ("looped", "fused", "pallas")}
                best = min(fixed, key=fixed.get)
                add(f"engine/auto/{S_name}/{B_name}/{profile}", us_auto, Bv,
                    S=shape.S, ps=list(ps), chosen=res_a.plan.backend,
                    chosen_block_b=res_a.plan.block_b,
                    best_fixed=best,
                    auto_vs_best=round(fixed[best] / us_auto, 2),
                    fixed_us={b: round(v, 1) for b, v in fixed.items()},
                    est=est)

    # ------------------------------------------------------------------
    # cached empirical autotuner (impl="tuned")
    # ------------------------------------------------------------------
    import tempfile

    from repro.tuning.autotune import CACHE_ENV

    with tempfile.TemporaryDirectory() as td:
        tune_path = os.path.join(td, "autotune.json")
        old = os.environ.get(CACHE_ENV)
        os.environ[CACHE_ENV] = tune_path
        try:
            Bt = 256 if smoke else 4096
            wpt = wp[:Bt]
            t0 = time.perf_counter()
            cold = eng.run(wpt, with_trace=False,
                           options=EngineOptions(impl="tuned"))
            cold_us = (time.perf_counter() - t0) * 1e6
            _, us_tuned = timed(
                lambda: eng.run(wpt, with_trace=False,
                                options=EngineOptions(impl="tuned")),
                repeat=repeat)
            warm = eng.run(wpt, with_trace=False,
                           options=EngineOptions(impl="tuned"))
            # tuned must be bit-identical to the backend it routed to
            forced = eng.run(wpt, with_trace=False,
                             options=EngineOptions(impl=warm.plan.backend))
            exact = bool(
                np.array_equal(warm.labels, forced.labels)
                and np.array_equal(warm.recircs, forced.recircs)
                and np.array_equal(warm.exit_partition,
                                   forced.exit_partition))
            add("engine/tuned", us_tuned, Bt,
                plan=warm.plan.describe(), source=warm.plan.source,
                cold_call_us=round(cold_us, 1),
                bit_identical_to_routed=exact,
                cold_source=cold.plan.source)
        finally:
            if old is None:
                os.environ.pop(CACHE_ENV, None)
            else:
                os.environ[CACHE_ENV] = old

    mode = "smoke" if smoke else ("quick" if quick else "full")
    path = _write_json(results, mode)
    mpath = _write_metrics(mode)
    import sys
    print(f"# bench_engine: wrote {path}", file=sys.stderr)
    print(f"# bench_engine: wrote {mpath}", file=sys.stderr)
    return rows

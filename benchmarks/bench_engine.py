"""Engine throughput: looped (per-partition host sync) vs fused
(single jitted lax.scan) vs streaming (fused over fixed micro-batches).

The fused path is the tentpole claim: at production flow counts the
partition walk must stay on device, so flows/sec should be bounded by
the kernel math, not by host round-trips.  The streaming row shows the
same math scaling past one device batch (memory high-water = one
micro-batch)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, dataset, splidt_model, timed
from repro.core.inference import Engine
from repro.flows.windows import window_packets
from repro.serve.streaming import run_streaming


def _tiled_windows(te, p: int, n_flows: int) -> np.ndarray:
    """Tile the test split's window tensor up to ``n_flows`` flows."""
    wp = window_packets(te, p)
    reps = -(-n_flows // wp.shape[0])
    return np.tile(wp, (reps, 1, 1, 1))[:n_flows]


def run(quick: bool = True, smoke: bool = False):
    rows = []
    name, p, k = "d2", 3, 4
    # smoke: small dataset; otherwise the DEFAULT n_flows so the
    # lru_cache hit is shared with the other bench modules
    if smoke:
        _, _, te = dataset(name, n_flows=400)
        pdt = splidt_model(name, (3,) * p, k, n_flows=400)
    else:
        _, _, te = dataset(name)
        pdt = splidt_model(name, (3,) * p, k)
    eng = Engine.from_model(pdt, impl="ref")

    B = 256 if smoke else (10_000 if quick else 100_000)
    wp = _tiled_windows(te, p, B)
    repeat = 1 if smoke else 3

    def flows_per_s(us: float) -> str:
        return f"flows_per_s={B / (us / 1e6):.0f};B={B}"

    _, us_loop = timed(lambda: eng.run_looped(wp, with_trace=False),
                       repeat=repeat)
    rows.append(Row("engine/looped", us_loop, flows_per_s(us_loop)))

    _, us_fused = timed(lambda: eng.run(wp, with_trace=False), repeat=repeat)
    rows.append(Row("engine/fused", us_fused, flows_per_s(us_fused)))

    mb = 128 if smoke else 4096
    _, us_stream = timed(
        lambda: run_streaming(eng, wp, micro_batch=mb), repeat=repeat)
    rows.append(Row("engine/streaming", us_stream,
                    flows_per_s(us_stream) + f";micro_batch={mb}"))

    rows.append(Row("engine/fused_speedup", us_fused,
                    f"speedup_vs_looped={us_loop / us_fused:.2f}x"))
    return rows

# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only pareto,...]

Modules map to the paper's tables/figures:
    bench_pareto      — Fig 6 / Table 3 (F1 vs flows, SpliDT vs NB/Leo)
    bench_resources   — Fig 9 (TCAM), Fig 11 (registers), Fig 12
                        (precision), Table 1 (feature density)
    bench_recirc_ttd  — Table 5 (recirc bandwidth), Fig 10 (TTD)
    bench_dse         — Fig 7 (BO convergence), Table 4 (stage timing)
    bench_kernels     — kernel + engine micro-benchmarks
    bench_roofline    — EXPERIMENTS.md §Roofline table (from dry-run)
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = ["pareto", "resources", "recirc_ttd", "dse", "kernels", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full dataset/table sizes (slower)")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    only = [m.strip() for m in args.only.split(",") if m.strip()]

    print("name,us_per_call,derived")
    failures = []
    for mod in MODULES:
        if only and mod not in only:
            continue
        t0 = time.time()
        try:
            m = __import__(f"benchmarks.bench_{mod}", fromlist=["run"])
            for row in m.run(quick=not args.full):
                print(row.csv(), flush=True)
            print(f"# bench_{mod} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures.append(mod)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()

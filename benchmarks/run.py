# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness.

    PYTHONPATH=src python -m benchmarks.run [--full|--smoke] [--only ...]

Modules map to the paper's tables/figures:
    bench_pareto      — Fig 6 / Table 3 (F1 vs flows, SpliDT vs NB/Leo)
    bench_resources   — Fig 9 (TCAM), Fig 11 (registers), Fig 12
                        (precision), Table 1 (feature density)
    bench_recirc_ttd  — Table 5 (recirc bandwidth), Fig 10 (TTD)
    bench_dse         — Fig 7 (BO convergence), Table 4 (stage timing)
    bench_kernels     — kernel micro-benchmarks
    bench_engine      — looped vs fused vs streaming engine throughput
    bench_fit         — numpy vs jitted trainer, serial vs batched DSE
    bench_roofline    — EXPERIMENTS.md §Roofline table (from dry-run)

``--smoke`` is the CI guard: every module must import, and modules with
smoke support run one tiny iteration; the rest are import-checked only.
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

MODULES = ["pareto", "resources", "recirc_ttd", "dse", "kernels", "engine",
           "fit", "serve", "roofline"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full dataset/table sizes (slower)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: import every module, run one tiny "
                         "iteration where supported")
    ap.add_argument("--only", default="",
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    only = [m.strip() for m in args.only.split(",") if m.strip()]
    unknown = sorted(set(only) - set(MODULES))
    if unknown:
        ap.error(f"unknown --only module(s) {unknown}; "
                 f"options: {','.join(MODULES)}")

    print("name,us_per_call,derived")
    failures = []
    for mod in MODULES:
        if only and mod not in only:
            continue
        t0 = time.time()
        try:
            m = __import__(f"benchmarks.bench_{mod}", fromlist=["run"])
            takes_smoke = "smoke" in inspect.signature(m.run).parameters
            if args.smoke and not takes_smoke:
                print(f"# bench_{mod} import-checked (no smoke mode)",
                      file=sys.stderr)
                continue
            kw = {"smoke": True} if args.smoke else {}
            for row in m.run(quick=not args.full, **kw):
                print(row.csv(), flush=True)
            print(f"# bench_{mod} done in {time.time() - t0:.1f}s",
                  file=sys.stderr)
        except Exception:
            failures.append(mod)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()

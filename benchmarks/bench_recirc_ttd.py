"""Paper Table 5 (recirculation bandwidth, WS/HD, 100K/500K/1M flows)
and Fig. 10 (time-to-detection vs one-shot baselines)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, dataset, splidt_model, windowed
from repro.core.recirc import ENVIRONMENTS, recirc_bandwidth, time_to_detection


def run(quick: bool = True):
    rows = []
    names = ["d1", "d2"] if quick else ["d1", "d2", "d3"]
    for name in names:
        ds, tr, te = dataset(name)
        p = 3
        pdt = splidt_model(name, (4, 4, 4), 4)
        _, Xw_te = windowed(name, p)
        _, recircs, exit_p = pdt.predict(Xw_te, return_trace=True)
        for env_name, env in ENVIRONMENTS.items():
            for flows in (100_000, 500_000, 1_000_000):
                bw = recirc_bandwidth(recircs, flows, env)
                rows.append(Row(
                    f"recirc/{name}/{env_name}/{flows}", 0.0,
                    f"mbps={bw.mean_mbps:.2f};std={bw.std_mbps:.2f};"
                    f"budget_frac={bw.fraction_of_budget:.2e}"))
        # TTD: SpliDT exits early; one-shot detects at flow end
        ttd_s = time_to_detection(te.packets, te.lengths, exit_p, p)
        oneshot = np.full_like(exit_p, p - 1)
        ttd_b = time_to_detection(te.packets, te.lengths, oneshot, p)
        rows.append(Row(
            f"ttd/{name}", 0.0,
            f"splidt_mean_s={ttd_s.mean():.4f};"
            f"oneshot_mean_s={ttd_b.mean():.4f};"
            f"splidt_p99_s={np.quantile(ttd_s, 0.99):.4f};"
            f"oneshot_p99_s={np.quantile(ttd_b, 0.99):.4f}"))
    return rows

"""Live serving throughput: the flow-table server on replayed streams.

The batch engine answers "how fast can we score windows we already
have"; this answers the deployment question — sustained packets/sec
through :class:`repro.serve.FlowTableServer` with verdicts emitted
in-stream.  Rows are written to ``BENCH_serve.json`` (override with the
BENCH_SERVE_JSON env var) alongside the CSV, one per
``<profile>/<impl>/<tick_engine>/t<tick>`` cell:

* ``pkts_per_s`` — sustained ingest throughput over the whole replay
  (all ticks + flush, steady-state: jit warm-up excluded by a priming
  replay on a stream prefix);
* ``verdict_p50_ms`` / ``verdict_p99_ms`` — per-verdict serving
  latency.  A verdict's latency is the wall time of the ingest call
  that emitted it: the time the caller waited on the serving step for
  that answer (arrival-queueing time is a property of the replayed
  trace, not of the server, so it is excluded on purpose);
* ``dispatches_per_tick`` — jitted device calls per ingest tick.  The
  fused tick engine's contract is O(1) (admission + tick step); the
  legacy engine pays per rank and per drain round.  Box timings are
  noisy — this is the deterministic column;
* ``speedup_vs_legacy`` — fused-tick wall-clock gain over the legacy
  engine at the same (profile, impl, tick), on fused rows where the
  matching legacy row ran;
* ``max_resident_flows`` — peak concurrent flows held (table slots +
  host spill), the memory high-water mark;
* ``spilled`` / ``evicted`` — how often the hash table overflowed to
  the host and how many flows timed out mid-stream.

The fused tick engine sweeps tick sizes (64/256/1024) — bigger ticks
amortise the fixed two dispatches over more packets, which is the whole
perf story on dispatch-bound hosts.  Both arrival profiles (``steady``,
``bursty``) run so the tail latency rows capture burst behaviour, not
just the uniform-arrival best case.  Alongside the timing rows, each
timed cell's full ``MetricRegistry.snapshot()`` (TTD histogram, recirc
overhead, dispatch counters — see ``docs/OBSERVABILITY.md``) lands in
``METRICS_serve.json`` (override: METRICS_SERVE_JSON env var), schema-
checked in CI by ``tools/check_metrics.py``.  Verdict parity is not
re-checked
here — ``tests/test_flowtable.py`` and ``tests/test_tick_engine.py``
hold every cell bit-identical to the batch walk."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Row, dataset, splidt_model

JSON_PATH_ENV = "BENCH_SERVE_JSON"
DEFAULT_JSON_PATH = "BENCH_serve.json"
METRICS_PATH_ENV = "METRICS_SERVE_JSON"
DEFAULT_METRICS_PATH = "METRICS_serve.json"

P = 3
TICK_SWEEP = (64, 256, 1024)


def _write_json(results: list[dict], mode: str) -> str:
    import jax
    path = os.environ.get(JSON_PATH_ENV, DEFAULT_JSON_PATH)
    payload = {
        "bench": "serve",
        "mode": mode,
        "jax_backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "cpu_count": os.cpu_count(),
        "results": results,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def _write_metrics(cells: dict, mode: str) -> str:
    """One ``MetricRegistry.snapshot()`` per timed grid cell — the
    observability artifact next to the timing rows.  CI schema-checks
    it (``tools/check_metrics.py``): every cell must carry the TTD
    histogram, the recirc-overhead gauge, and the dispatch counter."""
    path = os.environ.get(METRICS_PATH_ENV, DEFAULT_METRICS_PATH)
    with open(path, "w") as f:
        json.dump({"bench": "serve", "mode": mode, "cells": cells}, f,
                  indent=2)
        f.write("\n")
    return path


def _replay(make_server, stream, tick: int):
    """Replay the stream; return (seconds, verdict latencies, server)."""
    srv = make_server()
    lat: list[float] = []
    t_total = 0.0
    for batch in stream.ticks(tick):
        t0 = time.perf_counter()
        v = srv.ingest(batch)
        dt = time.perf_counter() - t0
        t_total += dt
        lat.extend([dt] * v.n_flows)
    t0 = time.perf_counter()
    v = srv.flush()
    dt = time.perf_counter() - t0
    t_total += dt
    lat.extend([dt] * v.n_flows)
    return t_total, np.asarray(lat), srv


def run(quick: bool = True, smoke: bool = False):
    from repro.core.inference import Engine, EngineOptions
    from repro.flows.synthetic import ARRIVAL_PROFILES, make_packet_stream
    from repro.serve import FlowTableServer

    if smoke:
        n_flows, base_tick, buckets = 96, 64, 8
    elif quick:
        n_flows, base_tick, buckets = 1200, 256, 32
    else:
        n_flows, base_tick, buckets = 4000, 1024, 64

    pdt = splidt_model("d2", (2, 3, 2), 4, n_flows=n_flows)
    eng = Engine.from_model(pdt)
    _, tr, _ = dataset("d2", n_flows)

    rows: list[Row] = []
    results: list[dict] = []
    metrics_cells: dict[str, dict] = {}
    impls = ("fused", "pallas")
    # grid: fused tick engine sweeps tick sizes; the legacy engine runs
    # at the base tick only (it is the baseline, not the product)
    grid = [("fused", t) for t in TICK_SWEEP] + [("legacy", base_tick)]
    if smoke:
        grid = [("fused", base_tick), ("fused", 4 * base_tick),
                ("legacy", base_tick)]
    secs_at = {}    # (profile, impl, tick, tick_engine) -> seconds
    for profile in ARRIVAL_PROFILES:
        stream = make_packet_stream(tr, seed=7, profile=profile)
        for impl in impls:
            cells = grid if impl == "fused" else [("fused", base_tick)]
            for tick_engine, tick in cells:
                def make_server(impl=impl, tick_engine=tick_engine):
                    return FlowTableServer(
                        eng, n_buckets=buckets, bucket_size=8,
                        tick_engine=tick_engine,
                        options=EngineOptions(impl=impl))
                # prime jit caches with an untimed replay so the timed
                # pass is steady-state — the capacity ladder keeps the
                # (rank, width) shapes shared, but only a full pass
                # visits the deep rank chains late in the stream
                _replay(make_server, stream, tick)

                secs, lat, srv = _replay(make_server, stream, tick)
                stats = srv.stats
                secs_at[(profile, impl, tick, tick_engine)] = secs
                pkts_s = stats.packets / secs if secs > 0 else float("inf")
                p50 = float(np.percentile(lat, 50) * 1e3)
                p99 = float(np.percentile(lat, 99) * 1e3)
                dpt = stats.dispatches / max(stats.ticks, 1)
                legacy = secs_at.get((profile, impl, tick, "legacy"))
                speedup = (round(legacy / secs, 2)
                           if tick_engine == "fused" and legacy and secs > 0
                           else None)
                name = f"serve/{profile}/{impl}/{tick_engine}/t{tick}"
                metrics_cells[name] = srv.registry.snapshot()
                rows.append(Row(
                    name, secs / max(stats.verdicts, 1) * 1e6,
                    f"pkts_per_s={pkts_s:.0f};p50_ms={p50:.2f};"
                    f"p99_ms={p99:.2f};disp_per_tick={dpt:.2f};"
                    f"peak_resident={stats.peak_resident}"))
                results.append({
                    "name": name,
                    "profile": profile,
                    "impl": impl,
                    "tick_engine": tick_engine,
                    "n_flows": stats.flows_seen,
                    "n_packets": stats.packets,
                    "tick": tick,
                    "pkts_per_s": round(pkts_s, 1),
                    "verdict_p50_ms": round(p50, 3),
                    "verdict_p99_ms": round(p99, 3),
                    "dispatches_per_tick": round(dpt, 3),
                    "speedup_vs_legacy": speedup,
                    "max_resident_flows": stats.peak_resident,
                    "spilled": stats.spilled,
                    "evicted": stats.evicted,
                })
    # the legacy baseline runs AFTER the fused sweep in each impl block;
    # back-fill the speedup column for the fused rows it bases
    for r in results:
        if r["tick_engine"] != "fused" or r["speedup_vs_legacy"]:
            continue
        legacy = secs_at.get((r["profile"], r["impl"], r["tick"], "legacy"))
        fused = secs_at.get((r["profile"], r["impl"], r["tick"], "fused"))
        if legacy and fused:
            r["speedup_vs_legacy"] = round(legacy / fused, 2)

    mode = "smoke" if smoke else ("quick" if quick else "full")
    path = _write_json(results, mode)
    rows.append(Row("serve/json", 0.0, f"path={path};rows={len(results)}"))
    mpath = _write_metrics(metrics_cells, mode)
    rows.append(Row("serve/metrics", 0.0,
                    f"path={mpath};cells={len(metrics_cells)}"))
    return rows


if __name__ == "__main__":
    import sys
    for row in run(quick="--full" not in sys.argv,
                   smoke="--smoke" in sys.argv):
        print(row.csv())

"""Live serving throughput: the flow-table server on replayed streams.

The batch engine answers "how fast can we score windows we already
have"; this answers the deployment question — sustained packets/sec
through :class:`repro.serve.FlowTableServer` with verdicts emitted
in-stream.  Rows are written to ``BENCH_serve.json`` (override with the
BENCH_SERVE_JSON env var) alongside the CSV, one per
``<profile>/<impl>`` cell:

* ``pkts_per_s`` — sustained ingest throughput over the whole replay
  (all ticks + flush, steady-state: jit warm-up excluded by a priming
  replay on a stream prefix);
* ``verdict_p50_ms`` / ``verdict_p99_ms`` — per-verdict serving
  latency.  A verdict's latency is the wall time of the ingest call
  that emitted it: the time the caller waited on the serving step for
  that answer (arrival-queueing time is a property of the replayed
  trace, not of the server, so it is excluded on purpose);
* ``max_resident_flows`` — peak concurrent flows held (table slots +
  host spill), the memory high-water mark;
* ``spilled`` / ``evicted`` — how often the hash table overflowed to
  the host and how many flows timed out mid-stream.

Both arrival profiles (``steady``, ``bursty``) run so the tail latency
row captures burst behaviour, not just the uniform-arrival best case.
Verdict parity is not re-checked here — ``tests/test_flowtable.py``
holds the server bit-identical to the batch walk."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import Row, dataset, splidt_model

JSON_PATH_ENV = "BENCH_SERVE_JSON"
DEFAULT_JSON_PATH = "BENCH_serve.json"

P = 3


def _write_json(results: list[dict], mode: str) -> str:
    import jax
    path = os.environ.get(JSON_PATH_ENV, DEFAULT_JSON_PATH)
    payload = {
        "bench": "serve",
        "mode": mode,
        "jax_backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "cpu_count": os.cpu_count(),
        "results": results,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    return path


def _replay(make_server, stream, tick: int):
    """Replay the stream; return (seconds, verdict latencies, stats)."""
    srv = make_server()
    lat: list[float] = []
    t_total = 0.0
    for batch in stream.ticks(tick):
        t0 = time.perf_counter()
        v = srv.ingest(batch)
        dt = time.perf_counter() - t0
        t_total += dt
        lat.extend([dt] * v.n_flows)
    t0 = time.perf_counter()
    v = srv.flush()
    dt = time.perf_counter() - t0
    t_total += dt
    lat.extend([dt] * v.n_flows)
    return t_total, np.asarray(lat), srv.stats


def run(quick: bool = True, smoke: bool = False):
    from repro.core.inference import Engine, EngineOptions
    from repro.flows.synthetic import ARRIVAL_PROFILES, make_packet_stream
    from repro.serve import FlowTableServer

    if smoke:
        n_flows, tick, buckets = 96, 64, 8
    elif quick:
        n_flows, tick, buckets = 1200, 256, 32
    else:
        n_flows, tick, buckets = 4000, 512, 64

    pdt = splidt_model("d2", (2, 3, 2), 4, n_flows=n_flows)
    eng = Engine.from_model(pdt)
    _, tr, _ = dataset("d2", n_flows)

    rows: list[Row] = []
    results: list[dict] = []
    impls = ("fused",) if smoke else ("fused", "pallas")
    for profile in ARRIVAL_PROFILES:
        stream = make_packet_stream(tr, seed=7, profile=profile)
        warm = stream.slice(0, min(stream.n_packets, 4 * tick))
        for impl in impls:
            def make_server(impl=impl):
                return FlowTableServer(
                    eng, n_buckets=buckets, bucket_size=8,
                    options=EngineOptions(impl=impl))
            # prime jit caches on a prefix so the timed replay is
            # steady-state (the capacity ladder keeps shapes shared)
            srv = make_server()
            srv.ingest(warm)
            srv.flush()

            secs, lat, stats = _replay(make_server, stream, tick)
            pkts_s = stats.packets / secs if secs > 0 else float("inf")
            p50 = float(np.percentile(lat, 50) * 1e3)
            p99 = float(np.percentile(lat, 99) * 1e3)
            name = f"serve/{profile}/{impl}"
            rows.append(Row(name, secs / max(stats.verdicts, 1) * 1e6,
                            f"pkts_per_s={pkts_s:.0f};p50_ms={p50:.2f};"
                            f"p99_ms={p99:.2f};"
                            f"peak_resident={stats.peak_resident}"))
            results.append({
                "name": name,
                "profile": profile,
                "impl": impl,
                "n_flows": stats.flows_seen,
                "n_packets": stats.packets,
                "tick": tick,
                "pkts_per_s": round(pkts_s, 1),
                "verdict_p50_ms": round(p50, 3),
                "verdict_p99_ms": round(p99, 3),
                "max_resident_flows": stats.peak_resident,
                "spilled": stats.spilled,
                "evicted": stats.evicted,
            })

    path = _write_json(results, "smoke" if smoke else
                       ("quick" if quick else "full"))
    rows.append(Row("serve/json", 0.0, f"path={path};rows={len(results)}"))
    return rows


if __name__ == "__main__":
    import sys
    for row in run(quick="--full" not in sys.argv,
                   smoke="--smoke" in sys.argv):
        print(row.csv())

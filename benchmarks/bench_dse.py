"""Paper Fig. 7 (BO convergence) and Table 4 (per-stage timing)."""
from __future__ import annotations

import time


from benchmarks.common import Row, dataset, windowed
from repro.core.dse import SearchSpace, bayes_search, make_splidt_evaluator


def run(quick: bool = True):
    rows = []
    names = ["d2"] if quick else ["d1", "d2", "d3"]
    for name in names:
        ds, tr, te = dataset(name)
        P = 4
        Xw_tr, Xw_te = windowed(name, P)

        # Table 4-style stage timing for one representative evaluation
        t0 = time.perf_counter()
        ev = make_splidt_evaluator(Xw_tr, tr.labels, Xw_te, te.labels,
                                   n_classes=ds.n_classes, flows=100_000)
        from repro.core.dse import Config
        t_fetch = time.perf_counter() - t0
        t0 = time.perf_counter()
        e = ev(Config(4, (4, 4, 4)))
        t_train_eval = time.perf_counter() - t0
        rows.append(Row(f"dse_stage_timing/{name}", 0.0,
                        f"fetch_s={t_fetch:.3f};train_eval_s={t_train_eval:.3f};"
                        f"f1={e.f1:.3f};tcam={e.tcam_entries}"))

        n_iter = 6 if quick else 24
        t0 = time.perf_counter()
        res = bayes_search(
            ev, SearchSpace(max_partitions=4, k_max=6, depth_max=8),
            n_iterations=n_iter, batch=3, n_init=6, seed=0)
        dt = time.perf_counter() - t0
        pareto = res.pareto()
        rows.append(Row(
            f"dse_convergence/{name}", dt / max(len(res.history), 1) * 1e6,
            f"best_f1={res.best.f1 if res.best else -1:.3f};"
            f"iters_to_best={res.iterations_to_best};"
            f"evals={len(res.history)};pareto_size={len(pareto)};"
            f"total_s={dt:.1f}"))
    return rows

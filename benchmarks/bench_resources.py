"""Paper Fig. 9 (TCAM vs F1), Fig. 11 (register scaling), Fig. 12
(bit-precision sweep), Table 1 (feature density)."""
from __future__ import annotations


from benchmarks.common import Row, dataset, splidt_model, windowed
from repro.core.resources import estimate
from repro.core.tree import macro_f1
from repro.flows.windows import quantize_features
from repro.core.partition import train_partitioned_dt


def run(quick: bool = True):
    rows = []
    name = "d2"
    ds, tr, te = dataset(name)

    # Fig 9: TCAM entries vs F1 across model sizes
    for ps, k in [((3, 3), 2), ((5, 5), 4), ((6, 6), 6), ((5, 5, 5), 6)]:
        pdt = splidt_model(name, ps, k)
        _, Xw_te = windowed(name, len(ps))
        f1 = macro_f1(te.labels, pdt.predict(Xw_te), ds.n_classes)
        rep = estimate(pdt)
        rows.append(Row(f"tcam/{name}/ps{len(ps)}k{k}", 0.0,
                        f"entries={rep.tcam_entries};f1={f1:.3f};"
                        f"tcam_bits={rep.tcam_bits:.0f}"))

    # Fig 11: register bits vs total features (constant-register claim)
    for ps, k in [((2, 2), 4), ((4, 4), 4), ((6, 6), 4), ((5, 5, 5), 4)]:
        pdt = splidt_model(name, ps, k)
        rep = estimate(pdt)
        rows.append(Row(f"registers/{name}/ps{ps}k{k}", 0.0,
                        f"reg_bits={rep.register_bits_per_flow};"
                        f"total_features={len(pdt.unique_features())};"
                        f"capacity={rep.flow_capacity}"))

    # Table 1: feature density per partition / subtree
    pdt = splidt_model(name, (5, 5, 5), 6)
    per_part, per_sub = pdt.feature_density()
    rows.append(Row(f"density/{name}", 0.0,
                    f"per_partition_pct={per_part:.1f};"
                    f"per_subtree_pct={per_sub:.1f};"
                    f"n_subtrees={len(pdt.subtrees)}"))

    # Fig 12: bit precision sweep
    Xw_tr, Xw_te = windowed(name, 2)
    for bits in (32, 16, 8):
        q_tr = quantize_features(Xw_tr, bits)
        q_te = quantize_features(Xw_te, bits)
        pdt = train_partitioned_dt(q_tr, tr.labels, partition_sizes=[5, 5],
                                   k=4, n_classes=ds.n_classes)
        f1 = macro_f1(te.labels, pdt.predict(q_te), ds.n_classes)
        rep = estimate(pdt, bits=bits)
        rows.append(Row(f"precision/{name}/{bits}b", 0.0,
                        f"f1={f1:.3f};capacity={rep.flow_capacity}"))
    return rows

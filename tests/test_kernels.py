"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import features as F
from repro.kernels import ops
from repro.kernels.ref import (
    chunk_scan_chunked_ref, chunk_scan_ref, dt_traverse_ref,
    feature_window_ref,
)
from tests.test_features import random_packets


# ---------------------------------------------------------------------------
# feature_window
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,w,k", [(8, 4, 1), (64, 16, 4), (130, 33, 6),
                                   (256, 8, 8)])
def test_feature_window_pallas_vs_ref(b, w, k):
    rng = np.random.default_rng(b * 1000 + w)
    pk = jnp.asarray(random_packets(rng, b, w))
    op = jnp.asarray(rng.integers(0, F.N_OPS, (b, k)), jnp.int32)
    field = jnp.asarray(rng.integers(0, F.PKT_NFIELDS, (b, k)), jnp.int32)
    pred = jnp.asarray(rng.integers(0, F.N_PREDS, (b, k)), jnp.int32)
    init = jnp.where(op == F.OP_MIN, jnp.float32(np.finfo(np.float32).max), 0.0)
    ref = feature_window_ref(pk, op, field, pred, init)
    from repro.kernels.feature_window import feature_window_pallas
    out = feature_window_pallas(pk, op, field, pred, init, interpret=True,
                                block_b=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6,
                               atol=1e-5)


# ---------------------------------------------------------------------------
# dt_traverse
# ---------------------------------------------------------------------------
def _random_range_tables(rng, S, k, T, L):
    thr = np.sort(rng.normal(size=(S, k, T)).astype(np.float32), axis=2)
    # random valid mark intervals
    lo = rng.integers(0, T, (S, L, k)).astype(np.int32)
    hi = lo + rng.integers(0, T, (S, L, k)).astype(np.int32)
    act = rng.integers(0, S + 5, (S, L)).astype(np.int32)
    valid = (rng.random((S, L)) < 0.8).astype(np.int32)
    valid[:, 0] = 1
    # make leaf 0 a catch-all so every flow matches something
    lo[:, 0, :] = 0
    hi[:, 0, :] = T + 1
    return thr, lo, hi, act, valid


@pytest.mark.parametrize("S,k,T,L,B", [(3, 2, 8, 8, 50), (16, 6, 16, 32, 300),
                                       (7, 4, 8, 16, 128)])
def test_dt_traverse_grouped_pallas_vs_ref(S, k, T, L, B):
    rng = np.random.default_rng(S * 100 + B)
    thr, lo, hi, act, valid = _random_range_tables(rng, S, k, T, L)
    regs = jnp.asarray(rng.normal(size=(B, k)).astype(np.float32))
    sid = jnp.asarray(rng.integers(0, S, B), jnp.int32)
    ref = dt_traverse_ref(regs, jnp.asarray(thr)[sid], jnp.asarray(lo)[sid],
                          jnp.asarray(hi)[sid], jnp.asarray(act)[sid],
                          jnp.asarray(valid)[sid] > 0)

    from repro.core.range_tables import RangeExecTables
    ret = RangeExecTables(thr, lo, hi, act, valid.astype(bool),
                          n_subtrees=S, n_classes=5)
    out = ops.dt_traverse(regs, sid, ret, impl="pallas", block_b=64)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# chunk_scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dk,dv", [(16, 16), (64, 32), (64, 64)])
@pytest.mark.parametrize("T,chunk", [(32, 16), (128, 64), (256, 128)])
@pytest.mark.parametrize("bonus", [False, True])
def test_chunk_scan_pallas_vs_naive(dk, dv, T, chunk, bonus):
    rng = np.random.default_rng(dk + T + bonus)
    B = 2
    q = jnp.asarray(rng.normal(size=(B, T, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, dv)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 0.999, (B, T, dk)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(B, dk)), jnp.float32) if bonus else None
    s0 = jnp.asarray(rng.normal(size=(B, dk, dv)), jnp.float32)
    o_ref, s_ref = chunk_scan_ref(q, k, v, w, u, s0)
    o, s = ops.chunk_scan(q, k, v, w, u, s0, chunk=chunk, impl="pallas")
    scale = float(jnp.abs(o_ref).max())
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               atol=2e-4 * max(scale, 1.0))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=3e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_scan_dtypes(dtype):
    rng = np.random.default_rng(9)
    B, T, d = 2, 64, 32
    q = jnp.asarray(rng.normal(size=(B, T, d)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, d)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, d)), dtype)
    w = jnp.asarray(rng.uniform(0.8, 0.999, (B, T, d)), jnp.float32)
    o_ref, _ = chunk_scan_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), w)
    o, _ = ops.chunk_scan(q.astype(jnp.float32), k.astype(jnp.float32),
                          v.astype(jnp.float32), w, chunk=32, impl="pallas")
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref), atol=5e-3)


def test_chunk_scan_state_continuity():
    """Running two halves with carried state == one full pass — the
    SpliDT window-reuse property on the LM side."""
    rng = np.random.default_rng(11)
    B, T, d = 2, 128, 32
    q = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.7, 0.999, (B, T, d)), jnp.float32)
    o_full, s_full = ops.chunk_scan(q, k, v, w, chunk=32, impl="ref")
    h = T // 2
    o1, s1 = ops.chunk_scan(q[:, :h], k[:, :h], v[:, :h], w[:, :h],
                            chunk=32, impl="ref")
    o2, s2 = ops.chunk_scan(q[:, h:], k[:, h:], v[:, h:], w[:, h:],
                            state=s1, chunk=32, impl="ref")
    np.testing.assert_allclose(np.asarray(o_full[:, h:]), np.asarray(o2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_chunked_ref_equals_naive_long():
    rng = np.random.default_rng(13)
    B, T, d = 1, 512, 16
    q = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, d)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.9, 0.9999, (B, T, d)), jnp.float32)
    o1, s1 = chunk_scan_ref(q, k, v, w)
    o2, s2 = chunk_scan_chunked_ref(q, k, v, w, chunk=128)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-3)

"""Flow-state store: CRC indexing, collisions, eviction, TTD."""
import numpy as np

from repro.core.mat import (
    FlowStore, collision_curve, crc32_hash, random_five_tuples,
)
from repro.core.recirc import time_to_detection


def test_crc_deterministic_and_spread():
    rng = np.random.default_rng(0)
    ft = random_five_tuples(2000, rng)
    h1, h2 = crc32_hash(ft), crc32_hash(ft)
    np.testing.assert_array_equal(h1, h2)
    # reasonable spread over 256 buckets
    counts = np.bincount(h1 % 256, minlength=256)
    assert counts.max() < 30


def test_store_admit_evict_cycle():
    rng = np.random.default_rng(1)
    store = FlowStore(capacity=4096, k=4)
    ft = random_five_tuples(1000, rng)
    slots = store.admit(np.arange(1000), crc32_hash(ft))
    live = (slots >= 0).sum()
    assert live + store.collisions == 1000
    store.evict(slots)
    assert store.stats().n_flows == 0
    # slots are reusable after eviction (register reuse)
    slots2 = store.admit(np.arange(1000, 2000), crc32_hash(ft))
    assert (slots2 >= 0).sum() == live


def test_collision_curve_monotone():
    curve = collision_curve(1 << 14, [0.05, 0.3, 0.7])
    rates = [r for _, r in curve]
    assert rates == sorted(rates)
    assert rates[0] < 0.05


def test_ttd_early_exit_faster(small_flow_ds):
    p = 3
    n = small_flow_ds.n_flows
    early = np.zeros(n, dtype=np.int64)           # exits partition 0
    late = np.full(n, p - 1, dtype=np.int64)      # one-shot: full flow
    ttd_e = time_to_detection(small_flow_ds.packets, small_flow_ds.lengths,
                              early, p)
    ttd_l = time_to_detection(small_flow_ds.packets, small_flow_ds.lengths,
                              late, p)
    assert (ttd_e <= ttd_l + 1e-9).all()
    assert ttd_e.mean() < ttd_l.mean()

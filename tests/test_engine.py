"""The central property: the data-plane engine (feature_window +
dt_traverse + recirculation) computes EXACTLY the same labels, recirc
counts, and exit partitions as the offline PartitionedDT oracle."""
import numpy as np
import pytest

from repro.core.inference import Engine
from repro.core.tree import macro_f1
from repro.flows.windows import window_packets


@pytest.fixture(scope="module")
def engine_setup(trained_pdt):
    pdt, Xw, tr = trained_pdt
    wp = window_packets(tr, 3)
    oracle = pdt.predict(Xw, return_trace=True)
    return pdt, wp, oracle


def test_engine_ref_matches_oracle_exactly(engine_setup):
    pdt, wp, (labels, recircs, exit_p) = engine_setup
    res = Engine.from_model(pdt, impl="ref").run(wp)
    np.testing.assert_array_equal(res.labels, labels)
    np.testing.assert_array_equal(res.recircs, recircs)
    np.testing.assert_array_equal(res.exit_partition, exit_p)


def test_engine_pallas_matches_oracle(engine_setup):
    pdt, wp, (labels, recircs, _) = engine_setup
    res = Engine.from_model(pdt, impl="pallas").run(wp)
    # pallas path may differ on exact-threshold ties in rare cases
    assert (res.labels == labels).mean() >= 0.999
    np.testing.assert_array_equal(res.recircs, recircs)


def test_register_budget_is_structural(engine_setup):
    """The engine physically has only k register slots -- the paper's
    claim that feature count scales at constant register width."""
    pdt, wp, _ = engine_setup
    res = Engine.from_model(pdt, impl="ref").run(wp)
    for regs in res.regs_trace:
        assert regs.shape[1] == pdt.k
    assert len(pdt.unique_features()) > pdt.k


def test_engine_f1(engine_setup, trained_pdt):
    pdt, wp, _ = engine_setup
    _, _, tr = trained_pdt
    res = Engine.from_model(pdt, impl="ref").run(wp)
    assert macro_f1(tr.labels, res.labels, 4) > 0.6

"""The central property: the data-plane engine (feature_window +
dt_traverse + recirculation) computes EXACTLY the same labels, recirc
counts, and exit partitions as the offline PartitionedDT oracle — on
both the fused (single jitted lax.scan) and looped execution paths.
The contract behind the exactness is documented in docs/PARITY.md."""
import numpy as np
import pytest

from repro.core.inference import Engine
from repro.core.partition import train_partitioned_dt
from repro.core.tree import macro_f1
from repro.flows.synthetic import make_dataset
from repro.flows.windows import window_features, window_packets
from repro.testing.hypothesis_compat import given, settings, strategies as st


@pytest.fixture(scope="module")
def engine_setup(trained_pdt):
    pdt, Xw, tr = trained_pdt
    wp = window_packets(tr, 3)
    oracle = pdt.predict(Xw, return_trace=True)
    return pdt, wp, oracle


def test_engine_ref_matches_oracle_exactly(engine_setup):
    pdt, wp, (labels, recircs, exit_p) = engine_setup
    res = Engine.from_model(pdt, impl="ref").run(wp)
    np.testing.assert_array_equal(res.labels, labels)
    np.testing.assert_array_equal(res.recircs, recircs)
    np.testing.assert_array_equal(res.exit_partition, exit_p)


def test_engine_pallas_matches_oracle(engine_setup):
    """Exact since the canonical reduction order (kernels.ref.ordered_wsum)
    and the in-jit SID dispatch landed: the Pallas walk is the same
    machine as the oracle, threshold-boundary flows included."""
    pdt, wp, (labels, recircs, exit_p) = engine_setup
    res = Engine.from_model(pdt, impl="pallas").run(wp)
    np.testing.assert_array_equal(res.labels, labels)
    np.testing.assert_array_equal(res.recircs, recircs)
    np.testing.assert_array_equal(res.exit_partition, exit_p)


def test_register_budget_is_structural(engine_setup):
    """The engine physically has only k register slots -- the paper's
    claim that feature count scales at constant register width."""
    pdt, wp, _ = engine_setup
    res = Engine.from_model(pdt, impl="ref").run(wp)
    for regs in res.regs_trace:
        assert regs.shape[1] == pdt.k
    assert len(pdt.unique_features()) > pdt.k


def test_engine_f1(engine_setup, trained_pdt):
    pdt, wp, _ = engine_setup
    _, _, tr = trained_pdt
    res = Engine.from_model(pdt, impl="ref").run(wp)
    assert macro_f1(tr.labels, res.labels, 4) > 0.6


# ---------------------------------------------------------------------------
# fused path
# ---------------------------------------------------------------------------
def test_fused_matches_looped(engine_setup):
    """The jitted scan and the host loop are the same machine."""
    pdt, wp, _ = engine_setup
    eng = Engine.from_model(pdt, impl="ref")
    fused = eng.run(wp)
    looped = eng.run_looped(wp)
    np.testing.assert_array_equal(fused.labels, looped.labels)
    np.testing.assert_array_equal(fused.recircs, looped.recircs)
    np.testing.assert_array_equal(fused.exit_partition, looped.exit_partition)
    assert len(fused.regs_trace) == len(looped.regs_trace)
    for a, b in zip(fused.regs_trace, looped.regs_trace):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_fused_recirc_counts_match_oracle(engine_setup):
    """Recirculation (= control-packet bandwidth, paper Table 5) must be
    counted identically by the fused engine and the offline oracle."""
    pdt, wp, (_, recircs, _) = engine_setup
    res = Engine.from_model(pdt).run(wp, with_trace=False)
    np.testing.assert_array_equal(res.recircs, recircs)
    assert res.regs_trace == []          # trace elided on request


def test_fused_single_device_round_trip(engine_setup, monkeypatch):
    """No per-partition host sync: the fused path crosses the
    device->host boundary exactly once per batch."""
    import jax

    import repro.core.inference as inf
    pdt, wp, _ = engine_setup
    eng = Engine.from_model(pdt)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(inf.jax, "device_get",
                        lambda tree: calls.append(1) or real(tree))
    eng.run(wp)
    assert len(calls) == 1


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_fused_engine_property_random_trees(seed):
    """Property over random datasets / tree shapes: the fused scan is
    bit-identical to the looped engine, and both agree EXACTLY with
    PartitionedDT.predict.

    The oracle's features come from the all-41-slot window tensor while
    the engine reduces only the active subtree's k slots; both now run
    the canonical left-to-right reduction (``kernels.ref.ordered_wsum``),
    so the shapes can no longer pick different f32 summation trees and
    threshold-boundary flows agree to the last ulp.  This used to allow
    <=1% tie flips — strengthened to zero tolerance.
    """
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 4))
    sizes = [int(rng.integers(1, 4)) for _ in range(p)]
    k = int(rng.integers(2, 5))
    ds = make_dataset("d2", n_flows=240, seed=seed)
    Xw = window_features(ds, p)
    pdt = train_partitioned_dt(Xw, ds.labels, partition_sizes=sizes, k=k)
    wp = window_packets(ds, p)
    labels, recircs, exit_p = pdt.predict(Xw, return_trace=True)
    eng = Engine.from_model(pdt)
    res = eng.run(wp, with_trace=False)
    looped = eng.run_looped(wp)
    np.testing.assert_array_equal(res.labels, looped.labels)
    np.testing.assert_array_equal(res.recircs, looped.recircs)
    np.testing.assert_array_equal(res.exit_partition, looped.exit_partition)
    np.testing.assert_array_equal(res.labels, labels)
    np.testing.assert_array_equal(res.recircs, recircs)
    np.testing.assert_array_equal(res.exit_partition, exit_p)

"""Range marking: prefix covers + rule-table semantics == tree traversal."""
import numpy as np
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core.rangemark import (
    build_subtree_rules, prefix_cover_count, quantize_thresholds,
)
from repro.core.tree import train_tree


def _brute_prefix_count(lo, hi, width):
    """Greedy minimal prefix cover (reference implementation)."""
    count = 0
    while lo <= hi:
        size = 1
        while (lo % (size * 2) == 0) and (lo + size * 2 - 1 <= hi):
            size *= 2
        count += 1
        lo += size
    return count


@settings(max_examples=60, deadline=None)
@given(st.integers(0, 255), st.integers(0, 255))
def test_prefix_cover_matches_reference(a, b):
    lo, hi = min(a, b), max(a, b)
    assert prefix_cover_count(lo, hi, 8) == _brute_prefix_count(lo, hi, 8)


def test_prefix_cover_bounds():
    w = 16
    for lo, hi in [(0, 2**w - 1), (1, 2**w - 2), (5, 5), (0, 0)]:
        c = prefix_cover_count(lo, hi, w)
        assert 1 <= c <= 2 * w - 2 or (lo, hi) == (0, 2**w - 1)


def test_quantize_thresholds_monotone():
    thr = np.array([0.5, 1.5, 7.2])
    q = quantize_thresholds(thr, 0.0, 10.0, 8)
    assert (np.diff(q) >= 0).all()
    assert q.min() >= 0 and q.max() <= 255


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 5))
def test_rules_equal_tree_traversal(seed, depth):
    """The paper's guarantee: range-marked TCAM rules implement exactly
    the same function as the decision tree (one rule per leaf)."""
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(300, 6)).astype(np.float32)
    y = rng.integers(0, 3, 300)
    t = train_tree(X, y, max_depth=depth, k_features=4)
    leaf_action = {int(i): 100 + int(i)
                   for i in np.nonzero(t.feature < 0)[0]}
    rules = build_subtree_rules(t, leaf_action)
    assert rules.model_entries == t.n_leaves        # one rule per leaf
    got = rules.apply(X)
    expect = np.asarray([leaf_action[int(l)] for l in t.apply(X)])
    np.testing.assert_array_equal(got, expect)


def test_key_bits_grow_with_features():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(400, 8)).astype(np.float32)
    y = ((X[:, 0] > 0).astype(int) + 2 * (X[:, 1] > 0)).astype(np.int64)
    t1 = train_tree(X, y, max_depth=2, k_features=1)
    t3 = train_tree(X, y, max_depth=6, k_features=3)
    r1 = build_subtree_rules(t1, {int(i): 0 for i in np.nonzero(t1.feature < 0)[0]})
    r3 = build_subtree_rules(t3, {int(i): 0 for i in np.nonzero(t3.feature < 0)[0]})
    assert r3.key_bits >= r1.key_bits

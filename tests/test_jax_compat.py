"""repro._jax_compat: the forward-compat shims actually deliver the
modern surface on the pinned 0.4.x wheels.

Everything in src/repro is written against the current JAX mesh/pallas
API; these tests pin down the contract the shims promise — the aliased
names exist, behave like their modern counterparts for the subset the
repo uses, and installing twice is a no-op (idempotency matters because
``repro/__init__.py`` runs ``install()`` on every import).
"""
import enum

import jax
import jax.numpy as jnp
import jax.sharding
import numpy as np
import pytest

from repro import _jax_compat


# ---------------------------------------------------------------------------
# surface: every aliased name is present after import
# ---------------------------------------------------------------------------

def test_axis_type_present_with_all_members():
    at = jax.sharding.AxisType
    for member in ("Auto", "Explicit", "Manual"):
        assert hasattr(at, member)
    # both the real enum and the shim are Enum subclasses
    assert issubclass(at, enum.Enum) or isinstance(at.Auto, at)


def test_make_mesh_accepts_axis_types():
    mesh = jax.make_mesh(
        (1,), ("data",),
        axis_types=(jax.sharding.AxisType.Auto,))
    assert isinstance(mesh, (jax.sharding.Mesh,
                             getattr(jax.sharding, "AbstractMesh", ())))
    assert mesh.shape == {"data": 1}
    assert mesh.axis_names == ("data",)


def test_make_mesh_devices_kwarg_still_works():
    devs = jax.devices()[:1]
    mesh = jax.make_mesh((1,), ("d",), devices=devs)
    assert mesh.shape == {"d": 1}


def test_set_mesh_present_and_usable_as_context():
    mesh = jax.make_mesh((1,), ("data",))
    ctx = jax.set_mesh(mesh)
    # 0.4.x shim returns the Mesh itself, which is a context manager;
    # current jax returns a context manager too — both must support
    # `with`, which is how the repo consumes it.
    with ctx:
        pass


def test_get_abstract_mesh_reflects_ambient_mesh():
    get = jax.sharding.get_abstract_mesh
    ambient = get()
    assert ambient.empty            # nothing installed yet
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))
    with mesh:
        inside = get()
        assert not inside.empty
        assert dict(inside.shape) == {"data": 1}
    assert get().empty              # restored on exit


def test_pallas_compiler_params_alias():
    pltpu = pytest.importorskip("jax.experimental.pallas.tpu")
    assert hasattr(pltpu, "CompilerParams")
    if hasattr(pltpu, "TPUCompilerParams"):
        assert pltpu.CompilerParams is pltpu.TPUCompilerParams


# ---------------------------------------------------------------------------
# cost_analysis normalisation: flat dict on every jax version
# ---------------------------------------------------------------------------

def test_cost_analysis_returns_flat_dict():
    compiled = jax.jit(lambda x: x * 2.0 + 1.0).lower(
        jnp.ones((8,), jnp.float32)).compile()
    out = compiled.cost_analysis()
    assert isinstance(out, dict)    # never the 0.4.x list-of-dicts
    if out:                         # backends may report nothing
        assert all(isinstance(k, str) for k in out)


def test_cost_analysis_normalises_list_payloads():
    """The wrapper's own logic: a 0.4.x-style list collapses to its
    first entry, an empty list to {} (exercised directly because the
    installed backend may already return a flat dict)."""
    wrapper = jax.stages.Compiled.cost_analysis
    assert getattr(wrapper, "_repro_normalised", False)

    class FakeCompiled:
        def __init__(self, payload):
            self._payload = payload

    # reuse the wrapper's closure over `orig` by monkey-class: call the
    # unbound function with a stand-in whose orig() result we control
    orig = wrapper.__wrapped__ if hasattr(wrapper, "__wrapped__") else None
    if orig is None:
        # the shim stores orig in its closure; drive it end to end via
        # a real Compiled instead
        compiled = jax.jit(lambda x: x + 1).lower(
            jnp.ones((4,), jnp.float32)).compile()
        assert isinstance(compiled.cost_analysis(), dict)
    else:  # pragma: no cover - only on builds exposing __wrapped__
        assert isinstance(orig(FakeCompiled([])), dict)


# ---------------------------------------------------------------------------
# idempotency: install() twice must not re-wrap or clobber
# ---------------------------------------------------------------------------

def test_install_is_idempotent():
    before = {
        "AxisType": jax.sharding.AxisType,
        "make_mesh": jax.make_mesh,
        "set_mesh": jax.set_mesh,
        "get_abstract_mesh": jax.sharding.get_abstract_mesh,
        "cost_analysis": jax.stages.Compiled.cost_analysis,
    }
    _jax_compat.install()
    assert jax.sharding.AxisType is before["AxisType"]
    assert jax.make_mesh is before["make_mesh"]
    assert jax.set_mesh is before["set_mesh"]
    assert jax.sharding.get_abstract_mesh is before["get_abstract_mesh"]
    # the cost_analysis guard is the load-bearing one: re-wrapping would
    # nest wrappers on every `import repro`
    assert jax.stages.Compiled.cost_analysis is before["cost_analysis"]


def test_cost_analysis_wrapper_installed_once():
    # the marker is how _install_cost_analysis detects itself
    assert getattr(jax.stages.Compiled.cost_analysis,
                   "_repro_normalised", False)
    _jax_compat._install_cost_analysis()
    _jax_compat._install_cost_analysis()
    ca = jax.stages.Compiled.cost_analysis
    assert getattr(ca, "_repro_normalised", False)

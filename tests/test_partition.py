"""Algorithm-1 partitioned training: structural invariants + routing."""

from repro.core.partition import EXIT, train_partitioned_dt
from repro.core.tree import macro_f1
from repro.flows.windows import window_features


def test_subtree_feature_budget(trained_pdt):
    pdt, _, _ = trained_pdt
    for st in pdt.subtrees:
        assert len(st.used_features) <= pdt.k, st.sid


def test_routing_targets_next_partition(trained_pdt):
    pdt, _, _ = trained_pdt
    for st in pdt.subtrees:
        for leaf, nxt in st.leaf_next_sid.items():
            if nxt == EXIT:
                continue
            assert pdt.subtrees[nxt].partition == st.partition + 1


def test_last_partition_always_exits(trained_pdt):
    pdt, _, _ = trained_pdt
    last = pdt.n_partitions - 1
    for st in pdt.subtrees:
        if st.partition == last:
            assert all(v == EXIT for v in st.leaf_next_sid.values())


def test_subtree_depths_within_partition_sizes(trained_pdt):
    pdt, _, _ = trained_pdt
    for st in pdt.subtrees:
        assert st.depth <= pdt.partition_sizes[st.partition]


def test_predict_beats_chance(trained_pdt, small_flow_ds):
    pdt, _, _ = trained_pdt
    _, te = small_flow_ds.split()
    Xw = window_features(te, 3)
    pred = pdt.predict(Xw)
    f1 = macro_f1(te.labels, pred, small_flow_ds.n_classes)
    assert f1 > 0.5   # 4-class problem; chance ~0.25


def test_recirc_bounded_by_partitions(trained_pdt):
    pdt, Xw, tr = trained_pdt
    _, recircs, exit_p = pdt.predict(Xw, return_trace=True)
    assert (recircs <= pdt.n_partitions - 1).all()
    assert (recircs == exit_p).all()   # one control pkt per transition


def test_feature_density_sparse(trained_pdt):
    """Paper Table 1: per-subtree feature density ~6-10%, not ~100%."""
    pdt, _, _ = trained_pdt
    _, per_sub = pdt.feature_density()
    assert per_sub < 25.0
    assert len(pdt.unique_features()) > pdt.k   # more total than k


def test_single_partition_degenerates_to_plain_tree(small_flow_ds):
    tr, te = small_flow_ds.split()
    Xw = window_features(tr, 1)
    pdt = train_partitioned_dt(Xw, tr.labels, partition_sizes=[6], k=4)
    assert pdt.n_partitions == 1
    assert len(pdt.subtrees) == 1
    _, recircs, _ = pdt.predict(Xw, return_trace=True)
    assert (recircs == 0).all()      # Table 5's 0.0 +- 0.0 rows

"""EngineOptions: the one configuration object behind ``Engine.run``,
``run_streaming`` and the flow-table server.  Legacy keywords keep
working through thin shims but warn; the options path is silent and
bit-identical to the keyword spelling it replaces."""
import warnings

import numpy as np
import pytest

from repro.core.inference import Engine, EngineOptions
from repro.flows.windows import window_packets
from repro.serve.streaming import run_streaming, stream_batches


@pytest.fixture(scope="module")
def setup(trained_pdt):
    pdt, _, tr = trained_pdt
    eng = Engine.from_model(pdt)
    wp = window_packets(tr, 3)
    return eng, wp


def _assert_same(a, b):
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.recircs, b.recircs)
    np.testing.assert_array_equal(a.exit_partition, b.exit_partition)


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def test_options_validate_eagerly():
    with pytest.raises(ValueError, match="impl"):
        EngineOptions(impl="sideways")
    with pytest.raises(ValueError, match="compact"):
        EngineOptions(compact="maybe")
    with pytest.raises(ValueError, match="compact_floor"):
        EngineOptions(compact_floor=0)
    with pytest.raises(ValueError, match="block_b"):
        EngineOptions(block_b=-4)
    with pytest.raises(ValueError, match="micro_batch"):
        EngineOptions(micro_batch=0)
    with pytest.raises(ValueError, match="inflight"):
        EngineOptions(inflight=0)


def test_options_replace_is_functional():
    base = EngineOptions(impl="fused")
    tuned = base.replace(impl="tuned", compact="auto")
    assert base.impl == "fused" and base.compact is False
    assert tuned.impl == "tuned" and tuned.compact == "auto"
    with pytest.raises(ValueError):
        base.replace(inflight=0)


# ---------------------------------------------------------------------------
# deprecation shims: every legacy keyword warns, options= is silent
# ---------------------------------------------------------------------------
def test_engine_run_legacy_kwargs_warn(setup):
    eng, wp = setup
    with pytest.warns(DeprecationWarning, match="impl"):
        legacy = eng.run(  # splint: allow[R005]: exercises the deprecation shim on purpose
            wp, with_trace=False, impl="fused")
    with pytest.warns(DeprecationWarning, match="compact"):
        eng.run(  # splint: allow[R005]: exercises the deprecation shim on purpose
            wp[:16], with_trace=False, compact=True)
    new = eng.run(wp, with_trace=False,
                  options=EngineOptions(impl="fused"))
    _assert_same(legacy, new)


def test_run_streaming_legacy_kwargs_warn(setup):
    eng, wp = setup
    with pytest.warns(DeprecationWarning, match="micro_batch"):
        legacy = run_streaming(  # splint: allow[R005]: exercises the deprecation shim on purpose
            eng, wp, micro_batch=64)
    new = run_streaming(eng, wp,
                        options=EngineOptions(micro_batch=64))
    _assert_same(legacy, new)
    with pytest.warns(DeprecationWarning, match="inflight"):
        run_streaming(  # splint: allow[R005]: exercises the deprecation shim on purpose
            eng, wp[:32], inflight=1)
    with pytest.warns(DeprecationWarning, match="compact"):
        run_streaming(  # splint: allow[R005]: exercises the deprecation shim on purpose
            eng, wp[:32], compact=True)


def test_engine_method_shims_warn(setup):
    eng, wp = setup
    with pytest.warns(DeprecationWarning, match="micro_batch"):
        legacy = eng.run_streaming(  # splint: allow[R005]: exercises the deprecation shim on purpose
            wp, micro_batch=48)
    new = eng.run_streaming(wp, options=EngineOptions(micro_batch=48))
    _assert_same(legacy, new)
    with pytest.warns(DeprecationWarning, match="compact"):
        looped = eng.run_looped(  # splint: allow[R005]: exercises the deprecation shim on purpose
            wp[:24], with_trace=False, compact=True)
    _assert_same(looped, eng.run_looped(
        wp[:24], with_trace=False, options=EngineOptions(compact=True)))


def test_options_path_is_warning_free(setup):
    eng, wp = setup
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng.run(wp[:32], with_trace=False,
                options=EngineOptions(impl="pallas", compact=True))
        run_streaming(eng, wp[:32], options=EngineOptions(
            micro_batch=16, inflight=1, compact="auto"))
        eng.run_looped(wp[:16], with_trace=False, options=EngineOptions())


def test_mixing_options_and_legacy_raises(setup):
    eng, wp = setup
    with pytest.raises(ValueError, match="not both"):
        eng.run(  # splint: allow[R005]: exercises the deprecation shim on purpose
            wp, options=EngineOptions(), impl="fused")
    with pytest.raises(ValueError, match="not both"):
        run_streaming(  # splint: allow[R005]: exercises the deprecation shim on purpose
            eng, wp, options=EngineOptions(), micro_batch=8)


# ---------------------------------------------------------------------------
# routing equivalences
# ---------------------------------------------------------------------------
def test_options_impl_matches_engine_impl_attr(setup):
    eng, wp = setup
    a = eng.run(wp, with_trace=False,
                options=EngineOptions(impl="pallas"))
    b = eng.run(wp, with_trace=False,
                options=EngineOptions(impl="fused"))
    c = eng.run(wp, with_trace=False)   # engine default impl
    _assert_same(a, b)
    _assert_same(a, c)


def test_options_plan_pins_backend(setup):
    eng, wp = setup
    auto = eng.run(wp, with_trace=False,
                   options=EngineOptions(impl="auto"))
    assert auto.plan is not None
    pinned = eng.run(wp, with_trace=False,
                     options=EngineOptions(plan=auto.plan))
    assert pinned.plan is auto.plan
    _assert_same(auto, pinned)


def test_streaming_options_compact_auto(setup):
    eng, wp = setup
    full = eng.run(wp, with_trace=False)
    res = run_streaming(eng, wp, options=EngineOptions(
        micro_batch=40, compact="auto"))
    _assert_same(res, full)
    ticks = list(stream_batches(eng, [wp[:20], wp[20:52]],
                                options=EngineOptions(micro_batch=16)))
    _assert_same(ticks[0], eng.run(wp[:20], with_trace=False))
    _assert_same(ticks[1], eng.run(wp[20:52], with_trace=False))


def test_streaming_inflight_zero_rejected_via_options(setup):
    eng, wp = setup
    with pytest.raises(ValueError):
        run_streaming(eng, wp, options=EngineOptions(inflight=0))


def test_serve_namespace_exports_unified_surface():
    import repro.serve as serve
    for name in ("Engine", "EngineOptions", "EngineResult",
                 "FlowTable", "FlowTableServer", "StreamVerdicts",
                 "StreamVerdict", "run_streaming", "stream_batches"):
        assert hasattr(serve, name), name
    # heavy LM-serving prototypes must stay un-imported by the package
    # surface (other tests may import them directly, so check the
    # package source rather than sys.modules)
    import ast
    import inspect
    imported = {
        name
        for node in ast.walk(ast.parse(inspect.getsource(serve)))
        if isinstance(node, (ast.Import, ast.ImportFrom))
        for name in ([a.name for a in node.names]
                     + ([node.module] if isinstance(node, ast.ImportFrom)
                        else []))
    }
    assert not any("batching" in m or "serve_step" in m for m in imported)

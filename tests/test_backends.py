"""The ExecutionBackend layer: every backend (looped / fused / pallas)
is the same machine — identical EngineResult bit-for-bit — and the
walk backends (fused, pallas with its in-jit SID dispatch) cross the
device->host boundary exactly once per batch.

Zero-tolerance equality here is a contract, not a tolerance choice:
docs/PARITY.md states the three invariants (canonical reduction order,
-1 sentinels, padding-leak) that make it achievable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.inference import (
    FUSED_BACKEND,
    LOOPED_BACKEND,
    PALLAS_BACKEND,
    Engine,
    get_backend,
)
from repro.core.partition import train_partitioned_dt
from repro.flows.synthetic import make_dataset
from repro.flows.windows import window_features, window_packets
from repro.kernels.dispatch import capacity_blocks, sid_dispatch
from repro.testing.hypothesis_compat import given, settings, strategies as st
from repro.core.inference import EngineOptions


# ---------------------------------------------------------------------------
# selection matrix
# ---------------------------------------------------------------------------
def test_backend_selection_matrix():
    assert get_backend("fused") is FUSED_BACKEND
    assert get_backend("ref") is FUSED_BACKEND          # ref == fused walk
    assert get_backend("pallas") is PALLAS_BACKEND
    assert get_backend("looped") is LOOPED_BACKEND
    # auto: pallas on TPU, fused elsewhere
    expected = PALLAS_BACKEND if jax.default_backend() == "tpu" \
        else FUSED_BACKEND
    assert get_backend("auto") is expected
    assert get_backend() is expected
    with pytest.raises(ValueError, match="unknown impl"):
        get_backend("tofino")


def test_walk_backends_expose_steps():
    assert FUSED_BACKEND.step is not None
    assert PALLAS_BACKEND.step is not None
    assert LOOPED_BACKEND.step is None      # not streamable


# ---------------------------------------------------------------------------
# in-jit SID dispatch (the grouping that used to live on the host)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("S,B,bb", [(1, 5, 4), (3, 50, 8), (16, 300, 64),
                                    (7, 128, 128)])
def test_sid_dispatch_routing(S, B, bb):
    """dest is an injective block-aligned layout: every flow lands in a
    block whose block_sid equals the flow's SID."""
    rng = np.random.default_rng(S * 1000 + B)
    sid = jnp.asarray(rng.integers(0, S, B), jnp.int32)
    d = jax.jit(sid_dispatch, static_argnames=("n_subtrees", "block_b"))(
        sid, n_subtrees=S, block_b=bb)
    nb = capacity_blocks(B, S, bb)
    order, dest, block_sid = map(np.asarray, d)
    assert sorted(order) == list(range(B))              # a permutation
    assert len(set(dest.tolist())) == B                 # injective
    assert dest.min() >= 0 and dest.max() < nb * bb
    assert block_sid.shape == (nb,)
    np.testing.assert_array_equal(block_sid[dest // bb],
                                  np.asarray(sid)[order])


def test_sid_dispatch_has_no_host_callbacks():
    """The grouping must trace into pure XLA — no callbacks, no numpy."""
    sid = jnp.zeros(64, jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda s: sid_dispatch(s, n_subtrees=4, block_b=32))(sid)
    assert "callback" not in str(jaxpr)


# ---------------------------------------------------------------------------
# backend equivalence
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def backend_setup(trained_pdt):
    pdt, Xw, tr = trained_pdt
    wp = window_packets(tr, 3)
    eng = Engine.from_model(pdt)
    return pdt, Xw, wp, eng


def _assert_identical(a, b):
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.recircs, b.recircs)
    np.testing.assert_array_equal(a.exit_partition, b.exit_partition)


def test_pallas_backend_identical_to_fused_and_looped(backend_setup):
    """The acceptance bar: impl='pallas' (interpret on CPU) produces
    labels identical to fused and looped — same trees, same windows,
    zero tolerance."""
    pdt, Xw, wp, eng = backend_setup
    fused = eng.run(wp, with_trace=True, options=EngineOptions(impl="fused"))
    pallas = eng.run(wp, with_trace=True, options=EngineOptions(impl="pallas"))
    looped = eng.run_looped(wp)
    _assert_identical(pallas, fused)
    _assert_identical(pallas, looped)
    # register traces agree bit-exactly too (canonical reduction order)
    assert len(pallas.regs_trace) == len(fused.regs_trace)
    for a, b in zip(pallas.regs_trace, fused.regs_trace):
        np.testing.assert_array_equal(a, b)


def test_pallas_backend_matches_oracle_exactly(backend_setup):
    pdt, Xw, wp, eng = backend_setup
    labels, recircs, exit_p = pdt.predict(Xw, return_trace=True)
    res = eng.run(wp, with_trace=False, options=EngineOptions(impl="pallas"))
    np.testing.assert_array_equal(res.labels, labels)
    np.testing.assert_array_equal(res.recircs, recircs)
    np.testing.assert_array_equal(res.exit_partition, exit_p)


def test_pallas_single_device_round_trip(backend_setup, monkeypatch):
    """No host-side SID grouping between recirculation hops: the pallas
    walk crosses the device->host boundary exactly once per batch."""
    import repro.core.inference as inf
    pdt, Xw, wp, eng = backend_setup
    calls = []
    real = jax.device_get
    monkeypatch.setattr(inf.jax, "device_get",
                        lambda tree: calls.append(1) or real(tree))
    eng.run(wp, with_trace=False, options=EngineOptions(impl="pallas"))
    assert len(calls) == 1


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_backend_equivalence_property_random_trees(seed):
    """Property over random datasets / tree shapes: all three backends
    emit bit-identical verdicts."""
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 4))
    sizes = [int(rng.integers(1, 4)) for _ in range(p)]
    k = int(rng.integers(2, 5))
    ds = make_dataset("d2", n_flows=200, seed=seed)
    Xw = window_features(ds, p)
    pdt = train_partitioned_dt(Xw, ds.labels, partition_sizes=sizes, k=k)
    wp = window_packets(ds, p)
    eng = Engine.from_model(pdt)
    fused = eng.run(wp, with_trace=False, options=EngineOptions(impl="fused"))
    pallas = eng.run(wp, with_trace=False, options=EngineOptions(impl="pallas"))
    looped = eng.run_looped(wp, with_trace=False)
    _assert_identical(pallas, fused)
    _assert_identical(pallas, looped)

"""The fused tick engine's perf contract: O(1) device dispatches per
ingest tick, regardless of how many packet ranks the tick packs or how
many drain rounds the hop loop needs.  Wall-clock on shared boxes is
noisy; dispatch counts are deterministic, so this is the regression
bar the cost model's call/sync terms justify."""
import numpy as np
import pytest

from repro.core.inference import Engine, EngineOptions
from repro.flows.synthetic import PacketBatch, make_packet_stream
from repro.serve import FlowTableServer, StreamVerdicts
from repro.tuning import (
    TICK_ENGINES,
    ShapeInfo,
    choose_tick_engine,
    choose_tick_plan,
    estimate_tick_us,
    tick_work_terms,
)

P = 3


@pytest.fixture(scope="module")
def tick_setup(trained_pdt):
    pdt, _, tr = trained_pdt
    eng = Engine.from_model(pdt)
    stream = make_packet_stream(tr, seed=23, profile="steady")
    return eng, tr, stream


def _whole_flow_ticks(tr, flows_per_tick):
    """Ticks delivering each flow's ENTIRE packet train at once — the
    deepest rank chains a tick can have (rank count = flow length)."""
    order = np.argsort(tr.lengths)[::-1]
    for at in range(0, order.size, flows_per_tick):
        sel = order[at:at + flows_per_tick]
        fid = np.concatenate(
            [np.full(int(tr.lengths[i]), i, np.int64) for i in sel])
        flen = tr.lengths[fid].astype(np.int32)
        pkts = np.concatenate(
            [tr.packets[i, :int(tr.lengths[i])] for i in sel])
        arr = np.arange(fid.size, dtype=np.float64)
        yield PacketBatch(fid, flen, pkts.astype(np.float32), arr)


def _dispatch_deltas(srv, batches):
    deltas = []
    for b in batches:
        before = srv.stats.dispatches
        srv.ingest(b)
        deltas.append(srv.stats.dispatches - before)
    return deltas


# ---------------------------------------------------------------------------
# the perf bar: constant dispatches per tick
# ---------------------------------------------------------------------------
def test_fused_tick_dispatches_constant(tick_setup):
    """Fused ticks cost at most 2 dispatches (admission scatter + tick
    step) no matter the rank depth: a tick of 1-packet ranks and a tick
    holding whole flows (rank depth = max flow length, every window
    boundary + full drain inside) must count the same."""
    eng, tr, stream = tick_setup
    # shallow ticks: stream order, small tick => few ranks
    srv = FlowTableServer(eng, n_buckets=64, bucket_size=8,
                          tick_engine="fused")
    shallow = _dispatch_deltas(srv, stream.ticks(64))
    # deep ticks: whole flows per tick => rank depth = flow length
    srv2 = FlowTableServer(eng, n_buckets=64, bucket_size=8,
                           tick_engine="fused")
    deep = _dispatch_deltas(srv2, _whole_flow_ticks(tr, 16))
    assert max(shallow) <= 2 and max(deep) <= 2
    # identical bound on wildly different tick shapes — O(1) dispatches
    assert max(deep) <= max(shallow) + 0  # deep ticks cost no extra calls
    assert set(shallow) | set(deep) <= {1, 2}


def test_legacy_tick_dispatches_grow_with_ranks(tick_setup):
    """The baseline the fused engine replaces: per-rank fold dispatches
    plus per-drain-round hop dispatches, so whole-flow ticks cost far
    more calls than shallow ticks — the O(ranks + drains) shape the
    cost model's legacy branch charges for."""
    eng, tr, stream = tick_setup
    srv = FlowTableServer(eng, n_buckets=64, bucket_size=8,
                          tick_engine="legacy")
    shallow = _dispatch_deltas(srv, stream.ticks(64))
    srv2 = FlowTableServer(eng, n_buckets=64, bucket_size=8,
                           tick_engine="legacy")
    deep = _dispatch_deltas(srv2, _whole_flow_ticks(tr, 16))
    assert max(deep) > max(shallow)
    assert max(deep) > 2 * max(1, min(shallow))


def test_fused_tick_dispatches_independent_of_drain_rounds(tick_setup):
    """Flows shorter than P packets drain multiple empty trailing
    windows in one tick; the fused engine's in-jit while_loop keeps the
    dispatch count at <= 2 anyway."""
    eng, _, _ = tick_setup
    srv = FlowTableServer(eng, n_buckets=8, bucket_size=4,
                          tick_engine="fused")
    # single-packet flows: window [0,1) completes on the only packet and
    # partitions 1..P-1 are all empty => P-1 drain rounds inside the jit
    from repro.core.features import PKT_NFIELDS
    fid = np.arange(12, dtype=np.int64)
    batch = PacketBatch(fid, np.ones(12, np.int32),
                        np.zeros((12, PKT_NFIELDS), np.float32),
                        np.arange(12, dtype=np.float64))
    before = srv.stats.dispatches
    v = srv.ingest(batch)
    assert srv.stats.dispatches - before <= 2
    assert v.n_flows == 12  # every flow drained to a verdict in-tick


# ---------------------------------------------------------------------------
# cost model: tick-shape terms route the engines
# ---------------------------------------------------------------------------
def _shape(eng, B=512):
    return ShapeInfo.from_engine(eng, None, B=B, W=1)


def test_tick_work_terms_shapes(tick_setup):
    eng, _, _ = tick_setup
    shape = _shape(eng)
    from repro.tuning import candidate_plans
    plan = candidate_plans(shape, compact=False)[0]
    from repro.tuning.costmodel import TERMS
    t = {name: i for i, name in enumerate(TERMS)}
    legacy = tick_work_terms(shape, plan, ranks=8, tick_engine="legacy")
    fused = tick_work_terms(shape, plan, ranks=8, tick_engine="fused")
    # legacy pays one call per rank + hop and one sync per hop round;
    # fused pays a constant call+sync budget
    assert legacy[t["call"]] > fused[t["call"]]
    assert legacy[t["sync"]] > fused[t["sync"]]
    assert fused[t["call"]] == pytest.approx(2.0)
    assert fused[t["sync"]] == pytest.approx(1.0)
    with pytest.raises(ValueError):
        tick_work_terms(shape, plan, tick_engine="looped")


def test_tick_estimate_scaling(tick_setup):
    """Legacy's estimate must grow with rank depth; fused's dispatch
    overhead must stay flat (only the fold work term grows)."""
    eng, _, _ = tick_setup
    shape = _shape(eng)
    from repro.tuning import candidate_plans
    plan = candidate_plans(shape, compact=False)[0]
    legacy = [estimate_tick_us(shape, plan, ranks=r, tick_engine="legacy")
              for r in (1, 8, 64)]
    fused = [estimate_tick_us(shape, plan, ranks=r, tick_engine="fused")
             for r in (1, 8, 64)]
    assert legacy[0] < legacy[1] < legacy[2]
    # dispatch overhead: the fused/legacy gap widens with rank count
    assert (legacy[2] - fused[2]) > (legacy[0] - fused[0])
    assert all(f < l for f, l in zip(fused, legacy))


def test_choose_tick_engine_prefers_fused_on_cpu(tick_setup):
    """On CPU, dispatch overhead dominates — auto must route fused for
    any realistic rank depth, which is what tick_engine='auto' uses."""
    eng, _, _ = tick_setup
    shape = _shape(eng)
    for ranks in (1, 4, 32):
        assert choose_tick_engine(shape, ranks=ranks) == "fused"
    engine, plan = choose_tick_plan(shape, ranks=4)
    assert engine in TICK_ENGINES
    assert plan.backend in ("fused", "pallas")


def test_server_auto_resolves_tick_engine(tick_setup):
    eng, _, stream = tick_setup
    srv = FlowTableServer(eng, n_buckets=16, bucket_size=4)
    assert srv.tick_engine in ("fused", "legacy")  # "auto" resolved
    assert srv.tick_engine == "fused"  # CPU: dispatch overhead dominates
    with pytest.raises(ValueError):
        FlowTableServer(eng, tick_engine="warp")


# ---------------------------------------------------------------------------
# engines are interchangeable: identical verdicts, identical stats
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["fused", "pallas"])
def test_tick_engines_bit_identical(tick_setup, impl):
    eng, tr, stream = tick_setup
    outs = {}
    for te in ("fused", "legacy"):
        srv = FlowTableServer(
            eng, n_buckets=32, bucket_size=4, tick_engine=te,
            options=EngineOptions(impl=impl))
        parts = [srv.ingest(b) for b in stream.ticks(97)]
        parts.append(srv.flush())
        outs[te] = (StreamVerdicts.concat(parts), srv.stats)
    a, sa = outs["fused"]
    b, sb = outs["legacy"]
    oa, ob = np.argsort(a.flow_id), np.argsort(b.flow_id)
    np.testing.assert_array_equal(a.flow_id[oa], b.flow_id[ob])
    np.testing.assert_array_equal(a.labels[oa], b.labels[ob])
    np.testing.assert_array_equal(a.recircs[oa], b.recircs[ob])
    np.testing.assert_array_equal(a.exit_partition[oa],
                                  b.exit_partition[ob])
    # same admission story: EVERY stats field except the dispatch count
    # (the engines' whole difference) agrees
    from repro.serve import ServerStats
    for f in ServerStats.FIELDS:
        if f == "dispatches":
            continue
        assert getattr(sa, f) == getattr(sb, f), f
    assert sa.dispatches < sb.dispatches  # the whole point


def test_tick_engines_stats_agree_under_spill_and_timeout(tick_setup):
    """The stats-drift audit bar: a tiny table (constant spill traffic)
    plus an aggressive timeout (eviction sentinels) exercises every
    counter-update path — fused and legacy must still agree on all
    stats fields, including the spill-run dispatches both engines now
    count identically."""
    eng, tr, stream = tick_setup
    from repro.serve import ServerStats
    outs = {}
    for te in ("fused", "legacy"):
        srv = FlowTableServer(eng, n_buckets=2, bucket_size=2,
                              tick_engine=te, timeout=0.005)
        parts = [srv.ingest(b) for b in stream.ticks(131)]
        parts.append(srv.flush())
        outs[te] = (StreamVerdicts.concat(parts), srv.stats)
    a, sa = outs["fused"]
    b, sb = outs["legacy"]
    assert sa.spilled > 0          # the tiny table forced the host path
    assert sa.evicted > 0          # the timeout fired
    oa, ob = np.argsort(a.flow_id), np.argsort(b.flow_id)
    np.testing.assert_array_equal(a.flow_id[oa], b.flow_id[ob])
    np.testing.assert_array_equal(a.labels[oa], b.labels[ob])
    for f in ServerStats.FIELDS:
        if f == "dispatches":
            continue
        assert getattr(sa, f) == getattr(sb, f), f
    assert sa.dispatches < sb.dispatches

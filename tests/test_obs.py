"""repro.obs: registry semantics, span tracing, the ``SPLIDT_OBS=0``
no-op contract, and live-metrics parity.

The parity tests are the acceptance bar from the paper's evaluation:
every number the live registry reports (recirc overhead, TTD
quantiles, dispatch counts) must be *recomputable offline* from the
raw :class:`StreamVerdicts` plus the replayable packet stream — exact
equality for counters, same-bucket equality for latencies."""
import json
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.core.inference import Engine, EngineOptions
from repro.flows.synthetic import make_packet_stream
from repro.obs import (
    Histogram,
    MetricRegistry,
    MetricsReporter,
    exp_edges,
)
from repro.serve import FlowTableServer, ServerStats, StreamVerdicts
from repro.serve.flowtable import TTD_EDGES


# ---------------------------------------------------------------------------
# MetricRegistry primitives
# ---------------------------------------------------------------------------
def test_counter_monotonic():
    reg = MetricRegistry()
    c = reg.counter("x_total", "doc")
    c.inc()
    c.inc(41)
    assert c.value == 42
    with pytest.raises(ValueError):
        c.inc(-1)
    # get-or-create returns the same live object
    assert reg.counter("x_total") is c


def test_gauge_set_add():
    g = MetricRegistry().gauge("x")
    g.set(2.5)
    g.add(-0.5)
    assert g.value == 2.0


def test_histogram_bucketing():
    h = Histogram("h", edges=[1.0, 10.0, 100.0])
    h.record(0.5)                       # below first edge
    h.record_many([1.0, 5.0, 50.0, 1e9])  # edge goes RIGHT (1.0 -> [1,10))
    assert [int(c) for c in h.counts] == [1, 2, 1, 1]
    assert h.total == 5
    assert h.bucket_of(0.0) == 0 and h.bucket_of(1.0) == 1
    assert h.bucket_of(float("inf")) == 3
    assert h.quantile(0.5) == 10.0      # upper edge of the median bucket
    assert h.quantile(1.0) == float("inf")
    assert np.isnan(Histogram("e", edges=[1.0]).quantile(0.5))


def test_histogram_rejects_bad_edges():
    for bad in ([], [3.0, 1.0], [1.0, 1.0]):
        with pytest.raises(ValueError):
            Histogram("h", edges=bad)
    with pytest.raises(ValueError):
        MetricRegistry().histogram("h")  # first use must pass edges


def test_exp_edges():
    e = exp_edges(0.001, 1000.0, 7)
    assert len(e) == 7
    assert e[0] == pytest.approx(0.001) and e[-1] == pytest.approx(1000.0)
    ratios = [b / a for a, b in zip(e, e[1:])]
    assert max(ratios) == pytest.approx(min(ratios))
    with pytest.raises(ValueError):
        exp_edges(0.0, 1.0, 4)


def test_label_identity():
    reg = MetricRegistry()
    a = reg.counter("d_total", labels={"backend": "fused"})
    b = reg.counter("d_total", labels={"backend": "pallas"})
    assert a is not b
    a.inc(3)
    # label order must not matter for identity
    c = reg.counter("d_total", labels={"backend": "fused"})
    assert c.value == 3
    snap = reg.snapshot()
    assert snap["counters"]['d_total{backend="fused"}']["value"] == 3


def test_snapshot_delta():
    reg = MetricRegistry()
    c = reg.counter("c_total")
    h = reg.histogram("h", edges=[1.0, 2.0])
    c.inc(5)
    h.record(0.5)
    before = reg.snapshot()
    c.inc(2)
    h.record(1.5)
    d = MetricRegistry.delta(before, reg.snapshot())
    assert d["counters"]["c_total"]["value"] == 2
    assert d["histograms"]["h"]["counts"] == [0, 1, 0]
    assert d["histograms"]["h"]["total"] == 1


def test_prometheus_exposition():
    reg = MetricRegistry()
    reg.counter("pkts_total", "packets").inc(7)
    reg.gauge("load").set(0.25)
    h = reg.histogram("lat_seconds", "latency", edges=[0.1, 1.0])
    h.record_many([0.05, 0.5, 5.0])
    text = reg.to_prometheus()
    assert "# TYPE pkts_total counter" in text
    assert "pkts_total 7" in text
    assert "load 0.25" in text
    # histogram buckets are cumulative and end at +Inf
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="1"} 2' in text
    assert 'lat_seconds_bucket{le="+Inf"} 3' in text
    assert "lat_seconds_count 3" in text
    # JSON exposition round-trips
    assert json.loads(reg.to_json())["counters"]["pkts_total"]["value"] == 7


def test_global_registry_swap():
    mine = MetricRegistry()
    prev = obs.set_registry(mine)
    try:
        assert obs.get_registry() is mine
    finally:
        obs.set_registry(prev)
    assert obs.get_registry() is prev


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------
def test_span_nesting_and_tree():
    prev = obs.set_enabled(True)
    obs.reset_spans()
    try:
        for _ in range(3):
            with obs.span("tick"):
                with obs.span("tick/pack"):
                    pass
                with obs.span("tick/dispatch"):
                    pass
        tree = obs.span_tree()
    finally:
        obs.set_enabled(prev)
        obs.reset_spans()
    assert "tick" in tree and "tick/pack" in tree
    # re-entry aggregates into one node, not three
    assert "       3 calls" in tree
    assert obs.span_tree() == "(no spans recorded)"


def test_null_span_is_shared_singleton():
    prev = obs.set_enabled(False)
    try:
        assert not obs.enabled()
        # the whole disabled path: one shared object, no allocation
        assert obs.span("a") is obs.span("b")
        with obs.span("a"):
            pass
        assert obs.span_tree() == "(no spans recorded)"
    finally:
        obs.set_enabled(prev)


# ---------------------------------------------------------------------------
# reporter
# ---------------------------------------------------------------------------
def test_reporter_jsonl(tmp_path):
    reg = MetricRegistry()
    reg.counter("n_total").inc(9)
    path = tmp_path / "metrics.jsonl"
    rep = MetricsReporter(str(path), registry=reg, interval_s=3600.0)
    rep.dump_once()
    reg.counter("n_total").inc(1)
    rep.close()  # close flushes one final line
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert [x["seq"] for x in lines] == [0, 1]
    assert lines[0]["counters"]["n_total"]["value"] == 9
    assert lines[1]["counters"]["n_total"]["value"] == 10


def test_reporter_http_scrape():
    reg = MetricRegistry()
    reg.counter("scraped_total").inc(4)
    rep = MetricsReporter(None, registry=reg, http_port=0)
    try:
        url = f"http://127.0.0.1:{rep.http_port}/metrics"
        body = urllib.request.urlopen(url, timeout=10).read().decode()
    finally:
        rep.close()
    assert "scraped_total 4" in body


# ---------------------------------------------------------------------------
# serving integration: no-op contract + live parity
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def obs_setup(trained_pdt):
    pdt, _, tr = trained_pdt
    return Engine.from_model(pdt), tr


def _serve(eng, tr, *, ticks=61, **kw):
    stream = make_packet_stream(tr, seed=29, profile="steady")
    srv = FlowTableServer(eng, n_buckets=32, bucket_size=4, **kw)
    parts = [srv.ingest(b) for b in stream.ticks(ticks)]
    parts.append(srv.flush())
    return StreamVerdicts.concat(parts), srv


def test_obs_disabled_is_bit_identical(obs_setup):
    """SPLIDT_OBS=0 must not change a single result bit or stats field
    (counters are product behaviour; only *timing* is switchable)."""
    eng, tr = obs_setup
    prev = obs.set_enabled(True)
    try:
        v_on, s_on = _serve(eng, tr)
        obs.set_enabled(False)
        v_off, s_off = _serve(eng, tr)
    finally:
        obs.set_enabled(prev)
    for f in ("flow_id", "labels", "recircs", "exit_partition"):
        np.testing.assert_array_equal(getattr(v_on, f), getattr(v_off, f))
    for f in ServerStats.FIELDS:  # INCLUDING dispatches
        assert getattr(s_on.stats, f) == getattr(s_off.stats, f), f
    # the registry views agree too (recirc overhead is counter-derived)
    g = "serve_recirc_overhead"
    assert (s_on.registry.gauge(g).value
            == s_off.registry.gauge(g).value)


def test_obs_enabled_overhead_bounded(obs_setup):
    """Coarse perf bar: instrumented serving stays within a small
    constant factor of the no-op path.  Wide tolerance — shared CI
    boxes are noisy — but it still catches a per-packet Python loop or
    an accidental device sync sneaking into the record path."""
    import time
    eng, tr = obs_setup

    def best_of(n, on):
        prev = obs.set_enabled(on)
        try:
            _serve(eng, tr)  # warm compile caches outside the clock
            times = []
            for _ in range(n):
                t0 = time.perf_counter()
                _serve(eng, tr)
                times.append(time.perf_counter() - t0)
        finally:
            obs.set_enabled(prev)
        return min(times)

    off = best_of(3, False)
    on = best_of(3, True)
    assert on <= 4.0 * off + 0.25  # generous: noise + span bookkeeping


@pytest.mark.parametrize("te", ["fused", "legacy"])
@pytest.mark.parametrize("impl", ["fused", "pallas"])
def test_live_metrics_parity(obs_setup, te, impl):
    """Every live number is recomputable offline from the raw verdicts
    plus the replayable stream: exact counters, same-bucket latencies.
    This is the paper's <0.05% recirc-overhead metric made auditable."""
    eng, tr = obs_setup
    stream = make_packet_stream(tr, seed=29, profile="steady")
    srv = FlowTableServer(eng, n_buckets=32, bucket_size=4,
                          tick_engine=te, options=EngineOptions(impl=impl))

    offline_ttd = Histogram("offline_ttd", edges=TTD_EDGES)
    first: dict[int, float] = {}
    now = -np.inf
    packets = 0
    parts = []

    def record_offline(v, now):
        ttd = now - np.asarray([first[f] for f in v.flow_id], np.float64)
        offline_ttd.record_many(ttd)

    for b in stream.ticks(61):
        packets += b.n_packets
        now = max(now, float(b.arrival.max()))
        for f, t in zip(b.flow_id.tolist(), b.arrival.tolist()):
            first.setdefault(f, t)  # arrivals are non-decreasing
        v = srv.ingest(b)
        record_offline(v, now)
        parts.append(v)
    v = srv.flush()
    record_offline(v, now)
    parts.append(v)
    verdicts = StreamVerdicts.concat(parts)

    reg = srv.registry
    # -- exact counters ------------------------------------------------
    recircs = int(np.asarray(verdicts.recircs, np.int64).sum())
    assert reg.counter("serve_recircs_total").value == recircs
    assert reg.counter("serve_packets_total").value == packets
    assert reg.counter("serve_verdicts_total").value == verdicts.n_flows
    assert reg.counter("serve_dispatches_total").value == srv.stats.dispatches
    assert srv.stats.dispatches > 0
    # -- derived gauge: the paper's recirc-overhead metric -------------
    assert (reg.gauge("serve_recirc_overhead").value
            == recircs / packets)
    # -- latency histogram: identical buckets, same-bucket quantiles ---
    live = reg.histogram("serve_ttd_seconds", edges=TTD_EDGES)
    assert live.total == verdicts.n_flows  # every verdict got a TTD
    np.testing.assert_array_equal(live.counts, offline_ttd.counts)
    for q in (0.5, 0.99):
        assert live.quantile(q) == offline_ttd.quantile(q)
    # -- recirc histogram mirrors the verdict distribution -------------
    rh = reg.snapshot()["histograms"]["serve_recircs_per_flow"]
    assert rh["total"] == verdicts.n_flows
    assert rh["sum"] == pytest.approx(float(recircs))

"""Early-exit compaction + the non-terminating-flow sentinel.

Two properties anchor this file:

  * the compacted walk (``compact=True``: argsort-on-done survivor
    gather, power-of-two capacity buckets, scatter-back) is
    BIT-IDENTICAL to the dense walk and to ``PartitionedDT.predict``,
    for every backend and every exit-rate profile — compaction is a
    pure execution optimisation;
  * a flow that never takes an exit action reports the ``-1`` sentinels
    for ``labels``/``exit_partition`` in all three backends (this used
    to silently read as "class 0 at partition 0").

Both are instances of the bit-exactness contract in docs/PARITY.md.
"""
import numpy as np
import pytest

from repro.core.inference import Engine
from repro.core.partition import train_partitioned_dt
from repro.flows.synthetic import (
    EXIT_PROFILES, make_dataset, make_profile_dataset,
)
from repro.flows.windows import window_features, window_packets
from repro.kernels.compaction import bucket_caps, compact_perm
from repro.testing.hypothesis_compat import given, settings, strategies as st
from repro.core.inference import EngineOptions


# ---------------------------------------------------------------------------
# bucket ladder + survivor permutation (the jit-safe building blocks)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,floor", [(1, 128), (100, 64), (128, 128),
                                     (129, 128), (4096, 128), (5000, 64)])
def test_bucket_caps_ladder(n, floor):
    caps = bucket_caps(n, floor)
    assert caps[0] == 0                      # "everyone exited" fast path
    assert caps[-1] == n                     # full batch always fits
    assert list(caps) == sorted(set(caps))   # strictly increasing
    # interior rungs are floor * 2^i: every survivor count snaps to at
    # most 2x its bucket, so wasted work is bounded
    for i, c in enumerate(caps[1:-1]):
        assert c == floor * 2 ** i


def test_bucket_caps_rejects_bad_input():
    assert bucket_caps(0) == (0,)        # empty batch: degenerate ladder
    with pytest.raises(ValueError):
        bucket_caps(-1)
    with pytest.raises(ValueError):
        bucket_caps(16, floor=0)


def test_empty_batch_all_backends():
    """B=0 must not crash any backend, compacted or dense (regression:
    the looped trace path used to hit an unbound local on B=0)."""
    ds = make_dataset("d2", n_flows=120, seed=5)
    Xw = window_features(ds, 2)
    pdt = train_partitioned_dt(Xw, ds.labels, partition_sizes=[2, 2], k=3)
    wp = window_packets(ds, 2)[:0]
    eng = Engine.from_model(pdt)
    for kw in (dict(impl="fused"), dict(impl="fused", compact=True),
               dict(impl="looped"), dict(impl="looped", compact=True)):
        res = eng.run(wp, with_trace=True, **kw)
        assert res.labels.shape == (0,)
        assert res.n_unterminated == 0


def test_compact_perm_survivors_first_in_order():
    done = np.array([True, False, True, False, False, True])
    perm, n_active = map(np.asarray, compact_perm(done))
    assert int(n_active) == 3
    # stable: survivors keep their original relative order
    np.testing.assert_array_equal(perm[:3], [1, 3, 4])
    assert sorted(perm.tolist()) == list(range(6))


# ---------------------------------------------------------------------------
# compacted walk == dense walk == oracle (the tentpole's acceptance bar)
# ---------------------------------------------------------------------------
def _assert_identical(a, b):
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.recircs, b.recircs)
    np.testing.assert_array_equal(a.exit_partition, b.exit_partition)


@pytest.fixture(scope="module")
def compact_setup(trained_pdt):
    pdt, Xw, tr = trained_pdt
    wp = window_packets(tr, 3)
    eng = Engine.from_model(pdt)
    dense = eng.run(wp, with_trace=True)
    oracle = pdt.predict(Xw, return_trace=True)
    return pdt, wp, eng, dense, oracle


def test_compact_fused_bit_identical(compact_setup):
    pdt, wp, eng, dense, (labels, recircs, exit_p) = compact_setup
    comp = eng.run(wp, with_trace=True, options=EngineOptions(compact=True))
    _assert_identical(comp, dense)
    np.testing.assert_array_equal(comp.labels, labels)
    np.testing.assert_array_equal(comp.recircs, recircs)
    np.testing.assert_array_equal(comp.exit_partition, exit_p)


def test_compact_trace_is_survivor_masked(compact_setup):
    """The compacted trace computes registers ONLY for surviving flows:
    rows of flows that exited before hop p are zero, surviving rows are
    bit-identical to the dense trace (same per-flow math, just gathered
    through the capacity bucket and scattered back)."""
    pdt, wp, eng, dense, _ = compact_setup
    comp = eng.run(wp, with_trace=True, options=EngineOptions(compact=True))
    assert len(comp.regs_trace) == len(dense.regs_trace)
    exited_before = np.full(wp.shape[0], False)
    for p, (c, d) in enumerate(zip(comp.regs_trace, dense.regs_trace)):
        np.testing.assert_array_equal(c[~exited_before], d[~exited_before])
        assert not c[exited_before].any()
        exited_before |= dense.exit_partition == p
    assert exited_before.any()      # the model actually exits flows


def test_compact_looped_bit_identical(compact_setup):
    pdt, wp, eng, dense, _ = compact_setup
    _assert_identical(eng.run_looped(wp, options=EngineOptions(compact=True)), dense)


def test_compact_pallas_bit_identical(compact_setup):
    """Pallas step (in-jit SID dispatch) under compaction: the capacity
    gather feeds the dispatch smaller batches per bucket; verdicts stay
    bit-identical.  Sliced batch keeps interpret-mode compile sane."""
    pdt, wp, eng, dense, _ = compact_setup
    B = 256
    comp = eng.run(wp[:B], with_trace=False, options=EngineOptions(impl="pallas", compact=True))
    np.testing.assert_array_equal(comp.labels, dense.labels[:B])
    np.testing.assert_array_equal(comp.recircs, dense.recircs[:B])
    np.testing.assert_array_equal(comp.exit_partition,
                                  dense.exit_partition[:B])


@pytest.mark.parametrize("profile", EXIT_PROFILES)
def test_compact_profiles_all_backends_match_oracle(profile):
    """The acceptance matrix: backends x exit-rate profiles, all
    bit-identical to the numpy oracle with compaction on.  front /
    uniform / back-loaded profiles drive the bucket ladder through
    completely different shrink schedules (front: most flows gone after
    hop 0; back: no shrink until the last hop)."""
    ds = make_profile_dataset(profile, n_flows=360, seed=3)
    tr, _ = ds.split()
    Xw = window_features(tr, 3)
    pdt = train_partitioned_dt(Xw, tr.labels, partition_sizes=[2, 2, 2], k=3)
    wp = window_packets(tr, 3)
    labels, recircs, exit_p = pdt.predict(Xw, return_trace=True)
    eng = Engine.from_model(pdt)
    for kw in ({"impl": "fused"}, {"impl": "pallas"}, {"impl": "looped"}):
        res = eng.run(wp, with_trace=False,
                      options=EngineOptions(compact=True, **kw))
        np.testing.assert_array_equal(res.labels, labels, err_msg=str(kw))
        np.testing.assert_array_equal(res.recircs, recircs, err_msg=str(kw))
        np.testing.assert_array_equal(res.exit_partition, exit_p,
                                      err_msg=str(kw))
        assert res.n_unterminated == 0


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_compact_property_random_trees(seed):
    """Property over random datasets / tree shapes: compaction never
    changes a verdict, whatever the exit pattern."""
    rng = np.random.default_rng(seed)
    p = int(rng.integers(2, 4))
    sizes = [int(rng.integers(1, 4)) for _ in range(p)]
    k = int(rng.integers(2, 5))
    ds = make_dataset("d2", n_flows=220, seed=seed)
    Xw = window_features(ds, p)
    pdt = train_partitioned_dt(Xw, ds.labels, partition_sizes=sizes, k=k)
    wp = window_packets(ds, p)
    eng = Engine.from_model(pdt)
    dense = eng.run(wp, with_trace=False)
    _assert_identical(eng.run(wp, with_trace=False, options=EngineOptions(compact=True)), dense)
    _assert_identical(eng.run_looped(wp, with_trace=False, options=EngineOptions(compact=True)),
                      dense)
    np.testing.assert_array_equal(dense.labels, pdt.predict(Xw))


# ---------------------------------------------------------------------------
# non-terminating flows: the -1 sentinel bugfix
# ---------------------------------------------------------------------------
def _truncated_model():
    """A model whose final partition routes instead of exiting — the
    shape of a depth-truncated DSE candidate or a corrupt table.  Flows
    reaching those leaves never take an exit action."""
    ds = make_dataset("d2", n_flows=300, seed=7)
    Xw = window_features(ds, 3)
    pdt = train_partitioned_dt(Xw, ds.labels, partition_sizes=[2, 2, 2], k=3)
    last = pdt.n_partitions - 1
    for st_ in pdt.subtrees:
        if st_.partition == last:
            for leaf in st_.leaf_next_sid:
                st_.leaf_next_sid[leaf] = st_.sid      # self-loop
    return pdt, Xw, window_packets(ds, 3)


def test_non_terminating_flows_report_sentinels():
    """Previously failed: a flow whose walk fell off the end reported
    ``labels == 0`` and ``exit_partition == 0`` — indistinguishable from
    a real class-0 verdict at partition 0.  Now every backend (dense and
    compacted) reports -1/-1, the oracle agrees, and the count is
    surfaced on EngineResult."""
    pdt, Xw, wp = _truncated_model()
    labels, recircs, exit_p = pdt.predict(Xw, return_trace=True)
    stuck = labels == -1
    assert stuck.any() and not stuck.all()
    np.testing.assert_array_equal(exit_p[stuck], -1)
    eng = Engine.from_model(pdt)
    for kw in (dict(impl="fused"), dict(impl="fused", compact=True),
               dict(impl="pallas"), dict(impl="looped"),
               dict(impl="looped", compact=True)):
        res = eng.run(wp, with_trace=False, **kw)
        np.testing.assert_array_equal(res.labels, labels, err_msg=str(kw))
        np.testing.assert_array_equal(res.exit_partition, exit_p,
                                      err_msg=str(kw))
        np.testing.assert_array_equal(res.recircs, recircs, err_msg=str(kw))
        assert res.n_unterminated == int(stuck.sum())
        assert res.labels.dtype == np.int32          # concat-stable
    # downstream: TTD has no value for a flow that never exited — NaN,
    # not the last window's end (negative indexing used to wrap there)
    from repro.core.recirc import time_to_detection
    ds = make_dataset("d2", n_flows=300, seed=7)
    ttd = time_to_detection(ds.packets, ds.lengths, exit_p,
                            pdt.n_partitions)
    assert np.isnan(ttd[stuck]).all()
    assert np.isfinite(ttd[~stuck]).all()


def test_non_terminating_streaming_dtype_stable():
    """Streaming must carry the sentinel through padded chunks without
    upcasting (int32 in, int32 out, -1 preserved)."""
    from repro.serve.streaming import run_streaming
    pdt, Xw, wp = _truncated_model()
    eng = Engine.from_model(pdt)
    full = eng.run(wp, with_trace=False)
    res = run_streaming(eng, wp, options=EngineOptions(micro_batch=100))
    _assert_identical(res, full)
    assert res.labels.dtype == np.int32
    assert res.exit_partition.dtype == np.int32
    assert res.n_unterminated == full.n_unterminated > 0

"""Sharding resolution + multi-device behaviours (subprocess: 8 fake
devices) : elastic restore across mesh sizes, compressed psum under
shard_map, pipeline parallelism, and a miniature dry-run."""
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.distributed import pspec
from repro.models import model_zoo
from tests.conftest import run_subprocess


# ---------------------------------------------------------------------------
# spec resolution (no devices needed)
# ---------------------------------------------------------------------------
def test_divisibility_fallback():
    from repro.distributed.pspec import ParamDef, resolve_spec
    d = ParamDef((4, 64), ("kv", "head_dim"))
    spec = resolve_spec(d, {"data": 16, "model": 16})
    assert spec[0] is None          # 4 kv heads can't shard over 16
    d2 = ParamDef((64, 128), ("heads", "mlp"))
    spec2 = resolve_spec(d2, {"data": 16, "model": 16})
    assert spec2 == ("model", "model") or tuple(spec2) == ("model", "model")


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_param_and_spec_trees_align(arch_id):
    """Every arch: ParamDef tree resolves to same-structure spec tree and
    every spec's sharded dims divide exactly (full configs, abstract)."""
    import jax
    cfg = get_arch(arch_id)
    defs = model_zoo.get_model(cfg).param_defs(cfg)
    sds = pspec.abstract_params(defs)
    specs = pspec.resolve_specs(defs, {"data": 16, "model": 16})
    n_checked = 0

    def check(s, spec):
        nonlocal n_checked
        sizes = {"data": 16, "model": 16}
        for dim, entry in zip(s.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, (arch_id, s.shape, spec)
            n_checked += 1

    jax.tree.map(check, sds, specs, is_leaf=lambda x: x is None)
    assert n_checked > 0


def test_batch_spec_rules():
    code = """
import jax
from jax.sharding import AxisType
from repro.distributed.sharding import batch_spec, cache_spec
from repro.configs import get_arch
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
cfg = get_arch("tinyllama-1.1b")
s = batch_spec(mesh, (8, 128))
assert tuple(s)[0] in (("data",), "data"), s
s1 = batch_spec(mesh, (1, 65536))      # batch=1 -> sequence parallelism
assert tuple(s1)[1] == "data", s1
cs = cache_spec(mesh, (22, 8, 8192, 4, 64), cfg)
assert tuple(cs)[1] in (("data",), "data"), cs
print("ok")
"""
    assert "ok" in run_subprocess(code, devices=8)


# ---------------------------------------------------------------------------
# multi-device subprocess tests
# ---------------------------------------------------------------------------
def test_elastic_restore_across_mesh_sizes(tmp_path):
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P, AxisType
from repro.train import checkpoint as ckpt_lib
from repro.train.optimizer import AdamW

params = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
opt = AdamW(lr=0.1)
state = opt.init(params)

mesh8 = jax.make_mesh((8,), ("data",), axis_types=(AxisType.Auto,))
sh8 = jax.tree.map(lambda x: jax.device_put(
    x, NamedSharding(mesh8, P("data") if x.ndim else P())), state)
ckpt_lib.save(r"{tmp_path}/step_1", sh8)

# restore onto a 4-device mesh, then a 2-device mesh
for n in (4, 2):
    mesh = jax.make_mesh((n,), ("data",),
                         axis_types=(AxisType.Auto,))
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P("data") if hasattr(x, "ndim") and x.ndim else P()),
        state)
    restored, _ = ckpt_lib.restore(r"{tmp_path}/step_1", shardings)
    np.testing.assert_array_equal(
        np.asarray(restored.params["w"]), np.asarray(params["w"]))
    assert len(restored.params["w"].sharding.device_set) == n
print("ok")
"""
    assert "ok" in run_subprocess(code, devices=8)


def test_compressed_psum_shard_map():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P, AxisType
from repro.distributed.compression import compressed_psum

mesh = jax.make_mesh((4,), ("pod",), axis_types=(AxisType.Auto,))
g = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)), jnp.float32)

def f(g_local):
    out, err = compressed_psum(g_local[0], "pod")
    return out[None], err[None]

out, err = jax.jit(shard_map(f, mesh=mesh, in_specs=P("pod"),
                   out_specs=(P("pod"), P("pod"))))(g)
ref = g.mean(axis=0)
got = np.asarray(out)[0]
rel = np.abs(got - np.asarray(ref)).max() / np.abs(ref).max()
assert rel < 0.05, rel
print("ok", rel)
"""
    assert "ok" in run_subprocess(code, devices=8)


def test_pipeline_parallel_matches_sequential():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import make_stage_mesh, pipeline_forward

S, M, d = 4, 6, 16
mesh = make_stage_mesh(S)
rng = np.random.default_rng(0)
Ws = jnp.asarray(rng.normal(size=(S, d, d)) / np.sqrt(d), jnp.float32)
mbs = jnp.asarray(rng.normal(size=(M, 8, d)), jnp.float32)

def stage_fn(W, x):
    return jnp.tanh(x @ W)

pipe = jax.jit(pipeline_forward(stage_fn, mesh))
with jax.set_mesh(mesh):
    out = pipe(Ws, mbs)

ref = mbs
for s in range(S):
    ref = jnp.tanh(ref @ Ws[s])
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("ok")
"""
    assert "ok" in run_subprocess(code, devices=8)


def test_mini_dryrun_on_8_devices():
    """Guards the dry-run plumbing (build_cell/lower/compile/roofline)
    without 512 devices: reduced config, 2x4 mesh."""
    code = """
import dataclasses, jax
from jax.sharding import AxisType
import repro.launch.dryrun as dr
from repro.configs import get_arch
from repro.configs.base import ShapeCfg

mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
cfg = get_arch("tinyllama-1.1b").reduced()
shape = ShapeCfg("t", 64, 8, "train")
compiled, tl, tc, defs, _, _ = dr.lower_compile(cfg, shape, mesh, unroll=False)
ma = compiled.memory_analysis()
assert ma.argument_size_in_bytes > 0
ca = compiled.cost_analysis()
assert ca.get("flops", 0) > 0
from repro.analysis.roofline import parse_collectives
st = parse_collectives(compiled.as_text())
print("ok", sum(st.counts.values()) >= 0)

# decode cell too
shape_d = ShapeCfg("d", 128, 8, "decode")
compiled, *_ = dr.lower_compile(cfg, shape_d, mesh, unroll=False)
print("ok decode")
"""
    out = run_subprocess(code, devices=8, timeout=900)
    assert "ok decode" in out

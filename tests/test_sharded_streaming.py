"""Mesh-sharded streaming (subprocess: 8 fake CPU devices): the
shard_map'd partition walk over the flow-batch axis must be
indistinguishable from the single-device fused run — including uneven
final micro-batches, micro-batches that don't divide the device count,
and donation on/off.  Sharding is part of the bit-exactness contract
(docs/PARITY.md): a per-flow walk has no cross-shard reductions, so
shard count can never change bits."""
from tests.conftest import run_subprocess

_SETUP = """
import numpy as np, jax
from repro.core.inference import Engine
from repro.core.partition import train_partitioned_dt
from repro.flows.synthetic import make_dataset
from repro.flows.windows import window_features, window_packets
from repro.launch.mesh import make_flow_mesh
from repro.serve.streaming import run_streaming

ds = make_dataset("d2", n_flows=600)
tr, _ = ds.split()
Xw = window_features(tr, 3)
pdt = train_partitioned_dt(Xw, tr.labels, partition_sizes=[2, 3, 2], k=4)
wp = window_packets(tr, 3)
eng = Engine.from_model(pdt)
full = eng.run(wp, with_trace=False)
mesh = make_flow_mesh()
assert len(jax.devices()) == 8, jax.devices()

def check(res):
    np.testing.assert_array_equal(res.labels, full.labels)
    np.testing.assert_array_equal(res.recircs, full.recircs)
    np.testing.assert_array_equal(res.exit_partition, full.exit_partition)
"""


def test_sharded_parity_and_ragged_tails():
    """Sharded == single-device for micro-batches that leave an uneven
    final chunk, don't divide the 8-device mesh (rounded up in-scheduler),
    or exceed B entirely."""
    code = _SETUP + """
B = wp.shape[0]
for mb in (64, B - 1, 10_000, 96, 50):   # 50 -> rounded up to 56
    check(run_streaming(eng, wp, micro_batch=mb, mesh=mesh))
print("ok", B)
"""
    assert "ok" in run_subprocess(code, devices=8)


def test_sharded_donation_on_off():
    """Donated device buffers must not change verdicts (donate=True
    exercises buffer reuse across in-flight chunks; donate=False and
    inflight=1 restore the conservative path)."""
    code = _SETUP + """
check(run_streaming(eng, wp, micro_batch=128, mesh=mesh, donate=True))
check(run_streaming(eng, wp, micro_batch=128, mesh=mesh, donate=False))
check(run_streaming(eng, wp, micro_batch=128, mesh=mesh, donate=True,
                    inflight=1))
print("ok")
"""
    assert "ok" in run_subprocess(code, devices=8)


def test_sharded_outputs_actually_sharded():
    """The walk must fan out: run the shard_map'd walk directly and
    assert its outputs span all 8 devices (not a degenerate 1-device
    execution)."""
    code = _SETUP + """
import jax.numpy as jnp
from repro.core.inference import FUSED_BACKEND
from repro.serve.streaming import _sharded_walk
walk = _sharded_walk(mesh, eng.ret.n_subtrees, False, FUSED_BACKEND.step)
P = eng.tables.n_partitions
batch = jnp.asarray(wp[:128, :P], jnp.float32)
labels, _, _ = walk(batch, eng.dev)
assert len(labels.sharding.device_set) == 8, labels.sharding
print("ok")
"""
    assert "ok" in run_subprocess(code, devices=8)


def test_sharded_compact_walk():
    """Early-exit compaction under shard_map: each shard argsorts its
    own survivors and picks its own capacity bucket (data-dependent
    lax.switch per shard, no collectives) — verdicts bit-identical to
    the single-device dense run, fused and pallas steps alike."""
    code = _SETUP + """
for mb in (64, 96):
    check(run_streaming(eng, wp, micro_batch=mb, mesh=mesh, compact=True))
res = run_streaming(eng, wp[:160], micro_batch=64, mesh=mesh,
                    impl="pallas", compact=True)
np.testing.assert_array_equal(res.labels, full.labels[:160])
np.testing.assert_array_equal(res.recircs, full.recircs[:160])
np.testing.assert_array_equal(res.exit_partition, full.exit_partition[:160])
print("ok")
"""
    assert "ok" in run_subprocess(code, devices=8)


def test_sharded_pallas_backend():
    """The in-jit SID dispatch composes with shard_map: the Pallas walk
    (interpret mode) streams sharded and stays bit-identical."""
    code = _SETUP + """
res = run_streaming(eng, wp[:160], micro_batch=64, mesh=mesh, impl="pallas")
np.testing.assert_array_equal(res.labels, full.labels[:160])
np.testing.assert_array_equal(res.recircs, full.recircs[:160])
np.testing.assert_array_equal(res.exit_partition, full.exit_partition[:160])
print("ok")
"""
    assert "ok" in run_subprocess(code, devices=8)

"""Feature semantics: numpy oracle vs the engine's jnp math."""
import jax.numpy as jnp
import numpy as np
import pytest
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core import features as F
from repro.kernels.ref import feature_window_ref


def random_packets(rng, b, w):
    pk = np.zeros((b, w, F.PKT_NFIELDS), np.float32)
    pk[..., F.PKT_TS] = np.cumsum(rng.random((b, w)), axis=1)
    pk[..., F.PKT_SIZE] = rng.integers(40, 1500, (b, w))
    pk[..., F.PKT_DIR] = rng.integers(0, 2, (b, w))
    pk[..., F.PKT_FLAGS] = rng.integers(0, 64, (b, w))
    pk[..., F.PKT_IAT] = rng.random((b, w))
    valid_len = rng.integers(1, w + 1, b)
    pk[..., F.PKT_VALID] = (np.arange(w)[None] < valid_len[:, None])
    return pk


def test_registry_size_matches_paper_d1():
    assert F.N_FEATURES == 41     # D1's N in the paper


def test_all_ops_and_preds_covered():
    ops = {s.op for s in F.REGISTRY}
    assert {F.OP_COUNT, F.OP_SUM, F.OP_MAX, F.OP_MIN, F.OP_LAST,
            F.OP_FIRST, F.OP_SUMSQ} <= ops
    assert F.max_dep_depth(range(F.N_FEATURES)) <= 3   # paper: <= 3 stages


@pytest.mark.parametrize("fid", range(0, F.N_FEATURES, 5))
def test_numpy_vs_jnp_engine_math(fid):
    rng = np.random.default_rng(fid)
    pk = random_packets(rng, 32, 24)
    spec = F.REGISTRY[fid]
    oracle = F.compute_feature(pk, spec)
    n = pk.shape[0]
    row = lambda v: jnp.full((n, 1), v, jnp.int32)
    out = feature_window_ref(
        jnp.asarray(pk), row(spec.op), row(spec.field), row(spec.pred),
        jnp.full((n, 1), spec.init_value, jnp.float32))
    np.testing.assert_allclose(np.asarray(out)[:, 0], oracle, rtol=1e-5,
                               atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 40))
def test_count_sum_invariants(seed, w):
    """Property: COUNT == #valid packets; SUM(size) == sum over valid."""
    rng = np.random.default_rng(seed)
    pk = random_packets(rng, 4, w)
    count = F.compute_feature(pk, F.REGISTRY[F.NAME_TO_FID["pkt_count"]])
    total = F.compute_feature(pk, F.REGISTRY[F.NAME_TO_FID["byte_sum"]])
    valid = pk[..., F.PKT_VALID] > 0
    np.testing.assert_array_equal(count, valid.sum(-1))
    np.testing.assert_allclose(
        total, (pk[..., F.PKT_SIZE] * valid).sum(-1), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_min_max_bounds(seed):
    rng = np.random.default_rng(seed)
    pk = random_packets(rng, 8, 16)
    mx = F.compute_feature(pk, F.REGISTRY[F.NAME_TO_FID["pkt_size_max"]])
    mn = F.compute_feature(pk, F.REGISTRY[F.NAME_TO_FID["pkt_size_min"]])
    assert (mx >= mn - 1e-6).all()
    assert (mx <= 1500).all() and (mn >= 40).all()

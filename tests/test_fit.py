"""repro.fit: jitted trainer parity, budget enforcement, batched DSE.

The zero-tolerance half of the cross-trainer contract stated in
``core/tree.py`` and docs/PARITY.md: the jitted level-synchronous
grower must reproduce the numpy oracle *structurally* -- identical
feature/threshold/left/right/value arrays, node for node -- so
``trainer="jax"`` DSE runs are interchangeable with ``trainer="numpy"``
ones.
"""
import numpy as np
import pytest

from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core.dse import (
    Config, SearchSpace, bayes_search, make_splidt_evaluator,
)
from repro.core.partition import train_partitioned_dt
from repro.core.tree import macro_f1, train_tree
from repro.fit import fleet_predict, train_forest, train_tree_jax
from repro.flows.windows import window_features, window_packets


def _assert_trees_equal(a, b, ctx=""):
    for name in ("feature", "threshold", "left", "right", "value"):
        np.testing.assert_array_equal(
            getattr(a, name), getattr(b, name),
            err_msg=f"{ctx}: Tree.{name} diverged")


# ---------------------------------------------------------------------------
# (a) the k budget holds for jitted trees
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 4), st.integers(1, 6))
def test_jax_trees_respect_k_budget(seed, k, depth):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(40, 300))
    m = int(rng.integers(max(k, 2), 14))
    C = int(rng.integers(2, 5))
    X = rng.normal(size=(n, m)).astype(np.float32)
    y = rng.integers(0, C, n)
    t = train_tree_jax(X, y, max_depth=depth, k_features=k, n_classes=C)
    assert len(t.used_features()) <= k
    assert t.max_depth <= depth


# ---------------------------------------------------------------------------
# (b) structural parity with the numpy oracle across random shapes
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_grower_structural_parity(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(30, 400))
    m = int(rng.integers(2, 14))
    C = int(rng.integers(2, 6))
    depth = int(rng.integers(1, 7))
    k = int(rng.integers(1, m + 1)) if rng.random() < 0.7 else None
    msl = int(rng.integers(1, 6))
    X = rng.normal(size=(n, m)).astype(np.float32)
    if rng.random() < 0.3:      # duplicate-heavy columns stress tie-breaks
        X = np.round(X * 2) / 2
    y = rng.integers(0, C, n)
    kw = dict(max_depth=depth, k_features=k, n_classes=C,
              min_samples_leaf=msl)
    _assert_trees_equal(train_tree(X, y, **kw), train_tree_jax(X, y, **kw),
                        ctx=f"seed={seed}")


def test_grower_parity_with_allowed_features():
    rng = np.random.default_rng(7)
    X = rng.normal(size=(250, 10)).astype(np.float32)
    y = rng.integers(0, 3, 250)
    allowed = np.array([1, 4, 7])
    kw = dict(max_depth=5, k_features=2, n_classes=3,
              allowed_features=allowed)
    t1, t2 = train_tree(X, y, **kw), train_tree_jax(X, y, **kw)
    _assert_trees_equal(t1, t2)
    assert set(t2.used_features()) <= set(allowed.tolist())


def test_forest_matches_per_tree_training():
    """One vmapped fleet dispatch == training each subset separately."""
    rng = np.random.default_rng(3)
    Xs, ys = [], []
    for _ in range(5):
        n = int(rng.integers(40, 200))
        Xs.append(rng.normal(size=(n, 8)).astype(np.float32))
        ys.append(rng.integers(0, 3, n))
    fleet = train_forest(Xs, ys, max_depth=4, k_features=3, n_classes=3)
    for i, (X, y) in enumerate(zip(Xs, ys)):
        solo = train_tree(X, y, max_depth=4, k_features=3, n_classes=3)
        _assert_trees_equal(solo, fleet[i], ctx=f"fleet[{i}]")


def test_partitioned_trainer_parity(small_flow_ds):
    """trainer="jax" trains the full PartitionedDT under jit, identical
    to the numpy trainer subtree-for-subtree (acceptance criterion)."""
    tr, _ = small_flow_ds.split()
    Xw = window_features(tr, 3)
    kw = dict(partition_sizes=[2, 3, 2], k=4,
              n_classes=small_flow_ds.n_classes)
    p1 = train_partitioned_dt(Xw, tr.labels, **kw)
    p2 = train_partitioned_dt(Xw, tr.labels, trainer="jax", **kw)
    assert len(p1.subtrees) == len(p2.subtrees)
    for a, b in zip(p1.subtrees, p2.subtrees):
        assert (a.sid, a.partition) == (b.sid, b.partition)
        assert a.leaf_next_sid == b.leaf_next_sid
        assert a.leaf_label == b.leaf_label
        _assert_trees_equal(a.tree, b.tree, ctx=f"sid={a.sid}")


def test_partitioned_trainer_rejects_unknown():
    with pytest.raises(ValueError, match="trainer"):
        train_partitioned_dt(np.zeros((8, 1, 3)), np.zeros(8, np.int64),
                             partition_sizes=[1], k=1, trainer="torch")


# ---------------------------------------------------------------------------
# batched DSE evaluation
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dse_setup(small_flow_ds):
    tr, te = small_flow_ds.split()
    P = 3
    return dict(
        Xw_tr=window_features(tr, P), y_tr=tr.labels,
        Xw_te=window_features(te, P), y_te=te.labels,
        wp_te=window_packets(te, P), n_classes=small_flow_ds.n_classes)


def test_fleet_predict_matches_oracle(dse_setup):
    s = dse_setup
    pdts = [train_partitioned_dt(s["Xw_tr"][:, :p], s["y_tr"],
                                 partition_sizes=sizes, k=k,
                                 n_classes=s["n_classes"])
            for p, sizes, k in [(3, [2, 2, 2], 3), (2, [3, 2], 4),
                                (1, [4], 2)]]
    labels, recircs, exit_p = fleet_predict(pdts, s["wp_te"])
    for i, pdt in enumerate(pdts):
        ref, rr, ee = pdt.predict(s["Xw_te"][:, :pdt.n_partitions],
                                  return_trace=True)
        np.testing.assert_array_equal(labels[i], ref)
        np.testing.assert_array_equal(recircs[i], rr)
        np.testing.assert_array_equal(exit_p[i], ee)


def test_evaluate_batch_matches_serial(dse_setup):
    s = dse_setup
    ev = make_splidt_evaluator(
        s["Xw_tr"], s["y_tr"], s["Xw_te"], s["y_te"],
        n_classes=s["n_classes"], flows=100_000, win_pkts_te=s["wp_te"])
    cfgs = [Config(3, (2, 2)), Config(2, (3,)), Config(4, (2, 2, 2))]
    batched = ev.evaluate_batch(cfgs)
    for cfg, b in zip(cfgs, batched):
        a = ev(cfg)
        assert a == b, cfg


# (c) jax-trainer DSE reproduces the numpy-trainer history exactly
def test_dse_trainer_parity(dse_setup):
    s = dse_setup
    space = SearchSpace(max_partitions=3, k_max=4, depth_max=4)
    kw = dict(n_classes=s["n_classes"], flows=100_000)
    common = (s["Xw_tr"], s["y_tr"], s["Xw_te"], s["y_te"])
    r_np = bayes_search(make_splidt_evaluator(*common, **kw), space,
                        n_iterations=2, batch=3, n_init=4, seed=0)
    r_jax = bayes_search(
        make_splidt_evaluator(*common, trainer="jax",
                              win_pkts_te=s["wp_te"], **kw),
        space, n_iterations=2, batch=3, n_init=4, seed=0)
    assert [e.config for e in r_np.history] == [e.config for e in r_jax.history]
    assert [e.f1 for e in r_np.history] == [e.f1 for e in r_jax.history]
    assert [e.feasible for e in r_np.history] == [
        e.feasible for e in r_jax.history]
    assert r_np.best.config == r_jax.best.config
    assert r_np.iterations_to_best == r_jax.iterations_to_best


# ---------------------------------------------------------------------------
# bayes_search batch fill (satellite: no silent underfill)
# ---------------------------------------------------------------------------
def test_bayes_search_full_batches():
    """Every iteration evaluates exactly ``batch`` distinct configs even
    when the sampler keeps colliding with ``seen`` (tiny space)."""
    space = SearchSpace(max_partitions=1, k_max=2, depth_max=3)  # 6 configs
    calls: list[Config] = []

    def fake_eval(cfg: Config):
        calls.append(cfg)
        from repro.core.dse import Evaluation
        return Evaluation(config=cfg, f1=0.5, feasible=True,
                          flow_capacity=1, tcam_entries=1, register_bits=1,
                          recirc_mbps=0.0, n_subtrees=1, unique_features=1)

    res = bayes_search(fake_eval, space, n_iterations=2, batch=2, n_init=2,
                       n_candidates=8, seed=0)
    assert len(res.history) == 2 + 2 * 2      # n_init + iterations x batch
    assert len(set(calls)) == len(calls)      # never re-evaluates a config


# ---------------------------------------------------------------------------
# vectorised macro_f1 (satellite)
# ---------------------------------------------------------------------------
def _macro_f1_loop(y_true, y_pred, n_classes):
    f1s = []
    for c in range(n_classes):
        tp = int(((y_pred == c) & (y_true == c)).sum())
        fp = int(((y_pred == c) & (y_true != c)).sum())
        fn = int(((y_pred != c) & (y_true == c)).sum())
        if tp + fp + fn == 0:
            continue
        prec = tp / (tp + fp) if tp + fp else 0.0
        rec = tp / (tp + fn) if tp + fn else 0.0
        f1s.append(0.0 if prec + rec == 0 else 2 * prec * rec / (prec + rec))
    return float(np.mean(f1s)) if f1s else 0.0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6))
def test_macro_f1_matches_per_class_loop(seed, C):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 200))
    y_true = rng.integers(0, C, n)
    y_pred = rng.integers(-1, C, n)     # includes the -1 sentinel
    assert macro_f1(y_true, y_pred, C) == _macro_f1_loop(y_true, y_pred, C)


def test_macro_f1_empty_and_perfect():
    y = np.array([0, 1, 2, 2])
    assert macro_f1(y, y, 3) == 1.0
    assert macro_f1(np.zeros(0, np.int64), np.zeros(0, np.int64), 3) == 0.0

"""Resource model anchors, recirculation model, BO design search."""
import numpy as np
import pytest

from repro.core.dse import (
    GP, SearchSpace, bayes_search, expected_improvement,
    make_splidt_evaluator,
)
from repro.core.recirc import HADOOP, WEBSERVER, recirc_bandwidth
from repro.core.resources import TOFINO1, estimate, estimate_oneshot
from repro.flows.windows import window_features


def test_oneshot_anchor_points():
    """Paper footnote 1: k=4 ~ 100K flows, k=6 fewer, on Tofino1."""
    r4 = estimate_oneshot(4, 5000, 40, depth=13)
    r6 = estimate_oneshot(6, 5000, 56, depth=13)
    assert 60_000 <= r6.flow_capacity < r4.flow_capacity <= 400_000


def test_splidt_constant_stage_cost(trained_pdt):
    """SpliDT's stage cost must NOT grow with total depth (time-sharing)."""
    pdt, _, _ = trained_pdt
    rep = estimate(pdt)
    assert rep.stages_logic <= TOFINO1.logic_stages + 3
    assert rep.feasible or rep.reasons


def test_feasibility_monotone_in_flows(trained_pdt):
    pdt, _, _ = trained_pdt
    caps = [estimate(pdt, flows=f).feasible
            for f in (1_000, 100_000, 10_000_000)]
    # once infeasible, stays infeasible as flows grow
    assert caps == sorted(caps, reverse=True)


def test_precision_increases_capacity(trained_pdt):
    """Paper Fig. 12: 16/8-bit registers support 2x/4x the flows."""
    pdt, _, _ = trained_pdt
    c32 = estimate(pdt, bits=32).flow_capacity
    c16 = estimate(pdt, bits=16).flow_capacity
    c8 = estimate(pdt, bits=8).flow_capacity
    assert c32 < c16 < c8
    assert c16 / c32 > 1.5 and c8 / c32 > 2.5


def test_recirc_bandwidth_scales(trained_pdt):
    pdt, Xw, tr = trained_pdt
    _, recircs, _ = pdt.predict(Xw, return_trace=True)
    ws = recirc_bandwidth(recircs, 1_000_000, WEBSERVER)
    hd = recirc_bandwidth(recircs, 1_000_000, HADOOP)
    assert hd.mean_mbps == pytest.approx(2 * ws.mean_mbps, rel=0.01)
    assert ws.fraction_of_budget < 0.001      # paper: <0.05% worst case
    half = recirc_bandwidth(recircs, 500_000, WEBSERVER)
    assert half.mean_mbps == pytest.approx(ws.mean_mbps / 2, rel=0.01)


def test_gp_and_ei():
    rng = np.random.default_rng(0)
    X = rng.random((20, 3))
    y = np.sin(X.sum(1) * 3)
    gp = GP().fit(X, y)
    mu, sd = gp.predict(X)
    np.testing.assert_allclose(mu, y, atol=0.15)   # interpolates
    assert (sd >= 0).all()
    ei = expected_improvement(np.array([1.0, 0.0]), np.array([0.1, 0.1]), 0.5)
    assert ei[0] > ei[1]


def test_bayes_search_finds_feasible(small_flow_ds):
    tr, te = small_flow_ds.split()
    P = 4
    Xw_tr = window_features(tr, P)
    Xw_te = window_features(te, P)
    ev = make_splidt_evaluator(Xw_tr, tr.labels, Xw_te, te.labels,
                               n_classes=small_flow_ds.n_classes,
                               flows=100_000)
    space = SearchSpace(max_partitions=4, k_max=5, depth_max=6)
    res = bayes_search(ev, space, n_iterations=3, batch=2, n_init=4, seed=1)
    assert res.best is not None
    assert res.best.feasible and res.best.f1 > 0.4
    pareto = res.pareto()
    assert pareto
    # pareto set is non-dominated
    for a in pareto:
        for b in pareto:
            assert not (b.f1 > a.f1 and b.flow_capacity > a.flow_capacity)

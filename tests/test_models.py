"""Model zoo: per-arch smoke tests (reduced configs, one train step,
shape + NaN assertions) and cache-path equivalence (prefill+decode ==
full forward) -- the serving-correctness property."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch
from repro.configs.base import SHAPES, ShapeCfg, shape_supported
from repro.distributed import pspec
from repro.models import model_zoo

ALL_ARCHS = sorted(ARCHS)
SMOKE = ShapeCfg("smoke", 32, 2, "train")


def _setup(arch_id):
    cfg = get_arch(arch_id).reduced()
    zoo = model_zoo.get_model(cfg)
    params = pspec.init_params(zoo.param_defs(cfg), jax.random.key(0))
    return cfg, zoo, params


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_smoke_train_step(arch_id):
    cfg, zoo, params = _setup(arch_id)
    batch = model_zoo.concrete_batch(cfg, SMOKE)
    loss, grads = jax.value_and_grad(
        lambda p: zoo.loss_fn(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    assert float(loss) < 2 * np.log(cfg.vocab) + 2
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_forward_shapes_and_finite(arch_id):
    cfg, zoo, params = _setup(arch_id)
    batch = model_zoo.concrete_batch(cfg, SMOKE)
    lg, _, _ = zoo.forward(cfg, params, batch, mode="train")
    T = batch["tokens"].shape[1] + (cfg.n_image_tokens
                                    if "img_embeds" in batch else 0)
    assert lg.shape == (2, T, cfg.vocab)
    assert bool(jnp.isfinite(lg.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch_id", ALL_ARCHS)
def test_prefill_decode_matches_full_forward(arch_id):
    """Teacher-forced: prefill(t[:k]) then decode t[k], t[k+1]... must
    reproduce the full forward's logits at those positions."""
    cfg, zoo, params = _setup(arch_id)
    B, T, k = 2, 12, 8
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, T)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family.value == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, T * cfg.dec_ratio, cfg.d_model)), jnp.bfloat16)
    if cfg.family.value == "vlm":
        batch["img_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_image_tokens, cfg.d_model)), jnp.bfloat16)

    # reference: teacher-forced full forward in INFERENCE mode (matters
    # for MoE: training uses capacity dropping, serving is dropless)
    full_lg, _, _ = zoo.forward(cfg, params, batch, mode="prefill")
    off = cfg.n_image_tokens if "img_embeds" in batch else 0

    cache = zoo.init_cache(cfg, B, T + off + 4)
    pre = dict(batch)
    pre["tokens"] = toks[:, :k]
    lg, cache, _ = zoo.forward(cfg, params, pre, mode="prefill", cache=cache)
    outs = [lg[:, -1]]
    for t in range(k, T):
        lg, cache, _ = zoo.forward(cfg, params, {"tokens": toks[:, t:t + 1]},
                                   mode="decode", cache=cache)
        outs.append(lg[:, -1])
    # outs[i] should equal full_lg at position off+k-1+i
    for i, o in enumerate(outs[:-1]):
        ref = full_lg[:, off + k - 1 + i]
        err = float(jnp.abs(o.astype(jnp.float32)
                            - ref.astype(jnp.float32)).max())
        scale = float(jnp.abs(ref.astype(jnp.float32)).max()) + 1e-6
        assert err / scale < 0.05, (arch_id, i, err, scale)


def test_mla_absorbed_equals_direct():
    cfg = get_arch("deepseek-v2-236b").reduced()
    from repro.models import mla as mla_lib
    defs = mla_lib.mla_defs(cfg)
    params = pspec.init_params(defs, jax.random.key(1))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 6, cfg.d_model)), jnp.float32)
    cache1 = mla_lib.init_mla_cache(cfg, 2, 8)
    cache2 = mla_lib.init_mla_cache(cfg, 2, 8)
    o1, _ = mla_lib.mla_attention(params, x, cfg, cache=cache1, absorbed=True)
    o2, _ = mla_lib.mla_attention(params, x, cfg, cache=cache2, absorbed=False)
    scale = float(np.abs(np.asarray(o2, np.float32)).max())
    np.testing.assert_allclose(np.asarray(o1, np.float32),
                               np.asarray(o2, np.float32),
                               atol=0.01 * scale)   # bf16 assoc. rounding


def test_moe_routing_is_sparse_and_normalised():
    cfg = get_arch("qwen2-moe-a2.7b").reduced()
    from repro.models import moe as moe_lib
    defs = moe_lib.moe_defs(cfg.d_model, cfg.moe)
    params = pspec.init_params(defs, jax.random.key(2))
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    out, aux = moe_lib.moe_ffn(params, x, cfg.moe)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) > 0.5  # ~1 when balanced


def test_moe_pad_experts_never_routed():
    from repro.models.moe import padded_experts
    cfg = get_arch("qwen2-moe-a2.7b")
    assert padded_experts(cfg.moe) == 64          # 60 -> 64 on 16-way EP
    assert padded_experts(get_arch("deepseek-v2-236b").moe) == 160


@pytest.mark.parametrize("arch_id", ["rwkv6-1.6b", "zamba2-2.7b"])
def test_ssm_state_is_constant_in_context(arch_id):
    """The long_500k enabler: cache bytes must not depend on seq_len."""
    cfg, zoo, _ = _setup(arch_id)
    c1 = jax.eval_shape(lambda: zoo.init_cache(cfg, 1, 1024))
    c2 = jax.eval_shape(lambda: zoo.init_cache(cfg, 1, 65536))
    b1 = sum(np.prod(l.shape) * l.dtype.itemsize
             for l in jax.tree.leaves(c1)
             if l.shape and l.shape[-1] != 0)
    b2 = sum(np.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(c2))
    if arch_id.startswith("rwkv"):
        assert b1 == b2                      # pure recurrent state
    else:
        assert b2 < b1 * 70                  # only the shared-attn window grows


def test_shape_support_matrix():
    """DESIGN.md §Arch-applicability: 32 runnable + 8 documented skips."""
    runnable = skips = 0
    for aid, cfg in ARCHS.items():
        for s in SHAPES.values():
            ok, reason = shape_supported(cfg, s)
            if ok:
                runnable += 1
            else:
                skips += 1
                assert s.name == "long_500k" and reason
    assert runnable == 32 and skips == 8


def test_param_counts_match_published():
    expect = {"tinyllama-1.1b": 1.1e9, "minitron-8b": 9.9e9,
              "granite-3-2b": 2.5e9, "stablelm-3b": 2.8e9,
              "rwkv6-1.6b": 1.6e9, "whisper-medium": 0.8e9,
              "qwen2-moe-a2.7b": 15.2e9, "deepseek-v2-236b": 236e9,
              "paligemma-3b": 1.9e9, "zamba2-2.7b": 2.4e9}
    for aid, n in expect.items():
        got = model_zoo.param_count(get_arch(aid))
        assert abs(got - n) / n < 0.12, (aid, got, n)

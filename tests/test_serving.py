"""Continuous batching engine: drain, slot isolation, reuse."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.distributed import pspec
from repro.models import model_zoo
from repro.serve.batching import ContinuousBatcher, Request
from repro.serve.serve_step import make_decode_step, make_prefill_step


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("tinyllama-1.1b").reduced()
    zoo = model_zoo.get_model(cfg)
    params = pspec.init_params(zoo.param_defs(cfg), jax.random.key(0))
    return cfg, zoo, params


def _reference_decode(cfg, zoo, params, prompt, n_new):
    """Single-request greedy decode (no batching engine)."""
    import jax.numpy as jnp
    cache = zoo.init_cache(cfg, 1, 64)
    prefill = make_prefill_step(cfg)
    decode = make_decode_step(cfg)
    lg, cache = prefill(params, {"tokens": jnp.asarray([prompt], jnp.int32)},
                        cache)
    out = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(n_new - 1):
        nxt, cache = decode(params, jnp.asarray([[out[-1]]], jnp.int32),
                            cache, None)
        out.append(int(nxt[0, 0]))
    return out


def test_engine_drains_and_reuses_slots(setup):
    cfg, zoo, params = setup
    eng = ContinuousBatcher(cfg, params, slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab, 5).tolist(), max_new=4))
    stats = eng.run_until_drained()
    assert stats.completed == 5
    assert stats.admitted == 5
    assert max(stats.slot_occupancy) <= 2     # fixed register pool


def test_slot_isolation_outputs_match_reference(setup):
    """Requests decoded through the shared slot pool must produce the
    same tokens as isolated single-request decoding."""
    cfg, zoo, params = setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, 6).tolist() for _ in range(3)]
    eng = ContinuousBatcher(cfg, params, slots=2, max_len=64)
    reqs = [Request(rid=i, prompt=p, max_new=5) for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    for r in reqs:
        ref = _reference_decode(cfg, zoo, params, r.prompt, 5)
        assert r.out == ref, (r.rid, r.out, ref)

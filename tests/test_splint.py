"""splint: every rule fires on a seeded violation, stays silent on the
clean tree, and the suppression/autofix machinery holds its contracts.

The fixtures are deliberately tiny known-bad snippets (docs/ANALYSIS.md
documents each rule); the clean-tree test is the acceptance bar the CI
splint job enforces: ``python -m tools.splint src tests benchmarks``
exits 0 on the landed tree.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from tools.splint import RULES, fix_source, lint_source, render_json
from tools.splint.__main__ import REPO, main

KPATH = "src/repro/kernels/fixture.py"      # parity-critical scope
CPATH = "src/repro/core/fixture.py"         # general src scope
TPATH = "tests/fixture.py"                  # tests scope (R005)

# Spelled via a variable so the pragma scanner (line-based, by design)
# never sees a literal pragma on a physical line of THIS file — splint
# lints its own test suite as part of the clean-tree acceptance test.
SP = "splint"


def codes(src: str, path: str = CPATH) -> list[str]:
    return [d.code for d in lint_source(src, path)]


# ---------------------------------------------------------------------------
# one seeded violation per rule
# ---------------------------------------------------------------------------

def test_r001_fires_on_stray_reduction_in_kernels():
    src = "import jax.numpy as jnp\ndef f(x):\n    return jnp.sum(x, axis=1)\n"
    assert codes(src, KPATH) == ["R001"]
    # the same code outside kernels//fit/ is not parity-critical
    assert codes(src, CPATH) == []


def test_r001_covers_dot_cumsum():
    src = ("import jax.numpy as jnp\n"
           "def f(x, w):\n"
           "    return jnp.dot(x, w) + jnp.cumsum(x, axis=0)\n")
    assert codes(src, "src/repro/fit/fixture.py") == ["R001", "R001"]


def test_r002_fires_on_host_sync_in_jit_helper():
    src = (
        "import jax\nimport jax.numpy as jnp\n"
        "@jax.jit\n"
        "def root(x):\n"
        "    return helper(x)\n"
        "def helper(x):\n"
        "    return float(x.mean().item())\n")
    got = codes(src, KPATH)
    assert "R002" in got                      # .item() in a reachable helper


def test_r002_reaches_through_the_call_graph_not_everything():
    src = (
        "import jax\nimport jax.numpy as jnp\n"
        "@jax.jit\n"
        "def root(x):\n"
        "    return x\n"
        "def cold_path(x):\n"
        "    return x.item()\n")              # NOT reachable from root
    assert codes(src, KPATH) == []


def test_r002_static_shapes_do_not_fire():
    src = (
        "import functools\nimport jax\nimport numpy as np\n"
        "@functools.partial(jax.jit, static_argnames=('n',))\n"
        "def root(x, n):\n"
        "    m = int(x.shape[0])\n"
        "    k = int(np.prod(x.shape))\n"
        "    j = int(n)\n"
        "    return x[: m + k + j]\n")
    assert codes(src, KPATH) == []


def test_r003_fires_and_scopes():
    src = "import jax.numpy as jnp\nx = jnp.zeros((4, 4))\n"
    assert codes(src) == ["R003"]
    ok = ("import jax.numpy as jnp\n"
          "a = jnp.zeros((4,), jnp.int32)\n"          # positional dtype
          "b = jnp.full((4,), -1, jnp.int32)\n"
          "c = jnp.arange(4, dtype=jnp.int32)\n")
    assert codes(ok) == []
    # excluded LM prototype tree: same violation, no diagnostic
    assert codes(src, "src/repro/models/fixture.py") == []


def test_r004_fires_on_global_rng_allows_seeded():
    bad = "import numpy as np\nx = np.random.rand(3)\nnp.random.seed(0)\n"
    assert codes(bad) == ["R004", "R004"]
    ok = ("import numpy as np\n"
          "rng = np.random.default_rng(np.random.SeedSequence([1, 2]))\n"
          "def f(r: np.random.Generator):\n    return r\n")
    assert codes(ok) == []
    unseeded = "import numpy as np\nrng = np.random.default_rng()\n"
    assert codes(unseeded) == ["R004"]


def test_r005_fires_on_legacy_engine_kwargs():
    src = "def f(eng, wp):\n    return eng.run(wp, impl='fused')\n"
    assert codes(src, TPATH) == ["R005"]
    # options= is the blessed spelling
    ok = ("def f(eng, wp, EngineOptions):\n"
          "    return eng.run(wp, options=EngineOptions(impl='fused'))\n")
    assert codes(ok, TPATH) == []
    # the shim file itself is exempt
    assert codes(src, "src/repro/core/inference.py") == []


def test_r006_fires_on_tracer_branch():
    src = (
        "import jax\nimport jax.numpy as jnp\n"
        "@jax.jit\n"
        "def root(x):\n"
        "    if jnp.any(x > 0):\n"
        "        return x\n"
        "    while jnp.sum(x) > 0:\n"
        "        x = x - 1\n"
        "    return x\n")
    assert codes(src, KPATH).count("R006") == 2
    # static python branches stay legal
    ok = ("import jax\n"
          "@jax.jit\n"
          "def root(x, flag=None):\n"
          "    if flag is None:\n"
          "        return x\n"
          "    return x + 1\n")
    assert codes(ok, KPATH) == []


def test_r007_fires_on_donated_buffer_reuse():
    src = (
        "import jax\n"
        "def raw(s):\n    return s\n"
        "step = jax.jit(raw, donate_argnums=(0,))\n"
        "def loop(state):\n"
        "    out = step(state)\n"
        "    return state + out\n")              # reads the dead buffer
    assert codes(src) == ["R007"]
    # rebinding the result is the blessed pattern
    ok = (
        "import jax\n"
        "def raw(s):\n    return s\n"
        "step = jax.jit(raw, donate_argnums=(0,))\n"
        "def loop(state):\n"
        "    for _ in range(3):\n"
        "        state = step(state)\n"
        "    return state\n")
    assert codes(ok) == []


def test_r008_fires_on_zero_sentinel():
    bad = ("import numpy as np\n"
           "labels = np.zeros(8)\n"
           "exit_partition = np.full(8, 0)\n")
    assert codes(bad) == ["R008", "R008"]
    ok = "import numpy as np\nlabels = np.full(8, -1, np.int32)\n"
    assert codes(ok) == []


def test_r009_fires_on_host_timer_under_jit():
    src = (
        "import time\nimport jax\n"
        "@jax.jit\n"
        "def root(x):\n"
        "    return helper(x)\n"
        "def helper(x):\n"
        "    t0 = time.perf_counter()\n"
        "    return x, time.time() - t0\n")
    assert codes(src, KPATH).count("R009") == 2


def test_r009_fires_on_obs_span_under_jit():
    src = (
        "import jax\nfrom repro import obs\n"
        "@jax.jit\n"
        "def root(x):\n"
        "    with obs.span('walk'):\n"
        "        return x + 1\n")
    assert codes(src, KPATH) == ["R009"]


def test_r009_clean_on_host_side_timing():
    """Timing AROUND the dispatch (the obs pattern) is the blessed
    shape: the timed function is not jit-reachable."""
    src = (
        "import time\nimport jax\nfrom repro import obs\n"
        "@jax.jit\n"
        "def step(x):\n"
        "    return x * 2\n"
        "def serve(x):\n"
        "    t0 = time.perf_counter()\n"
        "    with obs.span('tick/dispatch'):\n"
        "        y = step(x)\n"
        "    return y, time.perf_counter() - t0\n")
    assert codes(src, KPATH) == []


# ---------------------------------------------------------------------------
# suppression pragmas
# ---------------------------------------------------------------------------

def test_pragma_with_reason_suppresses():
    src = ("import jax.numpy as jnp\n"
           f"x = jnp.zeros((4,))  # {SP}: allow[R003]: fixture\n")
    assert codes(src) == []


def test_own_line_pragma_covers_next_statement():
    src = ("import jax.numpy as jnp\n"
           f"# {SP}: allow[R003]: fixture reason spanning\n"
           "# a continuation comment line\n"
           "x = jnp.zeros((4,))\n")
    assert codes(src) == []


def test_pragma_without_reason_is_r000():
    src = ("import jax.numpy as jnp\n"
           f"x = jnp.zeros((4,))  # {SP}: allow[R003]\n")
    assert codes(src) == ["R000"]


def test_unused_pragma_is_r000():
    src = ("import jax.numpy as jnp\n"
           f"x = jnp.zeros((4,), jnp.int32)  # {SP}: allow[R003]: stale\n")
    assert codes(src) == ["R000"]


def test_unknown_code_pragma_is_r000():
    src = f"x = 1  # {SP}: allow[R999]: no such rule\n"
    assert codes(src) == ["R000"]


def test_pragma_only_suppresses_listed_codes():
    src = ("import jax.numpy as jnp\n"
           f"labels = jnp.zeros((4,))  # {SP}: allow[R003]: fixture\n")
    assert codes(src) == ["R008"]            # R008 not listed -> survives


# ---------------------------------------------------------------------------
# autofix (R003 dtype insertion, R005 options= rewrite)
# ---------------------------------------------------------------------------

def test_fix_r003_inserts_inferred_dtype():
    src = ("import jax.numpy as jnp\n"
           "a = jnp.zeros((4, 4))\n"
           "b = jnp.full((2,), -1)\n"
           "c = jnp.arange(8)\n"
           "d = jnp.arange(0.0, 1.0)\n")
    fixed, n = fix_source(src, CPATH)
    assert n == 4
    assert "jnp.zeros((4, 4), dtype=jnp.float32)" in fixed
    assert "jnp.full((2,), -1, dtype=jnp.int32)" in fixed
    assert "jnp.arange(8, dtype=jnp.int32)" in fixed
    assert "jnp.arange(0.0, 1.0, dtype=jnp.float32)" in fixed
    assert [d.code for d in lint_source(fixed, CPATH)] == []


def test_fix_r005_rewrites_to_options():
    src = ("from repro.core.inference import EngineOptions\n"
           "def f(eng, wp):\n"
           "    return eng.run(wp, with_trace=False, impl='fused', "
           "compact=True)\n")
    fixed, n = fix_source(src, TPATH)
    assert n == 1
    assert ("eng.run(wp, with_trace=False, "
            "options=EngineOptions(impl='fused', compact=True))") in fixed
    assert [d.code for d in lint_source(fixed, TPATH)] == []


def test_fix_r005_adds_missing_import():
    src = ("import numpy as np\n"
           "def f(eng, wp):\n"
           "    return eng.run_streaming(wp, micro_batch=64)\n")
    fixed, _ = fix_source(src, TPATH)
    assert "from repro.core.inference import EngineOptions" in fixed
    # the import lands after the existing import block
    assert fixed.index("import numpy") < fixed.index("EngineOptions")


def test_fix_r005_skips_kwargs_splat_and_mixing():
    src = ("def f(eng, wp, kw, o):\n"
           "    eng.run(wp, compact=True, **kw)\n"
           "    eng.run(wp, options=o, impl='fused')\n")
    fixed, n = fix_source(src, TPATH)
    assert n == 0 and fixed == src           # unsafe: left for a human


def test_fix_is_idempotent():
    src = ("import jax.numpy as jnp\n"
           "a = jnp.zeros((4, 4))\n"
           "def f(eng, wp):\n"
           "    return eng.run(wp, impl='fused')\n")
    once, n1 = fix_source(src, CPATH)
    twice, n2 = fix_source(once, CPATH)
    assert n1 > 0 and n2 == 0 and twice == once


def test_fixed_snippet_respects_pragmas():
    src = ("import jax.numpy as jnp\n"
           f"a = jnp.zeros((4,))  # {SP}: allow[R003]: stay implicit\n")
    fixed, n = fix_source(src, CPATH)
    assert n == 0 and fixed == src


# ---------------------------------------------------------------------------
# registry / output / CLI / acceptance
# ---------------------------------------------------------------------------

def test_every_rule_registered_with_doc():
    assert sorted(RULES) == [f"R00{i}" for i in range(1, 10)]
    for r in RULES.values():
        assert r.doc and r.name


def test_json_report_shape():
    diags = lint_source("import jax.numpy as jnp\nx = jnp.zeros((1,))\n",
                        CPATH)
    payload = json.loads(render_json(diags))
    assert payload["count"] == 1
    (d,) = payload["diagnostics"]
    assert d["code"] == "R003" and d["path"] == CPATH
    assert d["line"] == 2 and d["fixable"] is True


def test_cli_select_unknown_code_errors():
    assert main(["--select", "R999", "src"]) == 2


def test_clean_tree_src_is_clean():
    """Acceptance bar: zero unsuppressed diagnostics on the landed tree
    (and every suppression carries a reason, or R000 would fire)."""
    assert main(["src"]) == 0


def test_clean_tree_tests_benchmarks_clean():
    assert main(["tests", "benchmarks"]) == 0


@pytest.mark.skipif(not os.path.isdir(os.path.join(REPO, "tools")),
                    reason="needs repo checkout")
def test_cli_subprocess_json(tmp_path):
    """`python -m tools.splint` (the CI invocation) works end to end."""
    # R002 applies on any path (src-scoped rules would skip a tmp file)
    bad = tmp_path / "fixture.py"
    bad.write_text("import jax\n"
                   "@jax.jit\n"
                   "def f(x):\n"
                   "    return x.item()\n")
    out = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "tools.splint", str(bad),
         "--format=json", "--output", str(out)],
        cwd=REPO, capture_output=True, text=True)
    assert proc.returncode == 1
    payload = json.loads(out.read_text())
    assert payload["count"] >= 1
    assert payload["diagnostics"][0]["code"] == "R002"

"""CART trainer: correctness + the SpliDT k-feature budget."""
import numpy as np
from repro.testing.hypothesis_compat import given, settings, strategies as st

from repro.core.tree import feature_importance, macro_f1, train_tree


def test_perfect_split():
    rng = np.random.default_rng(0)
    X = rng.normal(size=(400, 5)).astype(np.float32)
    y = (X[:, 2] > 0.1).astype(np.int64)
    t = train_tree(X, y, max_depth=3)
    assert (t.predict(X) == y).mean() > 0.97
    assert 2 in t.used_features()


def test_k_feature_budget_enforced():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(600, 20)).astype(np.float32)
    y = ((X[:, 0] > 0) ^ (X[:, 5] > 0) ^ (X[:, 9] > 0)).astype(np.int64)
    for k in (1, 2, 3):
        t = train_tree(X, y, max_depth=8, k_features=k)
        assert len(t.used_features()) <= k


def test_allowed_features_respected():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(300, 10)).astype(np.float32)
    y = (X[:, 3] > 0).astype(np.int64)
    t = train_tree(X, y, max_depth=4, allowed_features=np.array([1, 2]))
    assert set(t.used_features()) <= {1, 2}


def test_depth_limit():
    rng = np.random.default_rng(3)
    X = rng.normal(size=(500, 8)).astype(np.float32)
    y = rng.integers(0, 4, 500)
    for d in (1, 2, 5):
        t = train_tree(X, y, max_depth=d, min_gain=-1.0)
        assert t.max_depth <= d


def test_determinism():
    rng = np.random.default_rng(4)
    X = rng.normal(size=(300, 6)).astype(np.float32)
    y = rng.integers(0, 3, 300)
    t1 = train_tree(X, y, max_depth=5)
    t2 = train_tree(X, y, max_depth=5)
    np.testing.assert_array_equal(t1.feature, t2.feature)
    np.testing.assert_array_equal(t1.threshold, t2.threshold)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_apply_consistent_with_predict_proba(seed):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(200, 5)).astype(np.float32)
    y = rng.integers(0, 3, 200)
    t = train_tree(X, y, max_depth=4)
    leaves = t.apply(X)
    assert (t.feature[leaves] == -1).all()          # always lands on a leaf
    p = t.predict_proba(X)
    np.testing.assert_allclose(p.sum(1), 1.0, atol=1e-5)


def test_macro_f1_basics():
    y = np.array([0, 0, 1, 1, 2, 2])
    assert macro_f1(y, y, 3) == 1.0
    assert macro_f1(y, 1 - y % 2, 3) < 0.7


def test_feature_importance_finds_signal():
    rng = np.random.default_rng(5)
    X = rng.normal(size=(800, 12)).astype(np.float32)
    y = ((X[:, 7] > 0).astype(int) + (X[:, 2] > 0.5)).astype(np.int64)
    imp = feature_importance(X, y, n_classes=3)
    assert set(np.argsort(imp)[::-1][:2]) == {7, 2}

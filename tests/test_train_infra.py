"""Optimizer, checkpointing, recovery, elasticity, compression, data."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.tokens import TokenPipeline
from repro.distributed.compression import compress_grads, compression_ratio
from repro.train import checkpoint as ckpt_lib
from repro.train.elastic import StepWatchdog, run_with_recovery
from repro.train.optimizer import AdamW, TrainState, warmup_cosine


def _toy_state(seed=0):
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    return AdamW(lr=0.05), params


def test_adamw_converges_quadratic():
    opt, params = _toy_state()
    target = jax.tree.map(lambda p: jnp.ones_like(p), params)
    state = opt.init(params)

    def loss_fn(p):
        return sum(jnp.sum((a - t) ** 2)
                   for a, t in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss_fn(state.params))
    for _ in range(120):
        g = jax.grad(loss_fn)(state.params)
        state, m = opt.update(state, g)
    assert float(loss_fn(state.params)) < 0.05 * l0
    assert int(state.step) == 120


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, grad_clip=1e-3)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    state, m = opt.update(state, {"w": jnp.full((4,), 1e6)})
    assert float(m["grad_norm"]) > 1e5
    assert float(jnp.abs(state.params["w"]).max()) < 2.0


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(100))) == pytest.approx(0.1, abs=0.01)


def test_checkpoint_roundtrip(tmp_path):
    opt, params = _toy_state(1)
    state = opt.init(params)
    g = jax.tree.map(jnp.ones_like, params)
    state, _ = opt.update(state, g)
    path = str(tmp_path / "step_1")
    ckpt_lib.save(path, state, {"note": "x"})
    restored, extra = ckpt_lib.restore(path)
    assert extra == {"note": "x"}
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_committed_picks_max(tmp_path):
    opt, params = _toy_state(2)
    state = opt.init(params)
    for s in (1, 5, 3):
        st = TrainState(step=jnp.asarray(s, jnp.int32), params=state.params,
                        mu=state.mu, nu=state.nu)
        ckpt_lib.save(str(tmp_path / f"step_{s}"), st)
    assert ckpt_lib.latest_committed(str(tmp_path)).endswith("step_5")


def test_async_checkpointer(tmp_path):
    opt, params = _toy_state(3)
    state = opt.init(params)
    w = ckpt_lib.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in range(1, 5):
        state = TrainState(step=jnp.asarray(s, jnp.int32),
                           params=state.params, mu=state.mu, nu=state.nu)
        w.save(state)
    w.wait()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_3", "step_4"]      # gc keeps last 2


def test_run_with_recovery_replays_from_checkpoint(tmp_path):
    opt, params = _toy_state(4)
    state = opt.init(params)
    target = jax.tree.map(jnp.ones_like, params)

    def loss_fn(p, batch):
        return sum(jnp.sum((a - t) ** 2) for a, t in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    def step_fn(state, batch):
        g = jax.grad(loss_fn)(state.params, batch)
        state, m = opt.update(state, g)
        return state, m

    state, rep = run_with_recovery(
        step_fn, state, range(30), ckpt_root=str(tmp_path),
        ckpt_every=5, fail_at={12, 23})
    assert rep.failures == 2 and rep.restores == 2
    assert rep.final_step == 30              # exactly-once on step counter
    assert rep.steps_run > 30                # replayed some steps


def test_watchdog_flags_stragglers():
    flagged = []
    wd = StepWatchdog(threshold=2.0, warmup_steps=1,
                      on_straggler=lambda s, dt, ema: flagged.append(s))
    for i, dt in enumerate([0.1, 0.1, 0.1, 0.1, 0.5, 0.1]):
        wd.observe(i, dt)
    assert wd.stragglers == 1 and flagged == [4]


def test_compression_error_feedback_unbiased():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)}
    total_c = jnp.zeros((64, 64))
    total_r = jnp.zeros((64, 64))
    err = None
    for i in range(50):
        gi = {"w": g["w"] * (1 + 0.01 * i)}
        ci, err = compress_grads(gi, err)
        total_c += ci["w"]
        total_r += gi["w"]
    # accumulated compressed gradient tracks the true sum (error feedback)
    rel = float(jnp.abs(total_c - total_r).max() / jnp.abs(total_r).max())
    assert rel < 0.01
    assert compression_ratio(g) < 0.55


def test_compressed_training_matches_uncompressed():
    opt, params = _toy_state(5)
    target = jax.tree.map(jnp.ones_like, params)

    def loss_fn(p):
        return sum(jnp.sum((a - t) ** 2) for a, t in
                   zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    s_plain = opt.init(params)
    s_comp = opt.init(params)
    err = None
    for _ in range(80):
        s_plain, _ = opt.update(s_plain, jax.grad(loss_fn)(s_plain.params))
        g, err = compress_grads(jax.grad(loss_fn)(s_comp.params), err)
        s_comp, _ = opt.update(s_comp, g)
    assert float(loss_fn(s_comp.params)) < 1.5 * float(loss_fn(s_plain.params)) + 1e-3


def test_data_pipeline_deterministic_resume():
    pipe = TokenPipeline(vocab=128, batch=4, seq=16, seed=7)
    b5 = pipe.batch_at(5)
    b5_again = pipe.batch_at(5)
    np.testing.assert_array_equal(b5["tokens"], b5_again["tokens"])
    it = pipe.iterate(start_step=5)
    first = next(it)
    np.testing.assert_array_equal(first["tokens"], b5["tokens"])


def test_markov_source_learnable_structure():
    from repro.data.tokens import MarkovText
    src = MarkovText(64, branching=4, seed=0)
    rng = np.random.default_rng(0)
    seq = src.sample(rng, 1, 4000)[0]
    # successors are constrained: per-token successor entropy << log(V)
    succ_sets = {}
    for a, b in zip(seq[:-1], seq[1:]):
        succ_sets.setdefault(int(a), set()).add(int(b))
    mean_succ = np.mean([len(v) for v in succ_sets.values()])
    assert mean_succ <= 4.5

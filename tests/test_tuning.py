"""Cost-model routing + cached autotuning (repro.tuning).

Two families of properties:

  * the ROUTER is sane — estimates scale the right way (monotone in B,
    pallas padding grows with S), fitted coefficients reproduce
    synthetic timings, the cache round-trips and survives corruption;
  * the ROUTE is invisible — ``impl="auto"`` and ``impl="tuned"``
    produce verdicts bit-identical to the backend they resolve to, for
    single batches and for streaming, because routing is a pure
    execution choice (docs/PARITY.md).
"""
import json
import os

import numpy as np
import pytest

from repro.core.inference import (
    Engine,
    PALLAS_BACKEND,
    backend_for_plan,
    get_backend,
    pallas_backend,
)
from repro.flows.windows import window_packets
from repro.serve.streaming import run_streaming
from repro.tuning import (
    Coefficients,
    Plan,
    ShapeInfo,
    choose_plan,
    estimate_us,
    fit_coefficients,
    work_terms,
)
from repro.tuning.autotune import (
    CACHE_ENV,
    NO_TIME_ENV,
    autotune,
    cache_key,
    device_fingerprint,
    load_cache,
    save_cache,
)
from repro.core.inference import EngineOptions


def _shape(B=1024, S=9, k=4, P=3, W=32, T=8, L=16, **kw):
    return ShapeInfo(B=B, S=S, k=k, P=P, W=W, T=T, L=L, **kw)


@pytest.fixture()
def tune_cache(tmp_path, monkeypatch):
    path = str(tmp_path / "autotune.json")
    monkeypatch.setenv(CACHE_ENV, path)
    return path


@pytest.fixture(scope="module")
def tuned_engine(trained_pdt):
    pdt, Xw, tr = trained_pdt
    wp = window_packets(tr, 3)
    return Engine.from_model(pdt), wp, pdt, Xw


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
def test_shape_from_engine(tuned_engine):
    eng, wp, pdt, _ = tuned_engine
    s = ShapeInfo.from_engine(eng, wp)
    assert s.B == wp.shape[0] and s.W == wp.shape[2]
    assert s.S == eng.ret.n_subtrees and s.k == eng.ret.k
    assert s.P == eng.tables.n_partitions
    assert s.key() == ShapeInfo.from_engine(eng, wp).key()


def test_shape_validation():
    with pytest.raises(ValueError, match="must be positive"):
        _shape(S=0)
    with pytest.raises(ValueError, match="survivors"):
        _shape(survivors=(1.0, 0.5))       # P=3 needs 3 entries
    with pytest.raises(ValueError, match="unknown backend"):
        Plan(backend="tofino")


@pytest.mark.parametrize("backend", ["looped", "fused", "pallas"])
def test_estimates_monotone_in_batch(backend):
    costs = [estimate_us(_shape(B=B), Plan(backend=backend))
             for B in (128, 1024, 8192)]
    assert costs == sorted(costs)
    assert costs[0] > 0


def test_pallas_estimate_grows_with_subtrees():
    """The capacity bound ceil(B/bb) + S charges pallas for per-subtree
    padding; dense fused work is S-independent (gathers are per-flow)."""
    pal = [estimate_us(_shape(S=S), Plan(backend="pallas"))
           for S in (2, 16, 64)]
    assert pal == sorted(pal) and pal[0] < pal[-1]
    fus = [estimate_us(_shape(S=S), Plan(backend="fused"))
           for S in (2, 16, 64)]
    assert fus[0] == pytest.approx(fus[-1])


def test_compact_work_tracks_survivors():
    """With front-loaded exits the compacted plan does less work than
    the dense one; with no survivor info compaction is pure overhead."""
    surv = (1.0, 0.1, 0.05)
    dense = estimate_us(_shape(survivors=surv), Plan(backend="fused"))
    comp = estimate_us(_shape(survivors=surv),
                       Plan(backend="fused", compact=True))
    assert comp < dense
    no_info = estimate_us(_shape(), Plan(backend="fused", compact=True))
    assert no_info >= estimate_us(_shape(), Plan(backend="fused"))


def test_choose_plan_restricted_backends():
    for b in ("looped", "fused", "pallas"):
        assert choose_plan(_shape(), backends=(b,)).backend == b
    plan = choose_plan(_shape())
    assert plan.source == "costmodel" and plan.est_us > 0


def test_default_coefficients_route_sanely():
    """On CPU the fitted defaults must route every realistic shape to
    the fused walk (interpret-mode pallas and the host loop lose)."""
    import jax
    if jax.default_backend() == "tpu":
        pytest.skip("CPU-fitted defaults under test")
    for B in (256, 2048, 65536):
        for S in (4, 32):
            assert choose_plan(_shape(B=B, S=S)).backend == "fused"


def test_fit_coefficients_recovers_synthetic_weights():
    """Generate timings from known weights; the NNLS fit must recover
    them (and estimates must reproduce the synthetic timings)."""
    true = Coefficients(call=500.0, sync=0.0, fw=1e-3, tr_dense=2e-3,
                        tr_pallas=0.0, grid=0.0, sort=0.0)
    # vary W and L independently of B so the feature-window and
    # traversal columns are not collinear (both scale with B)
    shapes = [_shape(B=B, W=W, L=L)
              for B in (128, 512, 4096) for W, L in ((16, 8), (64, 32))]
    samples = [(s, Plan(backend="fused"),
                float(work_terms(s, Plan(backend="fused")) @ true.vector()))
               for s in shapes]
    fit = fit_coefficients(samples)
    for s, p, us in samples:
        assert estimate_us(s, p, fit) == pytest.approx(us, rel=1e-6)
    assert fit.fw == pytest.approx(1e-3, rel=1e-3)
    assert fit.tr_dense == pytest.approx(2e-3, rel=1e-3)


def test_fit_keeps_base_for_unsupported_terms():
    base = Coefficients(call=1.0, sync=99.0, fw=1.0, tr_dense=1.0,
                        tr_pallas=77.0, grid=88.0, sort=1.0)
    s = _shape()
    us = float(work_terms(s, Plan(backend="fused")) @ base.vector())
    fit = fit_coefficients([(s, Plan(backend="fused"), us)], base=base)
    # fused samples exercise no pallas terms: base survives
    assert fit.tr_pallas == 77.0 and fit.grid == 88.0


# ---------------------------------------------------------------------------
# autotune cache
# ---------------------------------------------------------------------------
def test_cache_round_trip(tune_cache):
    entries = {"k1": {"backend": "fused", "block_b": 128, "compact": False,
                      "compact_floor": 128, "us": 12.5}}
    save_cache(entries, tune_cache)
    assert load_cache(tune_cache) == entries
    # corrupt file -> tolerated, treated as empty (tuning never breaks
    # inference)
    with open(tune_cache, "w") as f:
        f.write("{not json")
    assert load_cache(tune_cache) == {}
    # wrong version -> ignored
    with open(tune_cache, "w") as f:
        json.dump({"version": 999, "entries": entries}, f)
    assert load_cache(tune_cache) == {}
    assert load_cache(str(tune_cache) + ".does-not-exist") == {}


def test_cache_key_includes_device_and_shape():
    k1 = cache_key(_shape(B=256))
    k2 = cache_key(_shape(B=512))
    assert k1 != k2
    assert device_fingerprint() in k1
    assert cache_key(_shape(B=256), streaming=True) != k1
    # pinned compact requests must not be served a compact="auto" plan
    # (and vice versa): they tune and cache separately
    assert len({cache_key(_shape(B=256), compact=c)
                for c in ("auto", True, False)}) == 3


def test_cached_auto_plan_does_not_override_pinned_compact(
        tuned_engine, tune_cache):
    eng, wp, _, _ = tuned_engine
    free = autotune(eng, wp, backends=("fused",), compact="auto",
                    repeat=1, probe_flows=64)
    assert free.source == "timed"
    pinned = autotune(eng, wp, backends=("fused",), compact=False,
                      repeat=1, probe_flows=64)
    # a fresh (pinned) tuning run, not a cache hit on the "auto" entry
    assert pinned.source == "timed" and pinned.compact is False
    assert autotune(eng, wp, backends=("fused",), compact=False,
                    repeat=1).source == "cache"


def test_autotune_times_caches_and_rehits(tuned_engine, tune_cache):
    eng, wp, _, _ = tuned_engine
    plan = autotune(eng, wp, backends=("fused",), compact=False,
                    repeat=1, probe_flows=64)
    assert plan.backend == "fused" and plan.source == "timed"
    assert os.path.exists(tune_cache)
    again = autotune(eng, wp, backends=("fused",), compact=False, repeat=1)
    assert again.source == "cache" and again.backend == "fused"
    forced = autotune(eng, wp, backends=("fused",), compact=False,
                      repeat=1, probe_flows=64, force=True)
    assert forced.source == "timed"


def test_autotune_no_timing_falls_back_to_costmodel(
        tuned_engine, tune_cache, monkeypatch):
    eng, wp, _, _ = tuned_engine
    monkeypatch.setenv(NO_TIME_ENV, "1")
    plan = autotune(eng, wp)
    assert plan.source == "costmodel"
    assert not os.path.exists(tune_cache)    # nothing was persisted


# ---------------------------------------------------------------------------
# routing parity: auto / tuned are invisible (zero tolerance)
# ---------------------------------------------------------------------------
def _assert_identical(a, b):
    np.testing.assert_array_equal(a.labels, b.labels)
    np.testing.assert_array_equal(a.recircs, b.recircs)
    np.testing.assert_array_equal(a.exit_partition, b.exit_partition)


def test_auto_impl_bit_identical_and_emits_plan(tuned_engine):
    eng, wp, pdt, Xw = tuned_engine
    auto = eng.run(wp, with_trace=False, options=EngineOptions(impl="auto"))
    assert auto.plan is not None and auto.plan.source == "costmodel"
    forced = eng.run(wp, with_trace=False, options=EngineOptions(impl=auto.plan.backend))
    assert forced.plan is None               # forced impls carry no plan
    _assert_identical(auto, forced)
    # ... and to the offline oracle
    labels, recircs, exit_p = pdt.predict(Xw, return_trace=True)
    np.testing.assert_array_equal(auto.labels, labels)
    np.testing.assert_array_equal(auto.recircs, recircs)
    np.testing.assert_array_equal(auto.exit_partition, exit_p)


def test_tuned_impl_bit_identical_to_routed_backend(tuned_engine,
                                                    tune_cache):
    eng, wp, _, _ = tuned_engine
    tuned = eng.run(wp, with_trace=False, options=EngineOptions(impl="tuned"))
    assert tuned.plan is not None and tuned.plan.source == "timed"
    again = eng.run(wp, with_trace=False, options=EngineOptions(impl="tuned"))
    assert again.plan.source == "cache"
    assert again.plan.backend == tuned.plan.backend
    # splint: allow[R005]: ExecutionBackend protocol run() — compact is a
    # real parameter here, not the Engine deprecation shim
    forced = backend_for_plan(again.plan).run(
        eng, wp, with_trace=False, compact=again.plan.compact,
        compact_floor=again.plan.compact_floor)
    _assert_identical(again, forced)
    _assert_identical(again, tuned)


def test_compact_auto_resolves_via_plan(tuned_engine):
    eng, wp, _, _ = tuned_engine
    res = eng.run(wp, with_trace=False, options=EngineOptions(impl="fused", compact="auto"))
    assert res.plan is not None and res.plan.backend == "fused"
    _assert_identical(res, eng.run(wp, with_trace=False, options=EngineOptions(impl="fused")))


def test_streaming_auto_and_tuned_parity(tuned_engine, tune_cache):
    eng, wp, _, _ = tuned_engine
    full = eng.run(wp, with_trace=False, options=EngineOptions(impl="fused"))
    auto = run_streaming(eng, wp, options=EngineOptions(micro_batch=96, impl="auto"))
    assert auto.plan is not None
    assert auto.plan.backend in ("fused", "pallas")   # walk backends only
    _assert_identical(auto, full)
    tuned = run_streaming(eng, wp, options=EngineOptions(micro_batch=96, impl="tuned"))
    assert tuned.plan is not None
    _assert_identical(tuned, full)
    # fixed impl: no plan attached
    assert run_streaming(eng, wp, options=EngineOptions(micro_batch=96, impl="fused")).plan is None


def test_custom_block_b_backend_bit_identical(tuned_engine):
    """block_b is a pure layout knob: any block size must reproduce the
    default walk bit-for-bit (registers included)."""
    eng, wp, _, _ = tuned_engine
    assert pallas_backend(128) is PALLAS_BACKEND
    ref = eng.run(wp[:96], with_trace=True, options=EngineOptions(impl="fused"))
    for bb in (32, 64):
        res = pallas_backend(bb).run(eng, wp[:96], with_trace=True)
        _assert_identical(res, ref)
        for a, b in zip(res.regs_trace, ref.regs_trace):
            np.testing.assert_array_equal(a, b)


def test_compact_floor_bit_identical(tuned_engine):
    eng, wp, _, _ = tuned_engine
    dense = eng.run(wp, with_trace=False, options=EngineOptions(impl="fused"))
    for floor in (32, 256):
        # splint: allow[R005]: ExecutionBackend protocol run() —
        # compact/compact_floor are real parameters here, not the shim
        res = backend_for_plan(
            Plan(backend="fused", compact=True, compact_floor=floor)).run(
                eng, wp, with_trace=False, compact=True,
                compact_floor=floor)
        _assert_identical(res, dense)


def test_get_backend_rejects_tuned_without_engine():
    with pytest.raises(ValueError, match="shape-dependent"):
        get_backend("tuned")


def test_get_backend_auto_with_shape_uses_cost_model():
    import jax
    backend = get_backend("auto", shape=_shape(B=2048))
    if jax.default_backend() != "tpu":
        assert backend.name == "fused"
    assert backend.step is not None

"""Live flow-table serving: the per-packet streaming engine must be
bit-identical to the offline batch walk — incremental folds vs rebuilt
windows (docs/PARITY.md), hash-bucket overflow vs the host spill path,
and mid-stream eviction sentinels all included."""
import functools

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.inference import Engine, EngineOptions, EngineResult
from repro.flows.synthetic import PacketBatch, make_packet_stream
from repro.flows.windows import window_bounds, window_packets
from repro.kernels import ref as kref
from repro.kernels.feature_window import (
    feature_update_finalize_pallas,
    feature_update_pallas,
)
from repro.serve import FlowTableServer, StreamVerdict, StreamVerdicts
from repro.testing.hypothesis_compat import given, settings, strategies as st

P = 3


@pytest.fixture(scope="module")
def serve_setup(trained_pdt):
    pdt, _, tr = trained_pdt
    eng = Engine.from_model(pdt)
    wp = window_packets(tr, P)
    full = eng.run(wp, with_trace=False)
    stream = make_packet_stream(tr, seed=11, profile="steady")
    return eng, tr, wp, full, stream


def _serve_all(srv, stream, tick):
    parts = [srv.ingest(b) for b in stream.ticks(tick)]
    parts.append(srv.flush())
    return StreamVerdicts.concat(parts)


def _assert_verdicts_match(v, full, n_flows):
    assert v.n_flows == n_flows
    assert np.unique(v.flow_id).size == n_flows  # one verdict per flow
    order = np.argsort(v.flow_id)
    np.testing.assert_array_equal(v.labels[order], np.asarray(full.labels))
    np.testing.assert_array_equal(v.recircs[order],
                                  np.asarray(full.recircs))
    np.testing.assert_array_equal(v.exit_partition[order],
                                  np.asarray(full.exit_partition))


# ---------------------------------------------------------------------------
# incremental fold == rebuilt window (the kernel-level parity clause)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_incremental_fold_matches_rebuilt_window(serve_setup, impl):
    """Folding a window one packet at a time must reproduce the
    all-at-once window registers bit for bit — including the padding
    packets, which a correct fold treats as no-ops."""
    eng, tr, wp, _, _ = serve_setup
    dev = eng.dev
    B, _, W, _ = wp.shape
    for w in range(P):
        win = jnp.asarray(wp[:, w])            # (B, W, F)
        sid = jnp.zeros(B, jnp.int32)
        op = dev.slot_op[sid]
        fld = dev.slot_field[sid]
        prd = dev.slot_pred[sid]
        init = dev.slot_init[sid]
        want = kref.feature_window_ref(win, op, fld, prd, init)
        acc, seen = kref.feature_state_init(op)
        for t in range(W):
            if impl == "ref":
                acc, seen = kref.feature_update_ref(
                    win[:, t], op, fld, prd, acc, seen)
            else:
                acc, seen = feature_update_pallas(
                    win[:, t], op, fld, prd, acc, seen)
        got = kref.feature_finalize_ref(acc, seen, op, init)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_fused_update_finalize_matches_composition(serve_setup, impl):
    """The tick-step kernel fuses fold and finalize into one pass; its
    registers AND its carried (acc, seen) must be bit-identical to the
    two-call composition at every packet position — otherwise the fused
    tick engine would drift from the legacy per-rank dispatches."""
    eng, tr, wp, _, _ = serve_setup
    dev = eng.dev
    B, _, W, _ = wp.shape
    for w in range(P):
        win = jnp.asarray(wp[:, w])
        sid = jnp.zeros(B, jnp.int32)
        op = dev.slot_op[sid]
        fld = dev.slot_field[sid]
        prd = dev.slot_pred[sid]
        init = dev.slot_init[sid]
        acc, seen = kref.feature_state_init(op)
        for t in range(W):
            wa, ws = kref.feature_update_ref(win[:, t], op, fld, prd,
                                             acc, seen)
            want = kref.feature_finalize_ref(wa, ws, op, init)
            if impl == "ref":
                a2, s2, regs = kref.feature_update_finalize_ref(
                    win[:, t], op, fld, prd, init, acc, seen)
            else:
                a2, s2, regs = feature_update_finalize_pallas(
                    win[:, t], op, fld, prd, init, acc, seen)
            np.testing.assert_array_equal(np.asarray(a2), np.asarray(wa))
            np.testing.assert_array_equal(np.asarray(s2), np.asarray(ws))
            np.testing.assert_array_equal(np.asarray(regs),
                                          np.asarray(want))
            acc, seen = a2, s2


# ---------------------------------------------------------------------------
# end-to-end: streamed verdicts == batch engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("impl", ["fused", "pallas"])
def test_stream_matches_batch_engine(serve_setup, impl):
    eng, tr, _, full, stream = serve_setup
    srv = FlowTableServer(eng, n_buckets=8, bucket_size=4,
                          options=EngineOptions(impl=impl))
    v = _serve_all(srv, stream, tick=53)
    _assert_verdicts_match(v, full, tr.n_flows)
    assert srv.stats.packets == stream.n_packets
    assert v.n_unterminated == full.n_unterminated


def test_auto_options_resolve_plan(serve_setup):
    eng, tr, _, full, stream = serve_setup
    srv = FlowTableServer(eng, options=EngineOptions(impl="auto"))
    v = _serve_all(srv, stream, tick=200)
    _assert_verdicts_match(v, full, tr.n_flows)
    assert v.plan is not None
    assert v.plan.backend in ("fused", "pallas")


def test_flowtable_rejects_non_walk_backend(serve_setup):
    eng = serve_setup[0]
    with pytest.raises(ValueError, match="walk backend"):
        FlowTableServer(eng, options=EngineOptions(impl="looped"))


# ---------------------------------------------------------------------------
# hash-bucket overflow: spill to host, never drop a flow
# ---------------------------------------------------------------------------
def test_bucket_overflow_spills_without_dropping(serve_setup):
    eng, tr, _, full, stream = serve_setup
    # 4 slots for dozens of concurrent flows: most of the stream must
    # take the spill path, and verdicts must still be bit-identical
    srv = FlowTableServer(eng, n_buckets=2, bucket_size=2)
    v = _serve_all(srv, stream, tick=97)
    assert srv.stats.spilled > 0
    assert srv.stats.peak_resident > srv.table.capacity
    _assert_verdicts_match(v, full, tr.n_flows)


# ---------------------------------------------------------------------------
# eviction before window-complete: -1 sentinels, mid-stream
# ---------------------------------------------------------------------------
def test_flush_mid_window_emits_sentinels(serve_setup):
    eng, tr, _, full, stream = serve_setup
    srv = FlowTableServer(eng, n_buckets=8, bucket_size=4)
    half = stream.slice(0, stream.n_packets // 2)
    v1 = srv.ingest(half)
    v2 = srv.flush()
    v = StreamVerdicts.concat([v1, v2])
    # flushed flows never exited: label and exit_partition both -1
    assert v2.n_flows > 0
    assert (v2.labels == -1).all()
    assert (v2.exit_partition == -1).all()
    assert v2.n_unterminated == v2.n_flows
    # flows that DID complete in the half-stream match the batch run
    done = v1.flow_id[np.asarray(v1.exit_partition) >= 0]
    if done.size:
        full_by_id = {int(i): (int(full.labels[i]), int(full.recircs[i]),
                               int(full.exit_partition[i]))
                      for i in done}
        for j in range(v1.n_flows):
            fid = int(v1.flow_id[j])
            if fid in full_by_id:
                assert (int(v1.labels[j]), int(v1.recircs[j]),
                        int(v1.exit_partition[j])) == full_by_id[fid]
    # every flow of the half-stream got exactly one verdict
    assert np.unique(v.flow_id).size == v.n_flows


def test_timeout_eviction_emits_sentinels(serve_setup):
    eng, tr, _, _, stream = serve_setup
    srv = FlowTableServer(eng, n_buckets=8, bucket_size=4, timeout=1e-12)
    first = stream.slice(0, 64)
    srv.ingest(first)
    # a later tick whose arrivals are far past every resident flow
    last = stream.slice(stream.n_packets - 8, stream.n_packets)
    v = srv.ingest(last)
    assert srv.stats.evicted > 0
    evicted = np.asarray(v.exit_partition) < 0
    assert evicted.any()
    assert (np.asarray(v.labels)[evicted] == -1).all()


def test_late_packets_for_retired_flow_are_dropped(serve_setup):
    eng, tr, _, full, stream = serve_setup
    srv = FlowTableServer(eng, n_buckets=8, bucket_size=4)
    v = _serve_all(srv, stream, tick=111)
    n = v.n_flows
    # replaying the whole stream: every flow is retired, nothing folds
    replay = [srv.ingest(b) for b in stream.ticks(111)]
    replay.append(srv.flush())
    again = StreamVerdicts.concat(replay)
    assert again.n_flows == 0
    assert n == tr.n_flows


# ---------------------------------------------------------------------------
# adversarial tick shapes: deep rank chains, mid-tick hops, slot reuse
# ---------------------------------------------------------------------------
def _flow_batch(tr, sel, t0=0.0, extra_tail=0):
    """One tick delivering each selected flow IN FULL (rank depth = flow
    length), optionally followed by ``extra_tail`` duplicate copies of
    the first flow's last packet — late arrivals past flow_len."""
    sel = list(sel)
    fid = np.concatenate(
        [np.full(int(tr.lengths[i]), i, np.int64) for i in sel])
    pkts = np.concatenate(
        [tr.packets[i, :int(tr.lengths[i])] for i in sel])
    if extra_tail:
        i = sel[0]
        last = tr.packets[i, int(tr.lengths[i]) - 1][None]
        fid = np.concatenate([fid, np.full(extra_tail, i, np.int64)])
        pkts = np.concatenate([pkts, np.repeat(last, extra_tail, axis=0)])
    flen = tr.lengths[fid].astype(np.int32)
    arr = t0 + np.arange(fid.size, dtype=np.float64)
    return PacketBatch(fid, flen, pkts.astype(np.float32), arr)


def _assert_subset_matches(v, full, fids):
    assert sorted(map(int, v.flow_id)) == sorted(map(int, fids))
    for j in range(v.n_flows):
        i = int(v.flow_id[j])
        assert int(v.labels[j]) == int(full.labels[i]), i
        assert int(v.recircs[j]) == int(full.recircs[i]), i
        assert int(v.exit_partition[j]) == int(full.exit_partition[i]), i


@pytest.mark.parametrize("impl", ["fused", "pallas"])
def test_whole_flow_ticks_recycle_slots(serve_setup, impl):
    """Capacity-ONE table fed whole flows: every tick completes its
    resident flow mid-tick (the deepest rank chain possible), frees the
    slot, and the next tick's flow recycles it; the companion flow
    spills to the host each round.  Both paths must match the batch
    walk bit for bit."""
    eng, tr, _, full, _ = serve_setup
    fids = list(range(24))
    srv = FlowTableServer(eng, n_buckets=1, bucket_size=1, rank_floor=1,
                          tick_engine="fused",
                          options=EngineOptions(impl=impl))
    parts = [srv.ingest(_flow_batch(tr, (i, i + 1), t0=1e3 * i))
             for i in range(0, 24, 2)]
    parts.append(srv.flush())
    v = StreamVerdicts.concat(parts)
    assert srv.stats.spilled > 0          # capacity 1: companions spill
    _assert_subset_matches(v, full, fids)


@pytest.mark.parametrize("tick_engine", ["fused", "legacy"])
def test_interleaved_boundary_hops_mid_tick(serve_setup, tick_engine):
    """Round-robin interleave of 16 flows in ONE tick: every window
    boundary, hop, and drain round lands mid-tick, with many flows
    completing in the same rank — the worst case for the in-jit hop
    bookkeeping (fused) and the vectorized drain masks (legacy)."""
    eng, tr, _, full, _ = serve_setup
    sel = list(range(40, 56))
    maxlen = max(int(tr.lengths[i]) for i in sel)
    fid_rows, pkt_rows = [], []
    for j in range(maxlen):
        for i in sel:
            if j < int(tr.lengths[i]):
                fid_rows.append(i)
                pkt_rows.append(tr.packets[i, j])
    fid = np.asarray(fid_rows, np.int64)
    batch = PacketBatch(fid, tr.lengths[fid].astype(np.int32),
                        np.asarray(pkt_rows, np.float32),
                        np.arange(fid.size, dtype=np.float64))
    srv = FlowTableServer(eng, n_buckets=4, bucket_size=4,
                          tick_engine=tick_engine)
    v = StreamVerdicts.concat([srv.ingest(batch), srv.flush()])
    _assert_subset_matches(v, full, sel)


@pytest.mark.parametrize("tick_engine", ["fused", "legacy"])
def test_late_packets_cannot_corrupt_recycled_slot(serve_setup,
                                                   tick_engine):
    """A flow completes mid-tick, duplicate tail packets of it keep
    arriving in the SAME tick (must not fold into anything), then a new
    flow takes the freed slot next tick while yet more late packets of
    the retired flow arrive — they must not fold into the new tenant."""
    eng, tr, _, full, _ = serve_setup
    a, b = 3, 5
    srv = FlowTableServer(eng, n_buckets=1, bucket_size=1,
                          tick_engine=tick_engine)
    v1 = srv.ingest(_flow_batch(tr, [a], extra_tail=3))
    assert v1.n_flows == 1                # a completed despite the dups
    t2 = _flow_batch(tr, [b], t0=1e6, extra_tail=0)
    late = _flow_batch(tr, [a], t0=2e6).pkts[-2:]
    t2 = PacketBatch(
        np.concatenate([t2.flow_id, np.full(2, a, np.int64)]),
        np.concatenate([t2.flow_len,
                        tr.lengths[[a, a]].astype(np.int32)]),
        np.concatenate([t2.pkts, late]),
        np.arange(t2.flow_id.size + 2, dtype=np.float64) + 1e6)
    v2 = srv.ingest(t2)
    v = StreamVerdicts.concat([v1, v2, srv.flush()])
    _assert_subset_matches(v, full, [a, b])


# ---------------------------------------------------------------------------
# padding-leak property: ticks/capacity/impl must never change verdicts
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=1)
def _property_setup():
    # @given-wrapped tests can't take pytest fixtures (the hypothesis
    # fallback shim erases the signature), so the property builds its
    # own small trained engine once
    from repro.core.partition import train_partitioned_dt
    from repro.flows.synthetic import make_dataset
    from repro.flows.windows import window_features
    ds = make_dataset("d2", n_flows=72, seed=9, max_len=48)
    pdt = train_partitioned_dt(window_features(ds, P), ds.labels,
                               partition_sizes=[2, 2, 2], k=3)
    eng = Engine.from_model(pdt)
    full = eng.run(window_packets(ds, P), with_trace=False)
    return eng, ds, full


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_flowtable_padding_never_leaks(seed):
    """Mirror of tests/test_streaming.py's property: rank batches are
    padded to a power-of-two ladder with dummy-row scatters, so any
    padding leak would corrupt a resident flow's registers.  Random
    tick sizes, table capacities, arrival profiles, and backends must
    all reproduce the batch verdicts exactly."""
    eng, tr, full = _property_setup()
    rng = np.random.default_rng(seed)
    profile = ("steady", "bursty")[int(rng.integers(0, 2))]
    stream = make_packet_stream(tr, seed=int(rng.integers(1 << 16)),
                                profile=profile)
    srv = FlowTableServer(
        eng,
        n_buckets=int(rng.integers(1, 9)),
        bucket_size=int(rng.integers(1, 5)),
        options=EngineOptions(
            impl=("fused", "pallas")[int(rng.integers(0, 2))]),
        rank_floor=int(rng.integers(1, 65)),
        tick_engine=("fused", "legacy")[int(rng.integers(0, 2))],
    )
    v = _serve_all(srv, stream, tick=int(rng.integers(1, 300)))
    _assert_verdicts_match(v, full, tr.n_flows)


# ---------------------------------------------------------------------------
# result-type contract + stream generator
# ---------------------------------------------------------------------------
def test_stream_verdicts_share_engine_result_contract():
    # the unified surface: one field contract across batch and stream
    for name in ("labels", "recircs", "exit_partition", "plan"):
        assert name in EngineResult.__dataclass_fields__
        assert name in StreamVerdicts.__dataclass_fields__
    assert StreamVerdict is StreamVerdicts
    e = StreamVerdicts.empty()
    assert e.n_flows == 0 and e.n_unterminated == 0
    one = StreamVerdicts(np.array([7], np.int64), np.array([2], np.int32),
                         np.array([1], np.int32), np.array([-1], np.int32))
    cat = StreamVerdicts.concat([e, one, one])
    assert cat.n_flows == 2 and cat.n_unterminated == 2
    assert StreamVerdicts.concat([]).n_flows == 0


def test_packet_stream_is_replayable_and_ordered(serve_setup):
    _, tr, _, _, _ = serve_setup
    a = make_packet_stream(tr, seed=5, profile="bursty")
    b = make_packet_stream(tr, seed=5, profile="bursty")
    np.testing.assert_array_equal(a.arrival, b.arrival)
    np.testing.assert_array_equal(a.flow_id, b.flow_id)
    np.testing.assert_array_equal(a.pkts, b.pkts)
    assert (np.diff(a.arrival) >= 0).all()
    # per-flow packet order is preserved under the arrival interleave
    for fid in np.unique(a.flow_id)[:5]:
        rows = a.pkts[a.flow_id == fid]
        lo, hi = window_bounds(int(rows.shape[0]), 1)[0]
        assert (lo, hi) == (0, rows.shape[0])
    ticks = list(a.ticks(37))
    assert all(isinstance(t, PacketBatch) for t in ticks)
    assert sum(t.n_packets for t in ticks) == a.n_packets
    with pytest.raises(ValueError):
        next(a.ticks(0))

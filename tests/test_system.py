"""End-to-end behaviour tests for the paper's system: the full SpliDT
pipeline (synthetic flows -> windowed features -> Algorithm-1 training
-> rule generation -> data-plane engine -> resource & recirc models)
reproducing the paper's headline claims in structure."""
import pytest

from repro.core.baselines import best_oneshot_for_flows
from repro.core.inference import Engine
from repro.core.partition import train_partitioned_dt
from repro.core.recirc import HADOOP, WEBSERVER, recirc_bandwidth
from repro.core.resources import estimate
from repro.core.tree import macro_f1
from repro.flows.synthetic import make_dataset
from repro.flows.windows import (
    full_flow_features, quantize_features, window_features, window_packets,
)


@pytest.fixture(scope="module")
def d1():
    ds = make_dataset("d1", n_flows=2500)
    tr, te = ds.split()
    return ds, tr, te


def test_splidt_beats_topk_baseline(d1):
    """Figure 2 / Table 3 in structure: partitioned DT with per-subtree
    feature sets beats the one-shot top-k model and approaches the
    unconstrained-tree ideal."""
    ds, tr, te = d1
    Xw_tr, Xw_te = window_features(tr, 2), window_features(te, 2)
    pdt = train_partitioned_dt(Xw_tr, tr.labels, partition_sizes=[6, 6], k=6)
    f1_splidt = macro_f1(te.labels, pdt.predict(Xw_te), ds.n_classes)

    Xf_tr, Xf_te = full_flow_features(tr), full_flow_features(te)
    _, f1_topk = best_oneshot_for_flows(
        Xf_tr, tr.labels, Xf_te, te.labels, flows=100_000, style="nb",
        n_classes=ds.n_classes, k_grid=(6,), depth_grid=(13,))
    assert f1_splidt > f1_topk, (f1_splidt, f1_topk)


def test_5x_feature_scaling_at_same_registers(d1):
    """Headline claim: ~5x more stateful features than top-k at the same
    k register slots."""
    ds, tr, te = d1
    Xw_tr = window_features(tr, 3)
    pdt = train_partitioned_dt(Xw_tr, tr.labels,
                               partition_sizes=[5, 5, 5], k=6)
    total = len(pdt.unique_features())
    assert total >= 5 * 6 * 0.8          # >= ~5x k (some slack)
    assert pdt.max_features_per_subtree() <= 6


def test_full_stack_engine_pipeline(d1):
    ds, tr, te = d1
    p = 3
    Xw_tr = window_features(tr, p)
    pdt = train_partitioned_dt(Xw_tr, tr.labels, partition_sizes=[3, 3, 3],
                               k=4)
    wp = window_packets(te, p)
    res = Engine.from_model(pdt, impl="ref").run(wp)
    f1 = macro_f1(te.labels, res.labels, ds.n_classes)
    assert f1 > 0.4
    # recirculation priced against both datacenter environments
    for env in (WEBSERVER, HADOOP):
        bw = recirc_bandwidth(res.recircs, 1_000_000, env)
        assert bw.fraction_of_budget < 5e-4      # paper: <0.05%
    rep = estimate(pdt, flows=100_000)
    assert rep.feasible, rep.reasons


def test_bit_precision_tradeoff(d1):
    """Fig 12: lower precision -> more flows, modest accuracy drop."""
    ds, tr, te = d1
    Xw_tr, Xw_te = window_features(tr, 2), window_features(te, 2)
    pdt32 = train_partitioned_dt(Xw_tr, tr.labels, partition_sizes=[5, 5], k=4)
    f32 = macro_f1(te.labels, pdt32.predict(Xw_te), ds.n_classes)
    q_tr, q_te = quantize_features(Xw_tr, 8), quantize_features(Xw_te, 8)
    pdt8 = train_partitioned_dt(q_tr, tr.labels, partition_sizes=[5, 5], k=4)
    f8 = macro_f1(te.labels, pdt8.predict(q_te), ds.n_classes)
    assert f8 > 0.5 * f32                 # modest drop, not collapse
    c32 = estimate(pdt32, bits=32).flow_capacity
    c8 = estimate(pdt8, bits=8).flow_capacity
    assert c8 > 2 * c32


def test_register_footprint_constant_in_features(d1):
    """Fig 11: register bits depend on k only, not total features."""
    ds, tr, _ = d1
    Xw = window_features(tr, 3)
    reg_bits = []
    totals = []
    for ps in ([2, 2, 2], [5, 5, 5]):
        pdt = train_partitioned_dt(Xw, tr.labels, partition_sizes=ps, k=4)
        reg_bits.append(estimate(pdt).register_bits_per_flow)
        totals.append(len(pdt.unique_features()))
    assert totals[1] > totals[0]          # deeper -> more unique features
    assert abs(reg_bits[1] - reg_bits[0]) <= 32   # ~constant registers

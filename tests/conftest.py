import os
import subprocess
import sys

import pytest

# NOTE: no XLA_FLAGS here on purpose -- unit tests and benches must see
# ONE device; only launch/dryrun.py (and subprocess helpers below) force
# a host-device count.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run python code in a subprocess with N fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    # repro/__init__ installs jax forward-compat shims (AxisType,
    # make_mesh axis_types, ...) that the code strings rely on
    code = "import repro  # noqa: F401\n" + code
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture(scope="session")
def small_flow_ds():
    from repro.flows.synthetic import make_dataset
    return make_dataset("d2", n_flows=1200)


@pytest.fixture(scope="session")
def trained_pdt(small_flow_ds):
    from repro.core.partition import train_partitioned_dt
    from repro.flows.windows import window_features
    tr, _ = small_flow_ds.split()
    Xw = window_features(tr, 3)
    pdt = train_partitioned_dt(Xw, tr.labels, partition_sizes=[2, 3, 2], k=4)
    return pdt, Xw, tr

"""§Perf layout regression tests (mini 8-device meshes).

Locks in the three hillclimb results structurally: the opt layouts must
lower+compile and produce strictly fewer collective bytes than the
baseline layouts on the same miniature cell.
"""
import numpy as np

from tests.conftest import run_subprocess


def test_blockwise_attention_equals_naive():
    import jax
    import jax.numpy as jnp
    from repro.models import layers as L
    rng = np.random.default_rng(0)
    B, Tq, Tk, Hq, Hkv, Dh = 2, 8, 48, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Tq, Hq, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Tk, Hkv, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Tk, Hkv, Dh)), jnp.float32)
    for kw in (dict(causal=True, q_offset=40),
               dict(causal=True, q_offset=16, window=8),
               dict(causal=True, q_offset=0, prefix_len=4, kv_len=30),
               dict(causal=False, q_offset=0)):
        naive = L.attend(q, k, v, **kw)
        bw = L._attend_blockwise(
            q, k, v, scale=Dh ** -0.5, block=16,
            causal=kw.get("causal", True), q_offset=kw.get("q_offset", 0),
            kv_len=kw.get("kv_len"), prefix_len=kw.get("prefix_len", 0),
            window=kw.get("window", 0))
        np.testing.assert_allclose(np.asarray(naive, np.float32),
                                   np.asarray(bw, np.float32), atol=2e-5)
    # gradients agree too (train path)
    import jax
    f = lambda fn: (lambda q: jnp.sum(fn(q) ** 2))
    g1 = jax.grad(f(lambda q: L.attend(q, k, v, causal=True, q_offset=40)))(q)
    g2 = jax.grad(f(lambda q: L._attend_blockwise(
        q, k, v, scale=Dh ** -0.5, block=16, causal=True, q_offset=40,
        kv_len=None, prefix_len=0, window=0)))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=2e-5)


def test_moe_einsum_decode_equals_scatter_path():
    """The §Perf einsum dispatch must match the scatter dispatch when
    neither drops tokens."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_arch
    from repro.distributed import pspec
    from repro.models import moe as moe_lib
    cfg = get_arch("qwen2-moe-a2.7b").reduced()
    defs = moe_lib.moe_defs(cfg.d_model, cfg.moe)
    params = pspec.init_params(defs, jax.random.key(0))
    x = jnp.asarray(np.random.default_rng(1).normal(
        size=(2, 4, cfg.d_model)), jnp.float32)
    E = params["router"].shape[1]
    out_e, _ = moe_lib._moe_decode_einsum(params, x, cfg.moe, E)
    out_s, _ = moe_lib.moe_ffn(params, x, cfg.moe, dropless=True)
    # dropless scatter path routes identically at this size... but the
    # wrapper itself routes to einsum; call the scatter body via a large
    # token threshold
    old = moe_lib._DECODE_EINSUM_MAX_TOKENS
    try:
        moe_lib._DECODE_EINSUM_MAX_TOKENS = 0
        out_s, _ = moe_lib.moe_ffn(params, x, cfg.moe, dropless=True)
    finally:
        moe_lib._DECODE_EINSUM_MAX_TOKENS = old
    scale = float(jnp.abs(out_s).max())
    np.testing.assert_allclose(np.asarray(out_e, np.float32),
                               np.asarray(out_s, np.float32),
                               atol=0.02 * scale)


def test_opt_layouts_reduce_collectives():
    code = """
import dataclasses, jax, re
from jax.sharding import AxisType
import repro.launch.dryrun as dr
from repro.configs import get_arch
from repro.configs.base import ShapeCfg
from repro.analysis.roofline import parse_collectives

mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)

# dense train: FSDP-2D must beat TP+FSDP on collective bytes
cfg = get_arch("granite-3-2b").reduced()
shape = ShapeCfg("t", 256, 8, "train")
res = {}
for layout in ("base", "opt"):
    compiled, *_ = dr.lower_compile(cfg, shape, mesh, unroll=False,
                                    layout=layout)
    res[layout] = parse_collectives(compiled.as_text()).total_bytes
assert res["opt"] < res["base"], res
print("train ok", res)

# moe decode: einsum dispatch must beat scatter dispatch
cfg = get_arch("qwen2-moe-a2.7b").reduced()
shape = ShapeCfg("d", 1024, 8, "decode")
res = {}
for layout in ("base", "opt"):
    compiled, *_ = dr.lower_compile(cfg, shape, mesh, unroll=False,
                                    layout=layout)
    res[layout] = parse_collectives(compiled.as_text()).total_bytes
assert res["opt"] < res["base"], res
print("decode ok", res)
"""
    out = run_subprocess(code, devices=8, timeout=900)
    assert "decode ok" in out


def test_windowed_decode_slice_correct():
    """Sliding-window decode with a window-sized cache slice must equal
    window-masked attention over the full cache (the §Perf long_500k
    change) — tested directly at the attend() level."""
    import jax
    import jax.numpy as jnp
    from repro.models import layers as L
    rng = np.random.default_rng(2)
    B, S, H, Dh, W = 2, 96, 4, 16, 16
    cur = 70                                  # tokens already cached
    q = jnp.asarray(rng.normal(size=(B, 1, H, Dh)), jnp.float32)
    ck = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    cv = jnp.asarray(rng.normal(size=(B, S, H, Dh)), jnp.float32)
    full = L.attend(q, ck, cv, causal=True, q_offset=cur,
                    kv_len=cur + 1, window=W)
    start = cur + 1 - W
    sliced = L.attend(q, ck[:, start:start + W], cv[:, start:start + W],
                      causal=True, q_offset=cur - start,
                      kv_len=cur + 1 - start, window=W)
    np.testing.assert_allclose(np.asarray(full, np.float32),
                               np.asarray(sliced, np.float32), atol=1e-5)

"""Streaming scheduler: chunked + padded micro-batches over the fused
engine must be indistinguishable from one full-batch run, for every
chunking — including ragged tails and chunks larger than the batch
(the padding-leak invariant of docs/PARITY.md)."""
import numpy as np
import pytest

from repro.core.inference import Engine
from repro.core.partition import train_partitioned_dt
from repro.flows.synthetic import make_dataset
from repro.flows.windows import window_features, window_packets
from repro.serve.streaming import microbatches, run_streaming, stream_batches
from repro.testing.hypothesis_compat import given, settings, strategies as st
from repro.core.inference import EngineOptions


@pytest.fixture(scope="module")
def stream_setup(trained_pdt):
    pdt, Xw, tr = trained_pdt
    wp = window_packets(tr, 3)
    eng = Engine.from_model(pdt)
    full = eng.run(wp, with_trace=False)
    oracle = pdt.predict(Xw, return_trace=True)
    return eng, wp, full, oracle


def _assert_same(res, full):
    np.testing.assert_array_equal(res.labels, full.labels)
    np.testing.assert_array_equal(res.recircs, full.recircs)
    np.testing.assert_array_equal(res.exit_partition, full.exit_partition)


def test_microbatch_bounds_cover_exactly():
    bounds = list(microbatches(103, 32))
    assert bounds == [(0, 32), (32, 64), (64, 96), (96, 103)]
    assert list(microbatches(32, 32)) == [(0, 32)]
    with pytest.raises(ValueError):
        list(microbatches(10, 0))


@pytest.mark.parametrize("micro_batch", [1, 7, 64, 10_000])
def test_streaming_equals_full_batch(stream_setup, micro_batch):
    """Every chunking — single-flow, ragged tail, one giant chunk —
    reproduces the full-batch fused run exactly."""
    eng, wp, full, _ = stream_setup
    res = run_streaming(eng, wp, options=EngineOptions(micro_batch=micro_batch))
    _assert_same(res, full)


def test_streaming_matches_oracle(stream_setup):
    """End-to-end: chunked streaming still equals the numpy oracle
    (labels AND recirculation counts — the bandwidth model's input)."""
    eng, wp, _, (labels, recircs, exit_p) = stream_setup
    res = eng.run_streaming(wp, options=EngineOptions(micro_batch=50))
    np.testing.assert_array_equal(res.labels, labels)
    np.testing.assert_array_equal(res.recircs, recircs)
    np.testing.assert_array_equal(res.exit_partition, exit_p)


def test_streaming_padded_tail_is_isolated(stream_setup):
    """A ragged tail is padded with invalid packets; padding must never
    leak into real flows' verdicts (micro_batch chosen so the last
    chunk is mostly padding)."""
    eng, wp, full, _ = stream_setup
    B = wp.shape[0]
    mb = B - 1            # tail chunk holds exactly 1 real flow
    res = run_streaming(eng, wp, options=EngineOptions(micro_batch=mb))
    _assert_same(res, full)


def test_stream_batches_generator(stream_setup):
    """Open-stream form: per-batch results concatenate to the full run."""
    eng, wp, full, _ = stream_setup
    cuts = [0, 13, 200, wp.shape[0]]
    parts = [wp[a:b] for a, b in zip(cuts, cuts[1:])]
    outs = list(stream_batches(eng, parts, options=EngineOptions(micro_batch=64)))
    assert len(outs) == len(parts)
    labels = np.concatenate([o.labels for o in outs])
    recircs = np.concatenate([o.recircs for o in outs])
    np.testing.assert_array_equal(labels, full.labels)
    np.testing.assert_array_equal(recircs, full.recircs)


def test_streaming_donate_flag_explicit(stream_setup):
    """donate=False must be honoured on any backend and stay exact."""
    eng, wp, full, _ = stream_setup
    res = run_streaming(eng, wp, options=EngineOptions(micro_batch=33, donate=False))
    _assert_same(res, full)


@pytest.mark.parametrize("inflight", [1, 3, 8])
def test_streaming_pipelining_depth(stream_setup, inflight):
    """Async in-flight dispatch (any depth) must not change verdicts —
    chunks complete out of the host loop but land in the right rows."""
    eng, wp, full, _ = stream_setup
    res = run_streaming(eng, wp, options=EngineOptions(micro_batch=40, inflight=inflight))
    _assert_same(res, full)
    with pytest.raises(ValueError):
        run_streaming(eng, wp, options=EngineOptions(inflight=0))


def test_streaming_pallas_backend(stream_setup):
    """The in-jit SID dispatch makes the Pallas walk streamable (the
    host-grouped PR 1 path had to reject this); verdicts identical."""
    eng, wp, full, _ = stream_setup
    res = run_streaming(eng, wp[:96], options=EngineOptions(micro_batch=32, impl="pallas"))
    np.testing.assert_array_equal(res.labels, full.labels[:96])
    np.testing.assert_array_equal(res.recircs, full.recircs[:96])
    np.testing.assert_array_equal(res.exit_partition, full.exit_partition[:96])


def test_streaming_rejects_looped_backend(stream_setup):
    eng, wp, _, _ = stream_setup
    with pytest.raises(ValueError, match="walk backend"):
        run_streaming(eng, wp, options=EngineOptions(impl="looped"))


@pytest.mark.parametrize("micro_batch", [40, 10_000])
def test_streaming_compact_equals_full_batch(stream_setup, micro_batch):
    """Early-exit compaction inside each chunk's walk (including the
    padded ragged tail, whose padding rows all 'exit' immediately and
    get compacted away) must not change a single verdict."""
    eng, wp, full, _ = stream_setup
    res = run_streaming(eng, wp, options=EngineOptions(micro_batch=micro_batch, compact=True))
    _assert_same(res, full)


def test_streaming_compact_pallas(stream_setup):
    eng, wp, full, _ = stream_setup
    res = run_streaming(eng, wp[:96], options=EngineOptions(micro_batch=32, impl="pallas", compact=True))
    np.testing.assert_array_equal(res.labels, full.labels[:96])
    np.testing.assert_array_equal(res.recircs, full.recircs[:96])
    np.testing.assert_array_equal(res.exit_partition, full.exit_partition[:96])


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_streaming_padding_never_leaks_property(seed):
    """Adversarial-padding property: the zero rows the scheduler pads
    ragged tails with DECODE TO A VALID EXIT ACTION (an all-invalid
    window produces deterministic registers, and a trained subtree maps
    every register vector to some leaf), so any padding row that leaked
    into the result buffer would overwrite a real verdict with a
    plausible-looking class.  For random chunkings, pipelining depths,
    and compaction, results must equal the unpadded full-batch run."""
    rng = np.random.default_rng(seed)
    ds = make_dataset("d2", n_flows=160, seed=seed)
    Xw = window_features(ds, 2)
    pdt = train_partitioned_dt(Xw, ds.labels,
                               partition_sizes=[2, 2], k=3)
    wp = window_packets(ds, 2)
    eng = Engine.from_model(pdt)
    full = eng.run(wp, with_trace=False)
    # the adversarial premise: all-zero "padding" flows really do decode
    # to valid verdicts (no -1s) — i.e. padding is indistinguishable
    # from a confident classification if it ever leaks
    zero = eng.run(np.zeros_like(wp[:8]), with_trace=False)
    assert (zero.labels >= 0).all()
    B = wp.shape[0]
    for _ in range(3):
        mb = int(rng.integers(1, B + 40))
        res = run_streaming(eng, wp, options=EngineOptions(micro_batch=mb, inflight=int(rng.integers(1, 4)), compact=bool(rng.integers(0, 2))))
        np.testing.assert_array_equal(res.labels, full.labels)
        np.testing.assert_array_equal(res.recircs, full.recircs)
        np.testing.assert_array_equal(res.exit_partition,
                                      full.exit_partition)
